"""Gradient compression for cross-pod sync: int8 quantization with error
feedback (EF-SGD style).

At multi-pod scale the pod axis crosses DCN-class links (an order of
magnitude slower than ICI), so the gradient all-reduce over "pod" is the
step's long pole. Compressing to int8 with per-tensor scale cuts those
bytes 2x vs bf16 (4x vs f32); the quantization error is carried in a
residual accumulator and re-injected next step (error feedback), which
keeps SGD/Adam convergence (Karimireddy et al. 2019).

Usage: wrap the cross-pod reduction only — the intra-pod reduction stays
full precision:

    comp = Int8Compressor()
    g_pod, state = comp.compress(grads, state)        # int8 + scales
    g_pod = psum over "pod" of dequantized             (2x fewer DCN bytes)
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class EFState(NamedTuple):
    residual: Dict      # error-feedback accumulator, mirrors grads


def init_ef_state(grads) -> EFState:
    return EFState(residual=jax.tree_util.tree_map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads))


def _quant(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequant(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress(grads, state: EFState) -> Tuple[Dict, Dict, EFState]:
    """-> (q_tree int8, scale_tree, new_state). Error feedback: the
    un-transmitted remainder is carried to the next step."""
    corrected = jax.tree_util.tree_map(
        lambda g, r: g.astype(jnp.float32) + r, grads, state.residual)
    qs = jax.tree_util.tree_map(_quant, corrected)
    q_tree = jax.tree_util.tree_map(lambda t: t[0], qs,
                                    is_leaf=lambda t: isinstance(t, tuple))
    s_tree = jax.tree_util.tree_map(lambda t: t[1], qs,
                                    is_leaf=lambda t: isinstance(t, tuple))
    residual = jax.tree_util.tree_map(
        lambda c, q, s: c - _dequant(q, s), corrected, q_tree, s_tree)
    return q_tree, s_tree, EFState(residual=residual)


def decompress(q_tree, s_tree, dtype=jnp.float32):
    return jax.tree_util.tree_map(
        lambda q, s: _dequant(q, s).astype(dtype), q_tree, s_tree)


def compressed_bytes(grads) -> Tuple[int, int]:
    """(full f32 bytes, compressed int8+scale bytes) for reporting."""
    full = sum(g.size * 4 for g in jax.tree_util.tree_leaves(grads))
    comp = sum(g.size + 4 for g in jax.tree_util.tree_leaves(grads))
    return full, comp
