"""AdamW with cosine schedule and global-norm clipping, pytree-native.

Optimizer moments mirror the parameter tree, so ZeRO-1 sharding is free:
the moments inherit each parameter's NamedSharding (launch/train wires
``param_shardings`` into the opt-state in_shardings).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class OptState(NamedTuple):
    step: jax.Array
    mu: Dict
    nu: Dict


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def cosine_schedule(base_lr: float, warmup: int, total: int):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = 0.5 * base_lr * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup, warm, cos)
    return lr


@dataclass(frozen=True)
class AdamW:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0
    warmup: int = 100
    total_steps: int = 10_000

    def init(self, params) -> OptState:
        f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
        return OptState(step=jnp.zeros((), jnp.int32),
                        mu=jax.tree_util.tree_map(f32, params),
                        nu=jax.tree_util.tree_map(f32, params))

    def update(self, grads, state: OptState, params, *,
               gnorm=None) -> Tuple[Dict, OptState, Dict]:
        """``gnorm`` overrides the clip norm: a model-parallel caller
        (pipeline stages holding disjoint block slices) passes the true
        cross-stage global norm — the local tree alone would under-count
        it and clip inconsistently per stage."""
        step = state.step + 1
        lr = cosine_schedule(self.lr, self.warmup, self.total_steps)(step)
        if gnorm is None:
            gnorm = global_norm(grads)
        if self.clip_norm is not None:
            scale = jnp.minimum(1.0, self.clip_norm / (gnorm + 1e-9))
            grads = jax.tree_util.tree_map(
                lambda g: g * scale.astype(g.dtype), grads)

        t = step.astype(jnp.float32)
        c1 = 1.0 - self.b1 ** t
        c2 = 1.0 - self.b2 ** t

        def upd(p, g, m, v):
            g32 = g.astype(jnp.float32)
            m_new = self.b1 * m + (1 - self.b1) * g32
            v_new = self.b2 * v + (1 - self.b2) * g32 * g32
            mhat = m_new / c1
            vhat = v_new / c2
            delta = mhat / (jnp.sqrt(vhat) + self.eps)
            if p.ndim >= 2:      # decoupled decay on matrices only
                delta = delta + self.weight_decay * p.astype(jnp.float32)
            return ((p.astype(jnp.float32) - lr * delta).astype(p.dtype),
                    m_new, v_new)

        flat_p, treedef = jax.tree_util.tree_flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_m = treedef.flatten_up_to(state.mu)
        flat_v = treedef.flatten_up_to(state.nu)
        out = [upd(p, g, m, v) for p, g, m, v
               in zip(flat_p, flat_g, flat_m, flat_v)]
        new_p = treedef.unflatten([o[0] for o in out])
        new_m = treedef.unflatten([o[1] for o in out])
        new_v = treedef.unflatten([o[2] for o in out])
        return new_p, OptState(step, new_m, new_v), \
            {"lr": lr, "grad_norm": gnorm}
