"""Importing this module registers all architecture configs."""
from . import (granite_3_2b, llama4_scout, llava_next_34b, mixtral_8x7b,
               qwen2_5_3b, qwen2_72b, smollm_135m, whisper_small,
               xlstm_125m, zamba2_7b)  # noqa: F401
