"""Architecture configs (one module per assigned arch) + shape sets.

Arch modules are loaded lazily (configs/archs.py) to avoid a circular
import with models.registry; ``repro.models.registry.get_config`` triggers
the load."""
from .base import (SHAPES, SHAPES_BY_NAME, ModelConfig, ShapeConfig,
                   cell_applicable)

ALL_ARCHS = (
    "llava-next-34b", "whisper-small", "xlstm-125m", "zamba2-7b",
    "qwen2-72b", "granite-3-2b", "qwen2.5-3b", "smollm-135m",
    "llama4-scout-17b-a16e", "mixtral-8x7b",
)

__all__ = ["ALL_ARCHS", "SHAPES", "SHAPES_BY_NAME", "ModelConfig",
           "ShapeConfig", "cell_applicable"]
