"""smollm-135m [dense] — 30L d_model=576 9H (GQA kv=3) d_ff=1536
vocab=49152, llama-arch small [hf:HuggingFaceTB/SmolLM-135M; hf]."""
from ..models.registry import register
from .base import ModelConfig


@register("smollm-135m")
def smollm_135m() -> ModelConfig:
    return ModelConfig(
        name="smollm-135m", family="dense",
        n_layers=30, d_model=576, n_heads=9, n_kv_heads=3,
        d_ff=1536, vocab_size=49152, tie_embeddings=True,
        rope_theta=1e4,
    )
