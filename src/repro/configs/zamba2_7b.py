"""zamba2-7b [hybrid] — 81L d_model=3584 32H (kv=32) d_ff=14336
vocab=32000, ssm_state=64; Mamba2 backbone + one *shared* attention+MLP
block applied every 3 Mamba blocks (81 = 27 applications; the real model
interleaves two shared blocks ~every 6 — period chosen to divide n_layers,
noted in DESIGN.md §7) [arXiv:2411.15242]. Sub-quadratic: long_500k runs
(SSM state decode + O(1) shared-attn KV reads bounded by the cache
window)."""
from ..models.registry import register
from .base import ModelConfig


@register("zamba2-7b")
def zamba2_7b() -> ModelConfig:
    return ModelConfig(
        name="zamba2-7b", family="hybrid",
        n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32,
        d_ff=14336, vocab_size=32000,
        ssm_state=64, ssm_expand=2, ssm_headdim=64,
        hybrid_attn_every=3,
        sliding_window=4096,   # shared-attn blocks use a windowed cache so
        # 500k decode stays sub-quadratic per application
    )
