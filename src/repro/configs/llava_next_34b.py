"""llava-next-34b [vlm] — 60L d_model=7168 56H (GQA kv=8) d_ff=20480
vocab=64000, anyres tiling [hf:llava-hf/llava-v1.6-mistral-7b-hf;
unverified]. The anyres vision frontend is a STUB per the assignment:
``input_specs()`` supplies precomputed patch embeddings (B, 576, d_model);
the text backbone runs full causal attention over [patches; tokens]."""
from ..models.registry import register
from .base import ModelConfig


@register("llava-next-34b")
def llava_next_34b() -> ModelConfig:
    return ModelConfig(
        name="llava-next-34b", family="vlm",
        n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8,
        d_ff=20480, vocab_size=64000,
        vision_tokens=576,
        rope_theta=5e6,
    )
