"""qwen2.5-3b [dense] — 36L d_model=2048 16H (GQA kv=2) d_ff=11008
vocab=151936, QKV bias [hf:Qwen/Qwen2.5-0.5B; hf]."""
from ..models.registry import register
from .base import ModelConfig


@register("qwen2.5-3b")
def qwen2_5_3b() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-3b", family="dense",
        n_layers=36, d_model=2048, n_heads=16, n_kv_heads=2,
        d_ff=11008, vocab_size=151936, qkv_bias=True,
        tie_embeddings=True, rope_theta=1e6,
    )
