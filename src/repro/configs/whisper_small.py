"""whisper-small [audio] — enc-dec, 12L decoder (and 12L encoder)
d_model=768 12H (kv=12) d_ff=3072 vocab=51865, conv frontend stubbed to
precomputed frame embeddings (B, 1500, 768) [arXiv:2212.04356]. Decode
shapes lower the decoder with a 32k self-attn KV cache structurally (the
real model caps at 448 decoder positions — noted in DESIGN.md §7);
long_500k is skipped (full attention)."""
from ..models.registry import register
from .base import ModelConfig


@register("whisper-small")
def whisper_small() -> ModelConfig:
    return ModelConfig(
        name="whisper-small", family="audio",
        n_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
        d_ff=3072, vocab_size=51865,
        encoder_layers=12, encoder_seq=1500, cross_attention=True,
        rope_theta=1e4,
    )
