"""Model / run configuration dataclasses.

One ``ModelConfig`` describes any of the supported architecture families
(dense / moe / ssm / hybrid / audio enc-dec / vlm backbone); family-specific
fields are ignored by the others. Configs are plain frozen dataclasses so
they hash (used as jit static args and cache keys).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None    # default: d_model // n_heads
    qkv_bias: bool = False
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    moe_group_size: int = 2048    # 0 = ungrouped dispatch (baseline)
    # --- attention variants ---
    sliding_window: Optional[int] = None   # SWA window (Mixtral: 4096)
    # --- SSM / hybrid ---
    ssm_state: int = 0                # Mamba2 state dim N
    ssm_conv: int = 4                 # depthwise conv width
    ssm_expand: int = 2               # Mamba2 expansion factor
    ssm_headdim: int = 64             # Mamba2 SSD head dim P
    hybrid_attn_every: int = 0        # zamba2: shared attn block period
    # --- xLSTM ---
    slstm_every: int = 0              # 1-in-k layers use sLSTM (rest mLSTM)
    # --- encoder-decoder (whisper) ---
    encoder_layers: int = 0
    encoder_seq: int = 0              # precomputed frame embeddings length
    cross_attention: bool = False
    # --- VLM backbone ---
    vision_tokens: int = 0            # stub frontend: # patch embeddings
    # --- misc ---
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: str = "bfloat16"

    # ------------------------------------------------------------------
    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim is not None \
            else self.d_model // self.n_heads

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.hd

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.hd

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def attention_free(self) -> bool:
        return self.family in ("ssm",) and self.hybrid_attn_every == 0

    @property
    def subquadratic(self) -> bool:
        """Can serve very long contexts (500k) at sub-quadratic cost: SSM,
        hybrid (SSM + O(1) shared-attn KV reads) and sliding-window models
        (ring-buffer cache)."""
        return (self.family in ("ssm", "hybrid")
                or self.sliding_window is not None)

    def reduced(self, **overrides) -> "ModelConfig":
        """Small same-family config for CPU smoke tests."""
        n_layers = min(self.n_layers, 2)
        if self.hybrid_attn_every or self.slstm_every:
            n_layers = 4      # 2 groups of 2 (group scans need L % k == 0)
        base = dict(
            n_layers=n_layers,
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads
            else self.n_kv_heads,
            head_dim=16,
            d_ff=128 if self.d_ff else 0,
            vocab_size=128,
            n_experts=min(self.n_experts, 4),
            sliding_window=16 if self.sliding_window else None,
            ssm_state=16 if self.ssm_state else 0,
            ssm_headdim=16 if self.ssm_state else 64,
            encoder_layers=min(self.encoder_layers, 2),
            encoder_seq=16 if self.encoder_seq else 0,
            vision_tokens=8 if self.vision_tokens else 0,
            hybrid_attn_every=2 if self.hybrid_attn_every else 0,
            slstm_every=min(self.slstm_every, 2) if self.slstm_every else 0,
            dtype="float32",
        )
        base.update(overrides)
        return dataclasses.replace(self, **base)

    # ---- parameter counting (for roofline MODEL_FLOPS = 6·N·D) ----------
    def param_count(self, active_only: bool = False) -> int:
        """Total (or MoE-active) parameter count, embeddings included."""
        D, F, V = self.d_model, self.d_ff, self.vocab_size
        qd, kd = self.q_dim, self.kv_dim
        attn = D * qd + 2 * D * kd + qd * D
        if self.qkv_bias:
            attn += qd + 2 * kd
        mlp = 3 * D * F                      # gate/up/down (swiglu)
        per_layer = 0
        if self.family in ("dense", "vlm", "audio"):
            per_layer = attn + mlp + 2 * D
        elif self.family == "moe":
            n_e = (self.top_k if active_only else self.n_experts)
            per_layer = attn + n_e * mlp + D * self.n_experts + 2 * D
        elif self.family == "ssm":
            per_layer = self._ssm_block_params() + 2 * D
            if self.slstm_every:   # xLSTM mix: approximate with mLSTM size
                per_layer = self._xlstm_block_params() + 2 * D
        elif self.family == "hybrid":
            per_layer = self._ssm_block_params() + 2 * D
        total = self.n_layers * per_layer
        if self.family == "hybrid" and self.hybrid_attn_every:
            total += attn + mlp + 2 * D      # one shared block
        if self.is_encdec:
            total += self.encoder_layers * (attn + mlp + 2 * D)
            total += self.n_layers * (attn + 2 * D)   # cross-attn
        total += V * D * (1 if self.tie_embeddings else 2)
        return total

    def _ssm_block_params(self) -> int:
        D = self.d_model
        d_in = self.ssm_expand * D
        nh = d_in // self.ssm_headdim
        # in_proj -> [z, x, B, C, dt] ; out_proj
        zxbcdt = 2 * d_in + 2 * self.ssm_state + nh
        return D * zxbcdt + self.ssm_conv * (d_in + 2 * self.ssm_state) \
            + 3 * nh + d_in * D

    def _xlstm_block_params(self) -> int:
        D = self.d_model
        d_in = 2 * D
        # mLSTM: up-proj to 2D, qkv, gates, out
        return D * 2 * d_in + 3 * d_in * d_in // 4 + 3 * d_in + d_in * D


@dataclass(frozen=True)
class ShapeConfig:
    """One benchmark cell's input shape."""

    name: str
    seq_len: int
    global_batch: int
    kind: str        # "train" | "prefill" | "decode"

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4_096, 256, "train"),
    ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    ShapeConfig("decode_32k", 32_768, 128, "decode"),
    ShapeConfig("long_500k", 524_288, 1, "decode"),
)

SHAPES_BY_NAME = {s.name: s for s in SHAPES}


def cell_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Assignment rules: which (arch x shape) cells run.

    ``long_500k`` needs sub-quadratic attention — skipped for pure
    full-attention archs (noted in DESIGN.md §7)."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, "full-attention arch: 500k decode is quadratic (skip)"
    return True, ""
