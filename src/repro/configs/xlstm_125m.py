"""xlstm-125m [ssm] — 12L d_model=768 4H vocab=50304, sLSTM + mLSTM blocks
[arXiv:2405.04517]. Block mix: one sLSTM every 4 layers (xLSTM[3:1]-style),
mLSTM elsewhere; d_ff=0 (the blocks carry their own up/down projections).
Attention-free: all four shapes run, including long_500k (O(1)-state
decode)."""
from ..models.registry import register
from .base import ModelConfig


@register("xlstm-125m")
def xlstm_125m() -> ModelConfig:
    return ModelConfig(
        name="xlstm-125m", family="ssm",
        n_layers=12, d_model=768, n_heads=4, n_kv_heads=4,
        d_ff=0, vocab_size=50304,
        slstm_every=4, tie_embeddings=True,
    )
