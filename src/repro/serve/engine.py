"""Batched serving engine: prefill -> decode with KV-cache handoff.

Continuous-batching-lite with a **phase-gated** slot refill: the decode
batch is a phaser team (DESIGN.md §3) — every decode step is one phase,
each occupied slot is a participant, and batch-membership changes ride
the same epoch mechanism as elastic training:

* a request entering a free slot is a JOIN (the paper's eager insertion:
  prefill + cache splice happen immediately, at the step boundary, and
  no running request is disturbed);
* a finished request is a LEAVE (deletion: the phase completes without
  it and the slot is reclaimed);
* the runtime's epoch index versions the batch composition — the swap is
  observable only at phase boundaries, so a step never sees a
  half-admitted batch.

Admission is **bulk**: all free slots are filled at the same phase
boundary, grouped by prompt length **padded up to a power-of-two
bucket**, and the admission *group size* is padded up to a power-of-two
row bucket too (clamped to the slot count) — so admission compiles ONE
prefill executable per (length bucket, group bucket) instead of one per
distinct (prompt length, group size): a boundary that happens to admit
3 requests hits the executable the 4-request boundary compiled. Each
group runs one full-logits prefill over the padded prompts (a single
forward instead of one decode step per token); causality keeps every
position below a request's true length unaffected by the pad tail, so
the engine reads each request's next token at its own ``len - 1`` and
splices only the first ``len`` KV positions into the slot's cache
region, without touching running slots; the pad ROWS' outputs are
simply sliced away before the splice.

Families whose decode state is a **recurrence** (ssm / xlstm / hybrid)
cannot splice a full-logits prefill's caches — their state is the
O(1) carry after the prompt, not a per-position buffer. They get their
own bulk path (``ModelAPI.prefill_state_fn``): one compiled
length-masked decode scan over the padded group (a slot's state freezes
at its true length), spliced into the admitted slots in one vectorized
scatter. That replaces G x len full-batch decode dispatches per group
with ONE jitted call per (group size, bucket) — the recurrent analogue
of the KV cache splice. Enc-dec/vlm and prompts longer than the cache
window keep the token-by-token path.

Correctness note (the bug this design fixed): anything handed to the
async-dispatched jitted decode must be an immutable snapshot. Passing a
live numpy buffer zero-copy and then mutating it in place (the next
prefill token, ``slot_pos[i] += 1``) races the pending execution —
flakily, since the window depends on dispatch latency. All device inputs
therefore go through ``utils.to_device_copy``.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..models.registry import ModelAPI
from ..obs.metrics import MetricsRegistry
from ..runtime_elastic.elastic_phaser import ElasticPhaserRuntime
from ..utils import to_device_copy


@dataclass
class Request:
    rid: int
    prompt: np.ndarray              # (S,) int32
    max_new: int = 16
    out: List[int] = field(default_factory=list)
    done: bool = False
    t_submit: float = 0.0           # stamped by submit(); queue-wait base


class ServeEngine:
    def __init__(self, api: ModelAPI, params, *, batch: int = 4,
                 window: int = 256, seed: int = 0):
        self.api = api
        self.cfg = api.cfg
        self.params = params
        self.batch = batch
        self.window = window
        self.state = api.init_decode_state(batch, window)
        self.slot_req: List[Optional[Request]] = [None] * batch
        self.slot_pos = np.zeros((batch,), np.int32)
        self.queue: List[Request] = []
        # control plane: occupied slots are phaser participants; admission
        # keys are monotone (a slot reused by a later request is a new
        # participant — phaser keys are never recycled)
        self.gate = ElasticPhaserRuntime(0, seed=seed, axis_name="slots")
        self.slot_key: List[Optional[int]] = [None] * batch
        self.finished: List[Request] = []
        # no donation: _admit snapshots the pre-prefill state for splicing
        self._decode = jax.jit(api.decode_fn)
        # per-engine metrics shard (obs plane): trace counters,
        # admission kinds, retire counts, decode occupancy. The legacy
        # ``prefill_traces``/``prefill_state_traces`` attributes are
        # read-only views over these counters.
        self.metrics = MetricsRegistry()
        # full-logits prefill: length-bucketed groups read each
        # request's next token at its true len-1, not the padded tail.
        # The trace counters tick ONCE per lowering (the wrapped python
        # body only runs at trace time): regression tests assert a new
        # admission group size re-uses the cached executable.

        def _pf(p, b):
            self.metrics.inc("serve.prefill.traces")
            return api.prefill_full_fn(p, b)

        self._prefill = jax.jit(_pf)
        # per-leaf batch dim: the dim whose size changes with the batch
        # (needed to splice a newly-prefilled slot into the live state
        # without touching other slots)
        self._bdim = api.decode_state_bdims(batch, window)
        # bulk-prefill eligibility: decode state must be the plain stacked
        # KV cache whose layout prefill_fn's caches splice into directly
        layers = self.state.get("layers")
        self._bulk = (self.cfg.family in ("dense", "moe")
                      and not self.cfg.is_encdec
                      and isinstance(layers, dict)
                      and set(layers) == {"k", "v", "pos"})
        self._kv_window = layers["k"].shape[2] if self._bulk else 0
        # recurrent families take the length-masked decode-scan bulk
        # path instead (xlstm is family "ssm" with slstm groups)
        self._bulk_rec = (self.cfg.family in ("ssm", "hybrid")
                          and not self.cfg.is_encdec)
        # one compiled scan per (group bucket, length bucket) — the
        # window is static and the group dim pads to pow2 rows
        def _ps(p, toks, lens):
            self.metrics.inc("serve.prefill_state.traces")
            return api.prefill_state_fn(p, toks, lens, window=window)

        self._prefill_state = jax.jit(_ps)

    @property
    def prefill_traces(self) -> int:
        """Compat view: lowerings of the full-logits prefill."""
        return self.metrics.counter("serve.prefill.traces").value

    @property
    def prefill_state_traces(self) -> int:
        """Compat view: lowerings of the recurrent prefill scan."""
        return self.metrics.counter("serve.prefill_state.traces").value

    @property
    def epoch(self) -> int:
        """Batch-membership epoch (bumps at the boundary after any
        admit/retire, exactly like the training runtime)."""
        return self.gate.epoch.index

    def _splice_slot(self, old_state, new_state, slot: int):
        """Keep ``new_state`` only at ``slot``; other slots keep ``old``
        (admitting a request must not disturb running ones — recurrent
        states would otherwise be corrupted by the admit steps)."""
        def f(o, n, d):
            idx = jnp.arange(o.shape[d])
            shape = [1] * o.ndim
            shape[d] = -1
            return jnp.where((idx == slot).reshape(shape), n, o)
        return jax.tree_util.tree_map(f, old_state, new_state, self._bdim)

    def _dispatch(self, token_b: np.ndarray, pos_b: np.ndarray):
        """One jitted decode call. Inputs are SNAPSHOTTED into fresh
        buffers owned by this call (``to_device_copy``): the
        host-to-device transfer may alias the source buffer and read it
        asynchronously, so handing it a buffer the caller mutates right
        after dispatch (the next prefill token, ``slot_pos[i] += 1``)
        races the pending execution (see module docstring)."""
        return self._decode(
            self.params, self.state,
            {"token": to_device_copy(token_b, dtype=np.int32),
             "t": to_device_copy(pos_b, dtype=np.int32)})

    # ------------------------------------------------------------- intake
    def submit(self, req: Request) -> None:
        req.t_submit = time.perf_counter()
        self.queue.append(req)

    @staticmethod
    def _bucket_len(length: int) -> int:
        """Prompt lengths pad up to power-of-two buckets, so admission
        compiles one prefill per (group bucket, length bucket) instead
        of one per distinct prompt length."""
        return 1 << max(0, (length - 1)).bit_length()

    def _bucket_group(self, n: int) -> int:
        """Admission group sizes pad up to power-of-two ROW buckets
        (clamped to the slot count — a group can never exceed the
        batch), the same trick as prompt-length buckets: one compiled
        prefill/decode-scan executable per (length bucket, group
        bucket) serves every admission size."""
        return min(self._bucket_len(max(1, n)), self.batch)

    def _admit(self) -> None:
        """Phase-boundary refill: fill ALL free slots from the queue at
        this boundary (JOIN = eager insertion). Admits are batched: bulk
        groups (same power-of-two length bucket) run one padded prefill
        forward (KV families) or one length-masked decode scan
        (recurrent families) each and splice their states in; everything
        else falls back to token-by-token prefill."""
        admits: List[Tuple[int, Request]] = []
        for slot in range(self.batch):
            if self.slot_req[slot] is None and self.queue:
                admits.append((slot, self.queue.pop(0)))
        groups: Dict[Tuple[str, int], List[Tuple[int, Request]]] = {}
        for slot, req in admits:
            # clamp to the window so a non-pow2 window keeps its largest
            # admissible prompts on the bulk path (they share one
            # window-sized bucket)
            L = len(req.prompt)
            if self._bulk and L <= self._kv_window:
                bucket = min(self._bucket_len(L), self._kv_window)
                groups.setdefault(("kv", bucket), []).append((slot, req))
            elif self._bulk_rec and L <= self.window:
                bucket = min(self._bucket_len(L), self.window)
                groups.setdefault(("rec", bucket), []).append((slot, req))
            else:
                self.metrics.inc("serve.admit.sequential")
                self._admit_sequential(slot, req)
        for (kind, bucket), group in sorted(groups.items()):
            self.metrics.inc(f"serve.admit.{kind}", len(group))
            self.metrics.observe("serve.admit.group_size", len(group))
            if kind == "kv":
                self._admit_bulk(group, bucket)
            else:
                self._admit_bulk_recurrent(group, bucket)

    def _admit_bulk(self, group: List[Tuple[int, "Request"]],
                    bucket: int) -> None:
        """One padded prefill forward over the whole group (rows padded
        to the pow2 group bucket), then splice each slot's cache region
        up to its TRUE prompt length (running slots untouched; neither
        the pad tail's KV nor the pad rows ever enter the cache)."""
        G = len(group)
        lengths = [len(r.prompt) for _, r in group]
        tokens = np.zeros((self._bucket_group(G), bucket), np.int32)
        for g, (_, r) in enumerate(group):
            tokens[g, :lengths[g]] = r.prompt
        logits, caches = self._prefill(self.params,
                                       {"tokens": to_device_copy(tokens)})
        # drop the pad rows: only the true group reaches the splice
        logits = logits[:G]
        caches = {**caches,
                  "layers": {k: v[:, :G]
                             for k, v in caches["layers"].items()}}
        self.state = self._splice_prefill(self.state, caches,
                                          [s for s, _ in group], lengths)
        # next token at each request's own last REAL position
        nxt = np.asarray(jnp.argmax(
            logits[jnp.arange(len(group)),
                   jnp.asarray(lengths) - 1], axis=-1))
        for g, (slot, req) in enumerate(group):
            self._occupy(slot, req, int(nxt[g]), lengths[g])

    def _splice_prefill(self, state, caches, slots: List[int],
                        lengths: List[int]):
        """Write the prefilled per-layer KV into the admitted slots'
        cache regions. One vectorized set per tensor over the whole
        group (not one per slot — each eager ``.at[].set`` copies the
        full cache): k/v take the entire padded bucket, and the pos
        mask validates only 0..len_i-1 per slot, so the pad tail's KV
        stays masked out of attention (kpos -1 = padding) exactly as if
        it were never written. Every other slot's cache is untouched."""
        st = state["layers"]
        pf = caches["layers"]
        bucket = pf["k"].shape[2]
        sl = jnp.asarray(slots)
        pos = jnp.arange(bucket, dtype=jnp.int32)
        valid = pos[None] < jnp.asarray(lengths, jnp.int32)[:, None]
        new = dict(st)
        new["k"] = st["k"].at[:, sl, :bucket].set(
            pf["k"].astype(st["k"].dtype))
        new["v"] = st["v"].at[:, sl, :bucket].set(
            pf["v"].astype(st["v"].dtype))
        # invalidate the slot's WHOLE window first: a reused slot whose
        # previous prompt was longer than this bucket would otherwise
        # keep stale attendable pos rows beyond the new region
        new["pos"] = st["pos"].at[:, sl].set(-1).at[:, sl, :bucket].set(
            jnp.broadcast_to(jnp.where(valid, pos[None], -1),
                             (st["pos"].shape[0], len(slots), bucket)))
        return {**state, "layers": new}

    def _admit_bulk_recurrent(self, group: List[Tuple[int, "Request"]],
                              bucket: int) -> None:
        """Bulk admission for recurrent-state families: ONE compiled
        length-masked decode scan over the padded group
        (``prefill_state_fn``) produces every request's final recurrent
        state and its next-token logits at its own ``len - 1``; the
        states splice into the admitted slots in one vectorized scatter
        (running slots untouched). The group dim pads to the pow2 group
        bucket (pad rows scan length-1 dummies and are sliced away), so
        a new admission size hits the cached executable."""
        G = len(group)
        Gp = self._bucket_group(G)
        lengths = [len(r.prompt) for _, r in group]
        tokens = np.zeros((Gp, bucket), np.int32)
        for g, (_, r) in enumerate(group):
            tokens[g, :lengths[g]] = r.prompt
        pad_lens = np.ones((Gp,), np.int32)
        pad_lens[:G] = lengths
        logits, gstate = self._prefill_state(
            self.params, to_device_copy(tokens),
            to_device_copy(pad_lens, dtype=np.int32))
        gstate = jax.tree_util.tree_map(
            lambda leaf, d: jnp.moveaxis(
                jnp.moveaxis(leaf, d, 0)[:G], 0, d),
            gstate, self._bdim)
        self.state = self._splice_state_group(self.state, gstate,
                                              [s for s, _ in group])
        nxt = np.asarray(jnp.argmax(logits[:G], axis=-1))
        for g, (slot, req) in enumerate(group):
            self._occupy(slot, req, int(nxt[g]), lengths[g])

    def _splice_state_group(self, state, gstate, slots: List[int]):
        """Scatter a group-batched decode state (leading batch = the
        group) into the live state's admitted slots, one vectorized set
        per leaf along its batch dim."""
        sl = jnp.asarray(slots)

        def f(o, n, d):
            om = jnp.moveaxis(o, d, 0)
            nm = jnp.moveaxis(n, d, 0)
            return jnp.moveaxis(om.at[sl].set(nm.astype(om.dtype)), 0, d)

        return jax.tree_util.tree_map(f, state, gstate, self._bdim)

    def _admit_sequential(self, slot: int, req: "Request") -> None:
        """Fallback admission for recurrent-state families and prompts
        beyond the cache window: prefill via decode steps, then splice
        only this slot's state back."""
        old_state = self.state
        # a REUSED slot still holds the previous request's state: a
        # recurrent carry (or stale KV pos rows) would leak into this
        # prefill — reset the slot to a fresh init first
        self.state = self._splice_slot(
            old_state, self.api.init_decode_state(self.batch, self.window),
            slot)
        token_b = np.zeros((self.batch,), np.int32)
        logits = None
        for t, tok in enumerate(req.prompt):
            token_b[slot] = tok
            logits, self.state = self._dispatch(
                token_b, self._pos_with(slot, t))
        self.state = self._splice_slot(old_state, self.state, slot)
        self._occupy(slot, req, int(jnp.argmax(logits[slot])),
                     len(req.prompt))

    def _occupy(self, slot: int, req: "Request", first_tok: int,
                length: int) -> None:
        # admission completes here: submit -> first token in a slot is
        # the request's queue wait (histogram buckets give p50/p99)
        if req.t_submit:
            self.metrics.observe("serve.admit.queue_wait_seconds",
                                 time.perf_counter() - req.t_submit)
        req.out.append(first_tok)
        self.slot_key[slot] = self.gate.request_join()
        self.slot_req[slot] = req
        self.slot_pos[slot] = length
        if len(req.out) >= req.max_new:
            req.done = True
            self._retire(slot)

    def _retire(self, slot: int) -> None:
        """LEAVE: the finished request's participant deregisters; the
        slot is reclaimed for the next boundary's refill."""
        self.finished.append(self.slot_req[slot])
        self.metrics.inc("serve.retired")
        self.gate.request_leave(self.slot_key[slot])
        self.slot_key[slot] = None
        self.slot_req[slot] = None

    def _pos_with(self, slot: int, t: int) -> np.ndarray:
        pos = self.slot_pos.copy()
        pos[slot] = t
        return pos

    # -------------------------------------------------------------- serve
    def step(self) -> int:
        """One decode step == one phase over the live batch; returns the
        number of active slots. Membership changes (admits at the leading
        boundary, retires at the trailing one) land as gate epochs."""
        self._admit()
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        self.metrics.set("serve.occupancy", len(active))
        self.metrics.observe("serve.active_slots", len(active))
        if not active:
            if self.gate.pending_churn:
                # a request was admitted AND retired inside _admit (e.g.
                # max_new reached at prefill): its join/leave must still
                # land as an epoch at this boundary
                self.gate.advance()
            return 0
        token_b = np.zeros((self.batch,), np.int32)
        for i in active:
            r = self.slot_req[i]
            token_b[i] = r.out[-1] if r.out else r.prompt[-1]
        self.metrics.inc("serve.decode.steps")
        t0 = time.perf_counter()
        logits, self.state = self._dispatch(token_b, self.slot_pos)
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        # np.asarray forced the device sync: this is the real per-token
        # decode latency of the whole batch (p50/p99 from the buckets)
        self.metrics.observe("serve.decode.token_seconds",
                             time.perf_counter() - t0)
        for i in active:
            r = self.slot_req[i]
            r.out.append(int(nxt[i]))
            self.slot_pos[i] += 1
            if len(r.out) >= r.max_new:
                r.done = True
                self._retire(i)     # slot freed -> next boundary refills
        # the step's phase: every live participant signals, the advance
        # marks the boundary where this step's churn becomes the new epoch
        self.gate.advance()
        return len(active)

    def run_until_drained(self, max_steps: int = 10_000) -> List[Request]:
        """Drive steps until queue and batch are empty; returns the
        requests finished during the drain, in completion order."""
        mark = len(self.finished)
        for _ in range(max_steps):
            n = self.step()
            if n == 0 and not self.queue:
                break
        return self.finished[mark:]
