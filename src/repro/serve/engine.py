"""Batched serving engine: prefill -> decode with KV-cache handoff.

Continuous-batching-lite: a fixed decode batch; finished slots are refilled
by prefilling queued requests and splicing their cache into the slot —
the serving analogue of the phaser's eager participant insertion (a new
request joins the active batch at the next step boundary; no running
request is disturbed).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..models.registry import ModelAPI


@dataclass
class Request:
    rid: int
    prompt: np.ndarray              # (S,) int32
    max_new: int = 16
    out: List[int] = field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, api: ModelAPI, params, *, batch: int = 4,
                 window: int = 256):
        self.api = api
        self.cfg = api.cfg
        self.params = params
        self.batch = batch
        self.window = window
        self.state = api.init_decode_state(batch, window)
        self.slot_req: List[Optional[Request]] = [None] * batch
        self.slot_pos = np.zeros((batch,), np.int32)
        self.queue: List[Request] = []
        # no donation: _admit snapshots the pre-prefill state for splicing
        self._decode = jax.jit(api.decode_fn)
        # per-leaf batch dim: the dim whose size changes with the batch
        # (needed to splice a newly-prefilled slot into the live state
        # without touching other slots)
        s1 = api.decode_state_spec(batch, window)
        s2 = api.decode_state_spec(batch + 1, window)
        self._bdim = jax.tree_util.tree_map(
            lambda a, b: next(i for i, (x, y)
                              in enumerate(zip(a.shape, b.shape))
                              if x != y), s1, s2)

    def _splice_slot(self, old_state, new_state, slot: int):
        """Keep ``new_state`` only at ``slot``; other slots keep ``old``
        (admitting a request must not disturb running ones — recurrent
        states would otherwise be corrupted by the admit steps)."""
        def f(o, n, d):
            idx = jnp.arange(o.shape[d])
            shape = [1] * o.ndim
            shape[d] = -1
            return jnp.where((idx == slot).reshape(shape), n, o)
        return jax.tree_util.tree_map(f, old_state, new_state, self._bdim)

    # ------------------------------------------------------------- intake
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _admit(self) -> None:
        """Eager insertion: fill free slots from the queue by prefilling
        the prompt token-by-token into the slot's cache region."""
        for slot in range(self.batch):
            if self.slot_req[slot] is not None or not self.queue:
                continue
            req = self.queue.pop(0)
            # prefill via decode steps, then splice only this slot's state
            # back (simple and correct for every family; the bulk prefill
            # path is exercised by prefill_fn in the dryrun cells)
            old_state = self.state
            token_b = np.zeros((self.batch,), np.int32)
            logits = None
            for t, tok in enumerate(req.prompt):
                token_b[slot] = tok
                logits, self.state = self._decode(
                    self.params, self.state,
                    {"token": jnp.asarray(token_b),
                     "t": jnp.asarray(self._pos_with(slot, t))})
            self.state = self._splice_slot(old_state, self.state, slot)
            req.out.append(int(jnp.argmax(logits[slot])))
            self.slot_req[slot] = req
            self.slot_pos[slot] = len(req.prompt)
            if len(req.out) >= req.max_new:
                req.done = True
                self.slot_req[slot] = None

    def _pos_with(self, slot: int, t: int) -> np.ndarray:
        pos = self.slot_pos.copy()
        pos[slot] = t
        return pos

    # -------------------------------------------------------------- serve
    def step(self) -> int:
        """One decode step over the live batch; returns #active slots."""
        self._admit()
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return 0
        token_b = np.zeros((self.batch,), np.int32)
        for i in active:
            r = self.slot_req[i]
            token_b[i] = r.out[-1] if r.out else r.prompt[-1]
        logits, self.state = self._decode(
            self.params, self.state,
            {"token": jnp.asarray(token_b),
             "t": jnp.asarray(self.slot_pos)})
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        for i in active:
            r = self.slot_req[i]
            r.out.append(int(nxt[i]))
            self.slot_pos[i] += 1
            if len(r.out) >= r.max_new:
                r.done = True
                self.slot_req[i] = None     # slot freed -> next _admit fills
        return len(active)

    def run_until_drained(self, max_steps: int = 10_000) -> List[Request]:
        done: List[Request] = []
        seen: set = set()
        for _ in range(max_steps):
            n = self.step()
            if n == 0 and not self.queue:
                break
        return done
