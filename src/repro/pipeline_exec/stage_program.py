"""Compiled pipeline-parallel train programs on the 2-D (stage, data) mesh.

``build_pipeline_program`` lowers the point-to-point dependency graph of
``core/p2p.py`` — stages SIG toward their successor, WAIT on their
predecessor — into one ``shard_map`` train step over a 2-D mesh:

* the **stage axis** partitions the stacked-blocks scan (stage s owns
  scan slice ``stage_map[s]``; embed/norms/head/shared replicated);
  activations and cotangents move between neighbouring stages as
  ``lax.ppermute`` rounds — one per schedule wave, emitted in the
  wave-synchronous 1F1B order ``derive_1f1b`` derives from the phase
  ordering (``schedule.py``). Each backward wave recomputes its stage
  slice under ``jax.vjp`` from the stored incoming activation (the 1F1B
  in-flight set), so cross-stage dataflow is exactly the phaser graph's
  signal/wait structure.
* the **data axis** runs the elastic epoch's collective schedule
  unchanged: the stage-local grads flatten into the engine's bucket
  layout (derived from the LOCAL param slice) and sync through
  ``execute_flat`` / ``execute_flat_pipelined`` — the same ppermute
  rounds, fused Pallas combine, alive-flag count and overlap config as
  the single-axis engine, now per stage row. Replicated-parameter grads
  (embed/head/shared) are psum'ed over the stage axis first, and the
  AdamW clip norm is computed globally across stages, so the update is
  mathematically identical to the single-axis step (asserted to f32
  tolerance against the ``xla_psum`` baseline program in
  ``examples/elastic_train.py`` through grow/shrink churn).

SPMD uniformity: every wave is kind-uniform (all active stages run the
same instruction), so warmup/cooldown idleness is masked compute — the
same wall-clock shape as a real pipeline bubble — and the per-stage
microbatch index is data (``wave - axis_index``), not control flow.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..collective_exec.buckets import make_layout
from ..collective_exec.executor import execute_flat, execute_flat_pipelined
from ..collective_exec.program import OVERLAP_MODES
from ..core.collective import PhaserCollective
from ..optim import OptState
from ..sharding.policies import stage_data_mesh
from .schedule import PipelineSchedule, derive_1f1b

STAGE_AXIS = "stage"


def stage_partition(api, n_stages: int) -> Tuple[Tuple[int, int], ...]:
    """The stage map: contiguous [lo, hi) slices of the stacked-blocks
    scan axis, one per stage. The scan length (layers, or groups for the
    grouped families) must divide evenly."""
    assert n_stages >= 1, n_stages
    assert api.pipeline_supported(), \
        f"pipeline: family {api.cfg.family!r} keeps the single-axis path"
    spec = api.param_spec()
    lens = {l.shape[0] for l in jax.tree_util.tree_leaves(spec["blocks"])}
    assert len(lens) == 1, f"ragged scan axis: {lens}"
    scan_len = lens.pop()
    assert scan_len % n_stages == 0, \
        f"scan length {scan_len} not divisible by {n_stages} stages"
    per = scan_len // n_stages
    return tuple((s * per, (s + 1) * per) for s in range(n_stages))


def _spec_tree(param_spec, leaf_spec: P, blocks_spec: P):
    """PartitionSpec tree over the param structure: ``blocks`` leaves
    sharded, everything else replicated."""
    return {k: jax.tree_util.tree_map(
        lambda _: blocks_spec if k == "blocks" else leaf_spec, v)
        for k, v in param_spec.items()}


@dataclass
class PipelineProgram:
    """One epoch's compiled 2-D train step. Mirrors ``GradSyncProgram``'s
    surface (``step``/``reduce_metrics``) so the train loop and example
    drive both interchangeably; ``key`` additionally carries the stage
    map and pipeline config."""

    key: tuple
    pc: PhaserCollective
    mesh: Mesh
    sched: PipelineSchedule
    stage_map: Tuple[Tuple[int, int], ...]
    layout: Any
    jitted: Callable
    stacked: bool
    param_sh: Any
    opt_sh: Any
    meta: Dict[str, int] = field(default_factory=dict)

    @property
    def n(self) -> int:
        return self.pc.n

    @property
    def n_stages(self) -> int:
        return len(self.stage_map)

    def _commit(self, tree, shardings):
        """Re-commit carried state onto this program's 2-D mesh (stage
        slices for blocks, replicated otherwise) — resharding is a no-op
        within an epoch, an explicit device_put across epoch swaps."""
        return jax.tree_util.tree_map(
            lambda x, sh: x if getattr(x, "sharding", None) == sh
            else jax.device_put(x, sh), tree, shardings)

    def step(self, params, opt_state, batch, alive=None):
        if alive is None:
            alive = jnp.ones((self.pc.n,), jnp.float32)
        params = self._commit(params, self.param_sh)
        opt_state = self._commit(opt_state, self.opt_sh)
        return self.jitted(params, opt_state, batch, alive)

    def reduce_metrics(self, pm: Dict[str, jax.Array]) -> Dict[str, Any]:
        n_alive = jnp.maximum(pm["alive"].sum(), 1.0)
        out = {}
        for k, v in pm.items():
            if k in ("loss", "aux"):
                out[k] = v.sum() / n_alive
            elif k == "alive":
                out[k] = v.sum()
            else:
                out[k] = v[0]
        out.update({k: jnp.asarray(v, jnp.float32)
                    for k, v in self.meta.items()})
        return out


def build_pipeline_program(api, opt, pc: PhaserCollective, *,
                           n_stages: int,
                           devices: Optional[Sequence] = None,
                           microbatches: int = 1,
                           stacked: bool = False,
                           remat: bool = False,
                           fused: bool = True,
                           interpret: Optional[bool] = None,
                           overlap: str = "eager",
                           bucket_elems: Optional[int] = None
                           ) -> PipelineProgram:
    """Compile the epoch's 2-D program: the 1F1B stage pipeline on the
    stage axis interleaved with the epoch's gradient-sync schedule on
    the data axis. ``microbatches`` is the pipeline depth M (the batch
    splits along its leading dim); ``overlap`` selects the data-axis
    executor exactly as in ``build_gradsync_program``."""
    assert overlap in OVERLAP_MODES, overlap
    assert microbatches >= 1, microbatches
    S, M = n_stages, microbatches
    mesh = stage_data_mesh(S, pc.n, data_axis=pc.axis_name,
                           stage_axis=STAGE_AXIS, devices=devices)
    stage_map = stage_partition(api, S)
    sched = derive_1f1b(S, M)
    axis = pc.axis_name
    per = stage_map[0][1] - stage_map[0][0]

    spec = api.param_spec()
    local_spec = dict(spec)
    local_spec["blocks"] = jax.tree_util.tree_map(
        lambda l: jax.ShapeDtypeStruct((per, *l.shape[1:]), l.dtype),
        spec["blocks"])
    layout = make_layout(local_spec, bucket_elems=bucket_elems)

    param_ps = _spec_tree(spec, P(), P(STAGE_AXIS))
    opt_ps = OptState(step=P(), mu=param_ps, nu=param_ps)
    fperm = [(s, s + 1) for s in range(S - 1)]
    bperm = [(s, s - 1) for s in range(1, S)]
    inv_M = 1.0 / M

    def worker(params, opt_state, batch, alive):
        if stacked:
            batch = jax.tree_util.tree_map(lambda x: x[0], batch)
        a = alive[0]
        sidx = lax.axis_index(STAGE_AXIS)
        is_first = sidx == 0
        is_last = sidx == S - 1
        blocks = params["blocks"]                    # local (per, ...) slice
        io = {k: v for k, v in params.items() if k != "blocks"}
        tok_s, tgt_s = (batch[k].reshape(M, batch[k].shape[0] // M,
                                         *batch[k].shape[1:])
                        for k in ("tokens", "targets"))

        def local_fwd(blocks, io, recv, tok):
            # the stage input: the embedded microbatch at stage 0, the
            # ppermuted predecessor activation elsewhere (the `where`
            # also routes the embed gradient to stage 0 only)
            h0 = api.embed_fn(io, tok)
            h_in = jnp.where(is_first, h0, recv.astype(h0.dtype))
            return api.stage_fn(io, blocks, h_in, remat=remat)

        def local_obj(blocks, io, recv, tok, tgt):
            h_out, aux = local_fwd(blocks, io, recv, tok)
            logits = api.head_fn(io, h_out)
            xent = api.loss_from_logits(logits, tgt)
            return h_out, xent, aux

        zero_h = jnp.zeros_like(api.embed_fn(io, tok_s[0]))
        # parked-activation RING: the wave-synchronous 1F1B in-flight
        # bound is min(M, 2(S-1-s)+1) per stage (schedule.check()), so
        # the stage-0 bound R suffices everywhere and live microbatch
        # indices are consecutive — modular indexing is collision-free.
        # This is what makes the compiled program hold O(S) activations
        # instead of GPipe's O(M).
        R = min(M, 2 * (S - 1) + 1)
        acts = jnp.zeros((R, *zero_h.shape), zero_h.dtype)
        fwd_reg = zero_h
        bwd_reg = zero_h
        f32z = lambda t: jax.tree_util.tree_map(
            lambda l: jnp.zeros(l.shape, jnp.float32), t)
        g_blocks = f32z(blocks)
        g_io = f32z(io)
        loss_acc = jnp.zeros((), jnp.float32)
        aux_acc = jnp.zeros((), jnp.float32)

        for kind, w in sched.waves:
            if kind == "F":
                y = (lax.ppermute(fwd_reg, STAGE_AXIS, perm=fperm)
                     if S > 1 else fwd_reg)
                m_i = w - sidx
                active = (m_i >= 0) & (m_i < M)
                mc = jnp.clip(m_i, 0, M - 1)
                h_out, _ = local_fwd(blocks, io, y, tok_s[mc])
                # park the incoming activation for the backward
                # recompute (the wave-synchronous 1F1B in-flight set)
                mcr = mc % R
                acts = acts.at[mcr].set(jnp.where(active, y, acts[mcr]))
                fwd_reg = jnp.where(active, h_out,
                                    jnp.zeros_like(h_out))
            else:
                cot = (lax.ppermute(bwd_reg, STAGE_AXIS, perm=bperm)
                       if S > 1 else bwd_reg)
                m_i = w - (S - 1 - sidx)
                active = (m_i >= 0) & (m_i < M)
                mc = jnp.clip(m_i, 0, M - 1)
                primals, pull = jax.vjp(local_obj, blocks, io,
                                        acts[mc % R], tok_s[mc],
                                        tgt_s[mc])
                _, xent_p, aux_p = primals
                cot_h = jnp.where(is_last, jnp.zeros_like(cot), cot)
                cot_x = jnp.where(is_last, inv_M, 0.0).astype(xent_p.dtype)
                cot_a = jnp.asarray(0.01 * inv_M, aux_p.dtype)
                gb, gio, g_recv, _, _ = pull(
                    (cot_h.astype(zero_h.dtype), cot_x, cot_a))
                gate = active.astype(jnp.float32)
                add = lambda acc, g: acc + gate * g.astype(jnp.float32)
                g_blocks = jax.tree_util.tree_map(add, g_blocks, gb)
                g_io = jax.tree_util.tree_map(add, g_io, gio)
                loss_acc = loss_acc + jnp.where(
                    active & is_last, xent_p.astype(jnp.float32), 0.0)
                aux_acc = aux_acc + jnp.where(
                    active, aux_p.astype(jnp.float32), 0.0)
                bwd_reg = jnp.where(active, g_recv,
                                    jnp.zeros_like(g_recv))

        # cross-stage reductions: the loss materializes at the last
        # stage, replicated-param grads sum their per-stage contributions
        loss = lax.psum(loss_acc, STAGE_AXIS) * inv_M
        aux = lax.psum(aux_acc, STAGE_AXIS) * inv_M
        g_io = jax.tree_util.tree_map(
            lambda g: lax.psum(g, STAGE_AXIS), g_io)
        grads = dict(g_io)
        grads["blocks"] = g_blocks
        grads = jax.tree_util.tree_map(
            lambda g: g * a.astype(g.dtype), grads)

        # ---- data-axis sync: the epoch's collective schedule, per
        # stage row, with the engine's bucket layout over the LOCAL
        # param slice (overlap config identical to the 1-D engine) ----
        if overlap == "pipelined":
            bufs = layout.flatten_groups(grads, a)
            bufs = execute_flat_pipelined(bufs, pc, fused=fused,
                                          interpret=interpret)
            grads, count = layout.unflatten_groups(bufs)
        else:
            flat = execute_flat(layout.flatten(grads, a), pc,
                                fused=fused, interpret=interpret)
            grads, count = layout.unflatten(flat)
        inv = 1.0 / jnp.maximum(count, 1.0)
        grads = jax.tree_util.tree_map(
            lambda g: g * inv.astype(g.dtype), grads)

        # clip on the TRUE global norm: stage-local block slices are
        # disjoint (psum their square sums), replicated grads count once
        sq = lambda t: sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                           for l in jax.tree_util.tree_leaves(t))
        gnorm = jnp.sqrt(lax.psum(sq(grads["blocks"]), STAGE_AXIS)
                         + sq({k: v for k, v in grads.items()
                               if k != "blocks"}))
        new_p, new_o, om = opt.update(grads, opt_state, params,
                                      gnorm=gnorm)
        pm = {"loss": loss * a, "aux": aux * a, "alive": a, **om}
        pm = {k: jnp.asarray(v, jnp.float32).reshape(1)
              for k, v in pm.items()}
        return new_p, new_o, pm

    sm = shard_map(worker, mesh=mesh,
                   in_specs=(param_ps, opt_ps, P(axis), P(axis)),
                   out_specs=(param_ps, opt_ps, P(axis)),
                   check_rep=False)
    jitted = jax.jit(sm)
    named = lambda ps: NamedSharding(mesh, ps)
    is_p = lambda x: isinstance(x, P)
    param_sh = jax.tree_util.tree_map(named, param_ps, is_leaf=is_p)
    opt_sh = OptState(step=named(P()), mu=param_sh, nu=param_sh)
    st = pc.stats()
    meta = {"team": pc.n, "stages": S, "microbatches": M,
            "pipeline_waves": sched.n_waves,
            "sync_rounds": st["rounds"],
            "sync_messages": st["messages"],
            "overlap": int(overlap == "pipelined"),
            "bucket_groups": layout.n_groups}
    key = (pc.keys, pc.kind, pc.seed, pc.p, "pipeline", stage_map,
           overlap, M)
    return PipelineProgram(key=key, pc=pc, mesh=mesh, sched=sched,
                           stage_map=stage_map, layout=layout,
                           jitted=jitted, stacked=stacked,
                           param_sh=param_sh, opt_sh=opt_sh, meta=meta)
