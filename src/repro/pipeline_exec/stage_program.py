"""Compiled pipeline-parallel train programs on the 2-D (stage, data) mesh.

``build_pipeline_program`` lowers the point-to-point dependency graph of
``core/p2p.py`` — chunks SIG toward their successor, WAIT on their
predecessor — into one ``shard_map`` train step over a 2-D mesh:

* the **stage axis** partitions the stacked-blocks scan. With
  ``interleave = v`` each device owns v NON-contiguous chunks of the
  scan (device s holds chunks s, s+S, …, the looping placement), so
  consecutive chunks sit on neighbouring devices and every wave's
  activation/cotangent handoff stays a single ``lax.ppermute`` hop —
  ring perms (±1 mod S) carry the chunk-group wrap, the open chains of
  the v=1 case are unchanged. Waves are emitted in the interleaved 1F1B
  order ``derive_interleaved`` derives from the phase ordering
  (``schedule.py``); the per-wave (chunk group, microbatch) item is
  data (``wave − axis_index`` arithmetic), not control flow, and each
  backward wave recomputes its chunk slice under ``jax.vjp`` from the
  parked incoming activation. Parked activations live in PER-CHUNK ring
  buffers of ``sched.ring_slots`` slots — live microbatch indices per
  chunk are consecutive (schedule ``check()``), so modular indexing is
  collision-free and the program holds O(ring) activations per chunk
  instead of GPipe's O(M).
* the **data axis** runs the elastic epoch's collective schedule
  unchanged: the stage-local grads flatten into the engine's bucket
  layout (derived from the LOCAL param slice — v·per scan rows) and
  sync through ``execute_flat`` / ``execute_flat_pipelined`` — the same
  ppermute rounds, fused Pallas combine, alive-flag count and overlap
  config as the single-axis engine, now per stage row; with
  ``overlap="pipelined"`` the extra backward waves of the interleaved
  schedule are exactly where the early bucket groups' gradsync rounds
  overlap. Replicated-parameter grads (embed/head/shared) are psum'ed
  over the stage axis first, and the AdamW clip norm is computed
  globally across stages, so the update is mathematically identical to
  the single-axis step (asserted to f32 tolerance against the
  ``xla_psum`` baseline program in ``examples/elastic_train.py``
  through grow/shrink churn, for any interleave).

Carried state is DEVICE-MAJOR: with v > 1 the step takes and returns
the stacked-blocks rows (params and both Adam moments) in the chunk
layout the stage shards actually hold — device s's contiguous shard is
its v chunks in group order. Steady-state training therefore performs
ZERO cross-shard layout permutes: the old design re-gathered params,
mu and nu to the canonical layer order inside every step (6 permutes
per step); now the canonical view exists only at the explicit
``bind_state`` / ``readout_state`` boundaries (program bind,
checkpoint save/restore, final readout). The permutation is a pure
row gather — arithmetic-free — so a device-major run read out at any
step is bitwise identical to the old canonical-surface step, and the
layout depends only on (S, v, rows-per-chunk): epoch swaps under
data-axis churn reuse the carried state as-is.

SPMD uniformity: every wave is kind-uniform (all active stages run the
same instruction), so warmup/cooldown idleness is masked compute — the
same wall-clock shape as a real pipeline bubble. Interleaving makes
each wave 1/v of a stage, cutting the fill/drain cost to 2(S-1) thin
waves (bubble fraction (S-1)/(vM+S-1), down from (S-1)/(M+S-1)).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..collective_exec.buckets import make_layout
from ..collective_exec.executor import execute_flat, execute_flat_pipelined
from ..collective_exec.program import OVERLAP_MODES, reduce_worker_metrics
from ..core.collective import PhaserCollective
from ..obs import timeline as obs_timeline
from ..optim import OptState
from ..sharding.policies import stage_data_mesh
from .schedule import PipelineSchedule, derive_interleaved

STAGE_AXIS = "stage"


def stage_partition(api, n_stages: int,
                    interleave: int = 1) -> Tuple[Tuple[int, int], ...]:
    """The chunk map: contiguous [lo, hi) slices of the stacked-blocks
    scan axis, one per CHUNK (``n_stages * interleave`` virtual stages;
    chunk c belongs to device ``c % n_stages``). The scan length
    (layers, or groups for the grouped families) must divide evenly."""
    assert n_stages >= 1 and interleave >= 1, (n_stages, interleave)
    assert api.pipeline_supported(), \
        f"pipeline: family {api.cfg.family!r} keeps the single-axis path"
    n_chunks = n_stages * interleave
    spec = api.param_spec()
    lens = {l.shape[0] for l in jax.tree_util.tree_leaves(spec["blocks"])}
    assert len(lens) == 1, f"ragged scan axis: {lens}"
    scan_len = lens.pop()
    assert scan_len % n_chunks == 0, \
        f"scan length {scan_len} not divisible by {n_chunks} chunks " \
        f"({n_stages} stages x {interleave} interleave)"
    per = scan_len // n_chunks
    return tuple((c * per, (c + 1) * per) for c in range(n_chunks))


def _spec_tree(param_spec, leaf_spec: P, blocks_spec: P):
    """PartitionSpec tree over the param structure: ``blocks`` leaves
    sharded, everything else replicated."""
    return {k: jax.tree_util.tree_map(
        lambda _: blocks_spec if k == "blocks" else leaf_spec, v)
        for k, v in param_spec.items()}


@dataclass
class PipelineProgram:
    """One epoch's compiled 2-D train step. Mirrors ``GradSyncProgram``'s
    surface (``step``/``reduce_metrics``) so the train loop and example
    drive both interchangeably; ``key`` additionally carries the chunk
    map and pipeline config (interleave included)."""

    key: tuple
    pc: PhaserCollective
    mesh: Mesh
    sched: PipelineSchedule
    stage_map: Tuple[Tuple[int, int], ...]
    interleave: int
    layout: Any
    jitted: Callable
    stacked: bool
    param_sh: Any
    opt_sh: Any
    bind_fn: Callable = None          # canonical -> device-major (jitted)
    readout_fn: Callable = None       # device-major -> canonical (jitted)
    meta: Dict[str, int] = field(default_factory=dict)

    @property
    def n(self) -> int:
        return self.pc.n

    @property
    def n_stages(self) -> int:
        return len(self.stage_map) // self.interleave

    def _commit(self, tree, shardings):
        """Re-commit carried state onto this program's 2-D mesh (stage
        slices for blocks, replicated otherwise) — resharding is a no-op
        within an epoch, an explicit device_put across epoch swaps."""
        return jax.tree_util.tree_map(
            lambda x, sh: x if getattr(x, "sharding", None) == sh
            else jax.device_put(x, sh), tree, shardings)

    def step(self, params, opt_state, batch, alive=None):
        """One step over DEVICE-MAJOR carried state (see module doc);
        ``bind_state`` converts canonical state once, the return value
        feeds the next step directly, and ``readout_state`` recovers
        the canonical order at checkpoint/readout boundaries."""
        if alive is None:
            alive = jnp.ones((self.pc.n,), jnp.float32)
        params = self._commit(params, self.param_sh)
        opt_state = self._commit(opt_state, self.opt_sh)
        return self.jitted(params, opt_state, batch, alive)

    def bind_state(self, params, opt_state):
        """Canonical layer order -> this program's device-major chunk
        layout (identity at v == 1). Pay once at program bind/restore;
        every subsequent step carries the returned layout."""
        if self.bind_fn is None:
            return params, opt_state
        return self.bind_fn(params, opt_state)

    def readout_state(self, params, opt_state):
        """Device-major carried state -> canonical layer order, for
        checkpoints, equality checks and final readout. A pure row
        gather: the round-trip is bitwise exact."""
        if self.readout_fn is None:
            return params, opt_state
        return self.readout_fn(params, opt_state)

    def reduce_metrics(self, pm: Dict[str, jax.Array]) -> Dict[str, Any]:
        return reduce_worker_metrics(pm, self.meta)


def build_pipeline_program(api, opt, pc: PhaserCollective, *,
                           n_stages: int,
                           interleave: int = 1,
                           devices: Optional[Sequence] = None,
                           microbatches: int = 1,
                           stacked: bool = False,
                           remat: bool = False,
                           fused: bool = True,
                           interpret: Optional[bool] = None,
                           overlap: str = "eager",
                           bucket_elems: Optional[int] = None,
                           block_groups: Optional[int] = None
                           ) -> PipelineProgram:
    """Compile the epoch's 2-D program: the (interleaved) 1F1B stage
    pipeline on the stage axis interleaved with the epoch's
    gradient-sync schedule on the data axis. ``microbatches`` is the
    pipeline depth M (the batch splits along its leading dim);
    ``interleave`` is the virtual-stage count v per device (M % S == 0
    required for v > 1); ``overlap``/``block_groups`` select the
    data-axis executor exactly as in ``build_gradsync_program``."""
    assert overlap in OVERLAP_MODES, overlap
    assert microbatches >= 1, microbatches
    S, M, v = n_stages, microbatches, interleave
    mesh = stage_data_mesh(S, pc.n, data_axis=pc.axis_name,
                           stage_axis=STAGE_AXIS, devices=devices)
    stage_map = stage_partition(api, S, v)
    sched = derive_interleaved(S, M, v)
    tl = obs_timeline.current()
    if tl is not None:
        # build-time: the schedule's wave/stage occupancy grid (one
        # event per filled slot, gaps = bubble) for the Chrome trace
        tl.extend(obs_timeline.pipeline_wave_events(
            sched, label=f":S{S}M{M}v{v}"))
    axis = pc.axis_name
    per = stage_map[0][1] - stage_map[0][0]
    Vc = S * v

    spec = api.param_spec()
    local_spec = dict(spec)
    local_spec["blocks"] = jax.tree_util.tree_map(
        lambda l: jax.ShapeDtypeStruct((v * per, *l.shape[1:]), l.dtype),
        spec["blocks"])
    layout = make_layout(local_spec, bucket_elems=bucket_elems,
                         block_groups=block_groups or 1)

    param_ps = _spec_tree(spec, P(), P(STAGE_AXIS))
    opt_ps = OptState(step=P(), mu=param_ps, nu=param_ps)
    if v > 1:
        # ring perms: the chunk-group wrap (chunk jS+S-1 -> (j+1)S)
        # lands on device 0, so every wave's handoff is one hop mod S
        fperm = [(s, (s + 1) % S) for s in range(S)]
        bperm = [(s, (s - 1) % S) for s in range(S)]
        # canonical scan rows -> device-major chunk layout: device s's
        # contiguous stage shard holds its v chunks in group order
        chunk_perm = np.concatenate(
            [np.arange(per) + (j * S + s) * per
             for s in range(S) for j in range(v)])
        chunk_inv = np.argsort(chunk_perm)
    else:
        fperm = [(s, s + 1) for s in range(S - 1)]
        bperm = [(s, s - 1) for s in range(1, S)]
    inv_M = 1.0 / M
    R = sched.ring_slots

    def worker(params, opt_state, batch, alive):
        if stacked:
            batch = jax.tree_util.tree_map(lambda x: x[0], batch)
        a = alive[0]
        sidx = lax.axis_index(STAGE_AXIS)
        is_first = sidx == 0
        is_last = sidx == S - 1
        blocks = params["blocks"]               # local (v*per, ...) slice
        io = {k: v_ for k, v_ in params.items() if k != "blocks"}
        tok_s, tgt_s = (batch[k].reshape(M, batch[k].shape[0] // M,
                                         *batch[k].shape[1:])
                        for k in ("tokens", "targets"))

        def chunk_blocks(blocks, j):
            if v == 1:
                return blocks
            return jax.tree_util.tree_map(
                lambda p: lax.dynamic_slice_in_dim(p, j * per, per, 0),
                blocks)

        def local_fwd(blocks, io, recv, tok, j, want_embed):
            # the chunk input: the embedded microbatch at chunk 0, the
            # ppermuted predecessor activation elsewhere (the `where`
            # also routes the embed gradient to chunk 0 only).
            # ``want_embed`` is STATIC per wave: with v > 1, only the
            # waves where device 0's item is chunk group 0 can consume
            # the embedding — the rest skip it (and its vjp) entirely,
            # which is what keeps the thinner interleaved waves cheap.
            ht = recv.astype(zero_h.dtype)
            if want_embed:
                h0 = api.embed_fn(io, tok)
                use_embed = is_first if v == 1 else is_first & (j == 0)
                ht = jnp.where(use_embed, h0, recv.astype(h0.dtype))
            return api.stage_fn(io, chunk_blocks(blocks, j), ht,
                                remat=remat)

        def local_obj(blocks, io, recv, tok, tgt, j, want_embed,
                      want_head):
            h_out, aux = local_fwd(blocks, io, recv, tok, j, want_embed)
            # ``want_head`` is STATIC per wave: only the waves where
            # device S-1's item is the LAST chunk read the loss head —
            # elsewhere the xent cotangent is zero anyway, so skipping
            # the head (and its vjp) computes the identical gradients
            if want_head:
                logits = api.head_fn(io, h_out)
                xent = api.loss_from_logits(logits, tgt)
            else:
                xent = jnp.zeros((), jnp.float32)
            return h_out, xent, aux

        zero_h = jnp.zeros_like(api.embed_fn(io, tok_s[0]))
        # parked-activation RINGS, one per chunk group: live microbatch
        # indices per chunk are consecutive and capped by the schedule's
        # per-chunk in-flight bound (check()), so ``ring_slots`` slots
        # with modular indexing are collision-free. This is what keeps
        # the compiled program at O(ring) activations per chunk instead
        # of GPipe's O(M).
        acts = jnp.zeros((v, R, *zero_h.shape), zero_h.dtype)
        fwd_reg = zero_h
        bwd_reg = zero_h
        f32z = lambda t: jax.tree_util.tree_map(
            lambda l: jnp.zeros(l.shape, jnp.float32), t)
        g_blocks = f32z(blocks)
        g_io = f32z(io)
        loss_acc = jnp.zeros((), jnp.float32)
        aux_acc = jnp.zeros((), jnp.float32)

        for kind, w in sched.waves:
            if kind == "F":
                y = (lax.ppermute(fwd_reg, STAGE_AXIS, perm=fperm)
                     if S > 1 else fwd_reg)
                r = w - sidx
                active = (r >= 0) & (r < v * M)
                rc = jnp.clip(r, 0, v * M - 1)
                j = (rc // S) % v
                m = (rc // Vc) * S + rc % S
                # static: only device 0 consumes the embedding, and
                # only in the waves where ITS item is chunk group 0
                we = (0 <= w < v * M) and (w // S) % v == 0
                h_out, _ = local_fwd(blocks, io, y, tok_s[m], j, we)
                # park the incoming activation for the backward
                # recompute (this chunk's 1F1B in-flight set)
                mr = m % R
                acts = acts.at[j, mr].set(jnp.where(active, y,
                                                    acts[j, mr]))
                fwd_reg = jnp.where(active, h_out,
                                    jnp.zeros_like(h_out))
            else:
                cot = (lax.ppermute(bwd_reg, STAGE_AXIS, perm=bperm)
                       if S > 1 else bwd_reg)
                r = w - (S - 1 - sidx)
                active = (r >= 0) & (r < v * M)
                rc = jnp.clip(r, 0, v * M - 1)
                j = (v - 1) - (rc // S) % v
                m = (rc // Vc) * S + rc % S
                last_chunk = is_last if v == 1 else is_last & (j == v - 1)
                # static per wave: device 0's backward touches the
                # embed grad only when its item is chunk group 0;
                # device S-1 reads the loss head only when its item is
                # the LAST chunk (w is device 0's / S-1's local index)
                r0 = w - (S - 1)
                we = (0 <= r0 < v * M) and \
                    (v - 1) - (r0 // S) % v == 0
                wh = (0 <= w < v * M) and (w // S) % v == 0
                obj = lambda b_, io_, recv, tok, tgt: \
                    local_obj(b_, io_, recv, tok, tgt, j, we, wh)
                primals, pull = jax.vjp(obj, blocks, io,
                                        acts[j, m % R], tok_s[m],
                                        tgt_s[m])
                _, xent_p, aux_p = primals
                cot_h = jnp.where(last_chunk, jnp.zeros_like(cot), cot)
                cot_x = jnp.where(last_chunk, inv_M,
                                  0.0).astype(xent_p.dtype)
                cot_a = jnp.asarray(0.01 * inv_M, aux_p.dtype)
                gb, gio, g_recv, _, _ = pull(
                    (cot_h.astype(zero_h.dtype), cot_x, cot_a))
                gate = active.astype(jnp.float32)
                add = lambda acc, g: acc + gate * g.astype(jnp.float32)
                g_blocks = jax.tree_util.tree_map(add, g_blocks, gb)
                g_io = jax.tree_util.tree_map(add, g_io, gio)
                loss_acc = loss_acc + jnp.where(
                    active & last_chunk, xent_p.astype(jnp.float32), 0.0)
                aux_acc = aux_acc + jnp.where(
                    active, aux_p.astype(jnp.float32), 0.0)
                bwd_reg = jnp.where(active, g_recv,
                                    jnp.zeros_like(g_recv))

        # cross-stage reductions: the loss materializes at the last
        # chunk, replicated-param grads sum their per-stage contributions
        loss = lax.psum(loss_acc, STAGE_AXIS) * inv_M
        aux = lax.psum(aux_acc, STAGE_AXIS) * inv_M
        g_io = jax.tree_util.tree_map(
            lambda g: lax.psum(g, STAGE_AXIS), g_io)
        grads = dict(g_io)
        grads["blocks"] = g_blocks
        grads = jax.tree_util.tree_map(
            lambda g: g * a.astype(g.dtype), grads)

        # ---- data-axis sync: the epoch's collective schedule, per
        # stage row, with the engine's bucket layout over the LOCAL
        # param slice (overlap config identical to the 1-D engine) ----
        if overlap == "pipelined":
            bufs = layout.flatten_groups(grads, a)
            bufs = execute_flat_pipelined(bufs, pc, fused=fused,
                                          interpret=interpret)
            grads, count = layout.unflatten_groups(bufs)
        else:
            flat = execute_flat(layout.flatten(grads, a), pc,
                                fused=fused, interpret=interpret)
            grads, count = layout.unflatten(flat)
        inv = 1.0 / jnp.maximum(count, 1.0)
        grads = jax.tree_util.tree_map(
            lambda g: g * inv.astype(g.dtype), grads)

        # clip on the TRUE global norm: stage-local block slices are
        # disjoint (psum their square sums), replicated grads count once
        sq = lambda t: sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                           for l in jax.tree_util.tree_leaves(t))
        gnorm = jnp.sqrt(lax.psum(sq(grads["blocks"]), STAGE_AXIS)
                         + sq({k: g for k, g in grads.items()
                               if k != "blocks"}))
        new_p, new_o, om = opt.update(grads, opt_state, params,
                                      gnorm=gnorm)
        pm = {"loss": loss * a, "aux": aux * a, "alive": a, **om}
        pm = {k: jnp.asarray(val, jnp.float32).reshape(1)
              for k, val in pm.items()}
        return new_p, new_o, pm

    sm = shard_map(worker, mesh=mesh,
                   in_specs=(param_ps, opt_ps, P(axis), P(axis)),
                   out_specs=(param_ps, opt_ps, P(axis)),
                   check_rep=False)

    # the step is compiled over the device-major layout directly —
    # carried state stays put between steps, so the interleaved program
    # has NO per-step layout permutes (the old canonical-surface design
    # re-gathered params + both Adam moments in and out every step).
    # The canonical view moves behind explicit jitted converters, paid
    # only at bind / checkpoint / readout boundaries.
    jitted = jax.jit(sm)
    bind_fn = readout_fn = None
    if v > 1:
        to_dev = jnp.asarray(chunk_perm)
        to_can = jnp.asarray(chunk_inv)

        def permute_blocks(tree, idx):
            blk = jax.tree_util.tree_map(
                lambda p: jnp.take(p, idx, axis=0), tree["blocks"])
            return {**tree, "blocks": blk}

        def permute_state(params, opt_state, idx):
            return (permute_blocks(params, idx),
                    OptState(step=opt_state.step,
                             mu=permute_blocks(opt_state.mu, idx),
                             nu=permute_blocks(opt_state.nu, idx)))

        bind_fn = jax.jit(lambda p, o: permute_state(p, o, to_dev))
        readout_fn = jax.jit(lambda p, o: permute_state(p, o, to_can))
    named = lambda ps: NamedSharding(mesh, ps)
    is_p = lambda x: isinstance(x, P)
    param_sh = jax.tree_util.tree_map(named, param_ps, is_leaf=is_p)
    opt_sh = OptState(step=named(P()), mu=param_sh, nu=param_sh)
    st = pc.stats()
    meta = {"team": pc.n, "stages": S, "microbatches": M,
            "interleave": v,
            "pipeline_waves": sched.n_waves,
            "ring_slots": R,
            "sync_rounds": st["rounds"],
            "sync_messages": st["messages"],
            "overlap": int(overlap == "pipelined"),
            "bucket_groups": layout.n_groups}
    key = (pc.keys, pc.kind, pc.seed, pc.p, "pipeline", stage_map,
           overlap, M, v)
    return PipelineProgram(key=key, pc=pc, mesh=mesh, sched=sched,
                           stage_map=stage_map, interleave=v,
                           layout=layout, jitted=jitted, stacked=stacked,
                           param_sh=param_sh, opt_sh=opt_sh,
                           bind_fn=bind_fn, readout_fn=readout_fn,
                           meta=meta)
