"""Pipeline-parallel execution on point-to-point phaser graphs
(DESIGN.md §6).

The point-to-point half of the paper's claim, lowered to the data plane:
pipeline chunks register SIG toward their successor and WAIT on their
predecessor (``core/p2p.py``), the wave-synchronous 1F1B schedule — and
its interleaved virtual-stage generalization (``interleave = v`` chunks
per device, bubble fraction (S-1)/(vM+S-1)) — is derived from and
verified against that phaser graph's phase ordering (``schedule``), and
``stage_program`` compiles it into one ``shard_map`` train step over a
2-D (stage, data) mesh where stage-axis ``lax.ppermute``
activation/cotangent handoffs interleave with the elastic epoch's
collective gradient-sync rounds on the data axis.
"""
from .schedule import (PipelineSchedule, derive_1f1b, derive_interleaved,
                       pipeline_edges, verify_phase_order)
from .stage_program import (STAGE_AXIS, PipelineProgram,
                            build_pipeline_program, stage_partition)

__all__ = ["PipelineSchedule", "derive_1f1b", "derive_interleaved",
           "pipeline_edges", "verify_phase_order", "STAGE_AXIS",
           "PipelineProgram", "build_pipeline_program",
           "stage_partition"]
