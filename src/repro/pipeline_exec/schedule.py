"""1F1B and interleaved pipeline schedules derived from the
point-to-point phase ordering.

A pipeline of S stages over M microbatches is the phaser graph of
``core/p2p.py``: forward edge phasers (c, c+1) carry activations (chunk
c SIG, chunk c+1 WAIT), backward edge phasers (c+1, c) carry cotangents.
``F(c, m)`` signals fwd phase m after waiting on fwd phase m of the
predecessor edge; ``B(c, m)`` signals bwd phase m after waiting on bwd
phase m of the successor edge (and, at the last chunk, on its own
``F`` — a local dependency, no phaser needed).

The schedule is organized in **waves** — global ticks where every active
stage executes the same instruction kind (the SPMD-uniform shape the
compiled program needs). With ``interleave = v`` **virtual stages per
device** (Megatron-style looping placement), the model splits into
``S*v`` chunks and device s owns the NON-contiguous chunks
``s, s+S, ..., s+(v-1)S`` — consecutive chunks always sit on
neighbouring devices (mod S), so per-wave handoffs stay single
``ppermute`` hops. Device s's local F index ``r = f - s`` maps to

* chunk group ``j = (r // S) % v`` (breadth-first chunk rotation:
  S microbatches flow through chunk group j before the device rotates
  to group j+1 — the rotation period S is what lets microbatch 0 reach
  chunk group j+1 exactly when the device finishes group j's round),
* microbatch ``m = (r // (S*v))*S + r % S``  (requires ``M % S == 0``
  for v > 1, as in Megatron's interleaved schedule),

and the backward mirrors it with ``j`` reversed. The wave order is the
same 1F1B interleaving as the plain schedule — ``S*v`` warmup forward
waves, then strict B/F alternation, then the backward tail — because
``B_0`` (last chunk, microbatch 0) needs exactly ``F_{S*v-1}``.

**Why interleave**: the plain 1F1B bubble is 2(S-1) waves of FULL-stage
compute; interleaved waves each do 1/v of a stage, so the fill/drain
cost drops to 2(S-1) *thin* waves — the bubble fraction falls from
``(S-1)/(M+S-1)`` to ``(S-1)/(vM+S-1)``, a factor-v cut at small M (the
dominant regime in BENCH_pipeline.json). The price is in-flight
activations: chunk (s, j) parks at most
``min(M, 2(S-1-s)+1 + (v-1-j)*S)`` live forward activations (proved in
``check()``; for v=1 this is exactly the wave-synchronous bound
``min(M, 2(S-1-s)+1)`` — each individual chunk stays under the
*expanded-graph* wave-synchronous bound ``min(vM, 2(Sv-1-c)+1)``, which
is what "tighter per-chunk in-flight" means here), and the live
microbatch indices per chunk are CONSECUTIVE, so the compiled program's
per-chunk parked-activation rings stay collision-free under modular
indexing (``ring_slots``).

``derive_interleaved`` constructs the schedule (``derive_1f1b`` is the
v=1 case); ``check()`` proves dependency validity, the steady-state F/B
alternation and the per-chunk in-flight bounds; ``as_program()``
linearizes the waves into the p2p instruction stream over the S·v-node
chunk graph; ``verify_phase_order`` drives that stream through the REAL
protocol actors and asserts the observed release order equals the host
counter oracle (``simulate_program``) — the per-epoch proof the example
and tests run (arXiv:1606.05937's notion of a legal phaser execution:
any linearization the counter oracle admits).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

from ..core.p2p import Edge, Op, PipelinePhaserGraph, simulate_program


def pipeline_edges(n_chunks: int) -> Tuple[Edge, ...]:
    """Forward activation edges then backward cotangent edges over the
    chunk graph (``n_chunks = S * interleave`` virtual stages)."""
    fwd = [(c, c + 1) for c in range(n_chunks - 1)]
    bwd = [(c + 1, c) for c in range(n_chunks - 1)]
    return tuple(fwd + bwd)


@dataclass(frozen=True)
class PipelineSchedule:
    """A wave-ordered (possibly interleaved) 1F1B schedule. ``waves[t]``
    is ``("F", f)`` or ``("B", b)`` — at tick t every stage s executes
    that wave's instruction for its own (chunk group, microbatch) item
    (or idles outside its range)."""

    n_stages: int
    n_microbatches: int
    waves: Tuple[Tuple[str, int], ...]
    interleave: int = 1

    @property
    def n_waves(self) -> int:
        return len(self.waves)

    @property
    def n_chunks(self) -> int:
        return self.n_stages * self.interleave

    def chunk_of(self, stage: int, group: int) -> int:
        """Virtual-stage (chunk) index of device ``stage``'s chunk
        group ``group`` — the looping placement c = group*S + stage."""
        return group * self.n_stages + stage

    # ------------------------------------------------------------ items
    def _item(self, r: int) -> Optional[Tuple[int, int]]:
        """Local instruction index r -> (chunk group, microbatch)."""
        S, M, v = self.n_stages, self.n_microbatches, self.interleave
        if not 0 <= r < v * M:
            return None
        j = (r // S) % v
        m = (r // (S * v)) * S + r % S
        return j, m

    def fwd_item(self, wave: int, stage: int) -> Optional[Tuple[int, int]]:
        return self._item(wave - stage)

    def bwd_item(self, wave: int, stage: int) -> Optional[Tuple[int, int]]:
        it = self._item(wave - (self.n_stages - 1 - stage))
        if it is None:
            return None
        j, m = it
        return self.interleave - 1 - j, m

    def fwd_mb(self, wave: int, stage: int) -> Optional[int]:
        """v=1 compatibility view: the wave's microbatch index."""
        assert self.interleave == 1
        it = self.fwd_item(wave, stage)
        return None if it is None else it[1]

    def bwd_mb(self, wave: int, stage: int) -> Optional[int]:
        assert self.interleave == 1
        it = self.bwd_item(wave, stage)
        return None if it is None else it[1]

    def chunk_stream(self, stage: int) -> List[Tuple[str, int, int]]:
        """The stage's own instruction sequence in wave order:
        (kind, chunk group, microbatch) triples."""
        out = []
        for kind, w in self.waves:
            it = (self.fwd_item(w, stage) if kind == "F"
                  else self.bwd_item(w, stage))
            if it is not None:
                out.append((kind, it[0], it[1]))
        return out

    def stage_stream(self, stage: int) -> List[Tuple[str, int]]:
        """v=1 view: the stage's (kind, microbatch) sequence."""
        assert self.interleave == 1
        return [(k, m) for k, _, m in self.chunk_stream(stage)]

    # --------------------------------------------------------- analysis
    def chunk_inflight(self) -> Dict[Tuple[int, int], Tuple[int, int]]:
        """(stage, chunk group) -> (peak live forward activations,
        max live microbatch-index span). The span bounds the ring size a
        compiled program needs for that chunk's parked activations."""
        out = {}
        for s in range(self.n_stages):
            live: Dict[int, set] = {j: set()
                                    for j in range(self.interleave)}
            peak = {j: 0 for j in range(self.interleave)}
            span = {j: 0 for j in range(self.interleave)}
            for kind, j, m in self.chunk_stream(s):
                if kind == "F":
                    live[j].add(m)
                    peak[j] = max(peak[j], len(live[j]))
                    span[j] = max(span[j],
                                  max(live[j]) - min(live[j]) + 1)
                else:
                    live[j].discard(m)
            for j in range(self.interleave):
                out[(s, j)] = (peak[j], span[j])
        return out

    def inflight_bound(self, stage: int, group: int) -> int:
        """The per-chunk in-flight cap ``check()`` proves:
        min(M, 2(S-1-s)+1 + (v-1-j)S). For v=1 this is the
        wave-synchronous 1F1B bound; every chunk stays under the
        expanded-graph wave-synchronous cap min(vM, 2(Sv-1-c)+1)."""
        S, v = self.n_stages, self.interleave
        return min(self.n_microbatches,
                   2 * (S - 1 - stage) + 1 + (v - 1 - group) * S)

    @property
    def ring_slots(self) -> int:
        """Parked-activation ring size per chunk: the max live
        microbatch span over every (stage, chunk group) — live indices
        per chunk are consecutive, so modular indexing into a ring of
        this size is collision-free (asserted in ``check()``)."""
        return max((sp for _, sp in self.chunk_inflight().values()),
                   default=1)

    def bubble_fraction(self) -> float:
        """Idle fraction of the wave schedule: (S-1)/(vM+S-1) — the
        fill/drain waves over the total. Interleaving divides the plain
        1F1B fraction (S-1)/(M+S-1) by ~v at small M because each
        interleaved wave computes 1/v of a stage."""
        S, M, v = self.n_stages, self.n_microbatches, self.interleave
        return (S - 1) / (v * M + S - 1)

    # ------------------------------------------------------------ validity
    def check(self) -> None:
        S, M, v = self.n_stages, self.n_microbatches, self.interleave
        assert v == 1 or M % S == 0, \
            f"interleave={v} needs M % S == 0, got M={M}, S={S}"
        nf = v * M + S - 1
        assert sorted(w for k, w in self.waves if k == "F") == list(range(nf))
        assert sorted(w for k, w in self.waves if k == "B") == list(range(nf))
        done: Dict[Tuple[str, int, int], int] = {}
        for t, (kind, w) in enumerate(self.waves):
            for s in range(S):
                if kind == "F":
                    it = self.fwd_item(w, s)
                    if it is None:
                        continue
                    j, m = it
                    c = self.chunk_of(s, j)
                    if c > 0:
                        # activation from the predecessor chunk's F,
                        # strictly earlier wave
                        assert done.get(("F", c - 1, m), t) < t, (t, c, m)
                    done[("F", c, m)] = t
                else:
                    it = self.bwd_item(w, s)
                    if it is None:
                        continue
                    j, m = it
                    c = self.chunk_of(s, j)
                    # own forward must have run (vjp recompute input)
                    assert done.get(("F", c, m), t) < t, (t, c, m)
                    if c < self.n_chunks - 1:
                        # cotangent from the successor chunk's B
                        assert done.get(("B", c + 1, m), t) < t, (t, c, m)
                    done[("B", c, m)] = t
        assert len(done) == 2 * self.n_chunks * M
        # per-chunk in-flight bound + ring contiguity + steady-state F/B
        # alternation: after its first backward a stage never runs two
        # forwards back to back (the 1F1B property); the warmup forward
        # run is capped by the total in-flight bound S(v-1)+2(S-1-s)+1.
        inflight = self.chunk_inflight()
        for s in range(S):
            for j in range(v):
                peak, span = inflight[(s, j)]
                bound = self.inflight_bound(s, j)
                assert peak <= bound, (s, j, peak, bound)
                # live microbatches stay consecutive: the ring of
                # ``ring_slots`` is collision-free under m % ring
                assert span <= bound, (s, j, span, bound)
            run = 0
            seen_b = False
            warm = min(v * M, S * (v - 1) + 2 * (S - 1 - s) + 1)
            for kind, j, m in self.chunk_stream(s):
                if kind == "F":
                    run += 1
                    assert run <= (1 if seen_b else warm), (s, run)
                else:
                    run = 0
                    seen_b = True

    # ----------------------------------------------------- p2p linearization
    def as_program(self) -> List[Op]:
        """The wave schedule as a p2p instruction stream over the chunk
        graph: each F/B wave emits its chunks' wait/signal ops in
        dependency order (ascending chunk for F — a chunk's input was
        signaled a wave earlier; descending for B)."""
        Vc = self.n_chunks
        ops: List[Op] = []
        for kind, w in self.waves:
            items = []                   # (chunk, microbatch) this wave
            for s in range(self.n_stages):
                it = (self.fwd_item(w, s) if kind == "F"
                      else self.bwd_item(w, s))
                if it is not None:
                    items.append((self.chunk_of(s, it[0]), it[1]))
            for c, m in sorted(items, reverse=(kind == "B")):
                if kind == "F":
                    if c > 0:
                        ops.append(("wait", (c - 1, c), m))
                    if c < Vc - 1:
                        ops.append(("signal", (c, c + 1)))
                else:
                    if c < Vc - 1:
                        ops.append(("wait", (c + 1, c), m))
                    if c > 0:
                        ops.append(("signal", (c, c - 1)))
        return ops

    def fingerprint(self) -> Tuple:
        return (self.n_stages, self.n_microbatches, self.interleave,
                self.waves)


def derive_interleaved(n_stages: int, n_microbatches: int,
                       interleave: int = 1) -> PipelineSchedule:
    """The interleaved 1F1B wave order: S·v warmup forward waves (the
    first backward — last chunk, microbatch 0 — needs exactly
    F_{Sv-1}), then strict B/F alternation, then the cooldown backward
    tail. For v=1 this is the canonical wave-synchronous 1F1B."""
    S, M, v = n_stages, n_microbatches, interleave
    assert S >= 1 and M >= 1 and v >= 1, (S, M, v)
    assert v == 1 or M % S == 0, \
        f"interleave={v} needs M % S == 0 (chunk rotation period), " \
        f"got M={M}, S={S}"
    nf = v * M + S - 1
    warm = min(S * v, nf)
    waves: List[Tuple[str, int]] = [("F", f) for f in range(warm)]
    b = 0
    for f in range(warm, nf):
        waves.append(("B", b))
        waves.append(("F", f))
        b += 1
    waves.extend(("B", bb) for bb in range(b, nf))
    sched = PipelineSchedule(S, M, tuple(waves), interleave=v)
    sched.check()
    return sched


def derive_1f1b(n_stages: int, n_microbatches: int) -> PipelineSchedule:
    """The canonical non-interleaved 1F1B wave order: S warmup forward
    waves, then strict B/F alternation, then the cooldown backward tail."""
    return derive_interleaved(n_stages, n_microbatches, 1)


def verify_phase_order(sched: PipelineSchedule, *,
                       seed: int = 0) -> Dict[str, int]:
    """Prove the schedule against the point-to-point protocol: drive its
    instruction stream through real phaser actors (one per chunk-graph
    edge, SIG/WAIT modes) and assert (1) every wait is already satisfied
    when reached, (2) the observed global release order equals the host
    counter oracle's, and (3) each edge phaser's converged SCSL/SNSL
    match the mode-filtered skip-list oracle. Returns protocol stats."""
    if sched.n_chunks == 1:
        return {"edges": 0, "messages": 0, "releases": 0}
    edges = pipeline_edges(sched.n_chunks)
    prog = sched.as_program()
    g = PipelinePhaserGraph(sched.n_chunks, edges, seed=seed)
    got = g.run_program(prog)
    want = simulate_program(edges, prog)
    assert [(e.edge, e.phase) for e in got] == \
        [(e.edge, e.phase) for e in want], "release order diverged"
    g.verify_topologies()
    return g.stats()
