"""1F1B pipeline schedules derived from the point-to-point phase ordering.

A pipeline of S stages over M microbatches is the phaser graph of
``core/p2p.py``: forward edge phasers (s, s+1) carry activations (stage
s SIG, stage s+1 WAIT), backward edge phasers (s+1, s) carry cotangents.
``F(s, m)`` signals fwd phase m after waiting on fwd phase m of the
predecessor edge; ``B(s, m)`` signals bwd phase m after waiting on bwd
phase m of the successor edge (and, at the last stage, on its own
``F(S-1, m)`` — a local dependency, no phaser needed).

The schedule is organized in **waves** — global ticks where every active
stage executes the same instruction kind (the SPMD-uniform shape the
compiled program needs):

* forward wave ``f``:  stage s runs ``F(s, m=f-s)``       if 0 <= m < M
* backward wave ``b``: stage s runs ``B(s, m=b-(S-1-s))`` if 0 <= m < M

The **wave-synchronous 1F1B** order is the interleaving
``F_0 .. F_{S-1}, B_0, F_S, B_1, F_{S+1}, ..., B_{last}``: after the
warmup every stage alternates one backward with one forward (the
defining 1F1B property — GPipe would run all forwards first, holding M
activations everywhere). The alternation is tight for kind-uniform
waves: ``B_b`` needs ``F_{S-1+b}`` (its last-stage microbatch's own
forward), which skews early stages' first backward by one wave per hop,
so stage s holds at most ``min(M, 2(S-1-s)+1)`` live forward
activations (vs the asynchronous-tick bound S-s; last stage exactly 1).
``derive_1f1b`` constructs it; ``check()`` proves dependency validity,
the steady-state F/B alternation, and the in-flight bound;
``as_program()`` linearizes the waves into the p2p instruction stream;
``verify_phase_order`` drives that stream through the REAL protocol
actors and asserts the observed release order equals the host counter
oracle (``simulate_program``) — the per-epoch proof the example and
tests run.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

from ..core.p2p import Edge, Op, PipelinePhaserGraph, simulate_program


def pipeline_edges(n_stages: int) -> Tuple[Edge, ...]:
    """Forward activation edges then backward cotangent edges."""
    fwd = [(s, s + 1) for s in range(n_stages - 1)]
    bwd = [(s + 1, s) for s in range(n_stages - 1)]
    return tuple(fwd + bwd)


@dataclass(frozen=True)
class PipelineSchedule:
    """A wave-ordered 1F1B schedule. ``waves[t]`` is ``("F", f)`` or
    ``("B", b)`` — at tick t every stage s executes that wave's
    instruction for its own microbatch (or idles outside [0, M))."""

    n_stages: int
    n_microbatches: int
    waves: Tuple[Tuple[str, int], ...]

    @property
    def n_waves(self) -> int:
        return len(self.waves)

    def fwd_mb(self, wave: int, stage: int) -> Optional[int]:
        m = wave - stage
        return m if 0 <= m < self.n_microbatches else None

    def bwd_mb(self, wave: int, stage: int) -> Optional[int]:
        m = wave - (self.n_stages - 1 - stage)
        return m if 0 <= m < self.n_microbatches else None

    def stage_stream(self, stage: int) -> List[Tuple[str, int]]:
        """The stage's own instruction sequence in wave order."""
        out = []
        for kind, w in self.waves:
            m = (self.fwd_mb(w, stage) if kind == "F"
                 else self.bwd_mb(w, stage))
            if m is not None:
                out.append((kind, m))
        return out

    # ------------------------------------------------------------ validity
    def check(self) -> None:
        S, M = self.n_stages, self.n_microbatches
        nf = M + S - 1
        assert sorted(w for k, w in self.waves if k == "F") == list(range(nf))
        assert sorted(w for k, w in self.waves if k == "B") == list(range(nf))
        done: Dict[Tuple[str, int, int], int] = {}
        for t, (kind, w) in enumerate(self.waves):
            for s in range(S):
                if kind == "F":
                    m = self.fwd_mb(w, s)
                    if m is None:
                        continue
                    if s > 0:
                        # activation from the predecessor's F, earlier wave
                        assert done.get(("F", s - 1, m), t) < t, (t, s, m)
                    done[("F", s, m)] = t
                else:
                    m = self.bwd_mb(w, s)
                    if m is None:
                        continue
                    # own forward must have run (vjp recompute input)
                    assert done.get(("F", s, m), t) < t, (t, s, m)
                    if s < S - 1:
                        # cotangent from the successor's B, earlier wave
                        assert done.get(("B", s + 1, m), t) < t, (t, s, m)
                    done[("B", s, m)] = t
        # in-flight bound + steady-state alternation: stage s holds at
        # most min(M, 2(S-1-s)+1) live forward activations (the
        # wave-synchronous 1F1B memory cap; GPipe would hold M at every
        # stage), and between any two backwards there is at most one
        # forward — the 1F1B property
        for s in range(S):
            live = peak = run = 0
            seen_b = False
            for kind, m in self.stage_stream(s):
                if kind == "F":
                    live += 1
                    run += 1
                    assert run <= (1 if seen_b
                                   else 2 * (S - 1 - s) + 1), (s, run)
                else:
                    live -= 1
                    run = 0
                    seen_b = True
                peak = max(peak, live)
            assert live == 0
            assert peak <= min(M, 2 * (S - 1 - s) + 1), (s, peak)

    # ----------------------------------------------------- p2p linearization
    def as_program(self) -> List[Op]:
        """The wave schedule as a p2p instruction stream: each F/B wave
        emits its stages' wait/signal ops in dependency order (ascending
        stage for F — a stage's input was signaled a wave earlier;
        descending for B)."""
        S, M = self.n_stages, self.n_microbatches
        ops: List[Op] = []
        for kind, w in self.waves:
            stages = range(S) if kind == "F" else reversed(range(S))
            for s in stages:
                if kind == "F":
                    m = self.fwd_mb(w, s)
                    if m is None:
                        continue
                    if s > 0:
                        ops.append(("wait", (s - 1, s), m))
                    if s < S - 1:
                        ops.append(("signal", (s, s + 1)))
                else:
                    m = self.bwd_mb(w, s)
                    if m is None:
                        continue
                    if s < S - 1:
                        ops.append(("wait", (s + 1, s), m))
                    if s > 0:
                        ops.append(("signal", (s, s - 1)))
        return ops

    def fingerprint(self) -> Tuple:
        return (self.n_stages, self.n_microbatches, self.waves)


def derive_1f1b(n_stages: int, n_microbatches: int) -> PipelineSchedule:
    """The canonical non-interleaved 1F1B wave order: S warmup forward
    waves, then strict B/F alternation, then the cooldown backward tail."""
    S, M = n_stages, n_microbatches
    assert S >= 1 and M >= 1, (S, M)
    nf = M + S - 1
    waves: List[Tuple[str, int]] = [("F", f) for f in range(min(S, nf))]
    b = 0
    for f in range(S, nf):
        waves.append(("B", b))
        waves.append(("F", f))
        b += 1
    waves.extend(("B", bb) for bb in range(b, nf))
    sched = PipelineSchedule(S, M, tuple(waves))
    sched.check()
    return sched


def verify_phase_order(sched: PipelineSchedule, *,
                       seed: int = 0) -> Dict[str, int]:
    """Prove the schedule against the point-to-point protocol: drive its
    instruction stream through real phaser actors (one per edge, SIG/WAIT
    modes) and assert (1) every wait is already satisfied when reached,
    (2) the observed global release order equals the host counter
    oracle's, and (3) each edge phaser's converged SCSL/SNSL match the
    mode-filtered skip-list oracle. Returns protocol stats."""
    if sched.n_stages == 1:
        return {"edges": 0, "messages": 0, "releases": 0}
    edges = pipeline_edges(sched.n_stages)
    prog = sched.as_program()
    g = PipelinePhaserGraph(sched.n_stages, edges, seed=seed)
    got = g.run_program(prog)
    want = simulate_program(edges, prog)
    assert [(e.edge, e.phase) for e in got] == \
        [(e.edge, e.phase) for e in want], "release order diverged"
    g.verify_topologies()
    return g.stats()
