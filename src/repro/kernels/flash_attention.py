"""Flash attention (training/prefill) Pallas TPU kernel.

Blockwise causal GQA attention with online softmax. TPU adaptation: the
(block_q x block_k) score tile lives in VMEM, MXU-shaped (128x128 default);
the KV loop is the innermost grid dim with running (acc, m, l) carried in
VMEM scratch across its iterations (the sequential last grid dim is the
TPU-idiomatic replacement for the GPU kernel's warp-level softmax
reductions).

Layout: q (B, H, Sq, hd); k/v (B, Kh, Sk, hd); GQA mapping h -> h*Kh//H.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                 block_q: int, block_k: int, causal: bool,
                 sliding_window, sm_scale: float, kv_blocks: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)            # (bq, hd)
    k = k_ref[0, 0].astype(jnp.float32)            # (bk, hd)
    v = v_ref[0, 0].astype(jnp.float32)            # (bk, hd)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    s = s * sm_scale                               # (bq, bk)

    qpos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 0)
    kpos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 1)
    mask = jnp.ones_like(s, dtype=jnp.bool_)
    if causal:
        mask = mask & (kpos <= qpos)
    if sliding_window is not None:
        mask = mask & (qpos - kpos < sliding_window)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    l_prev = l_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_ref[...] = l_prev * alpha + jnp.sum(p, axis=1)
    m_ref[...] = m_new
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(ki == kv_blocks - 1)
    def _final():
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l_ref[...], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, sliding_window=None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False):
    """q: (B,H,Sq,hd); k/v: (B,Kh,Sk,hd) -> (B,H,Sq,hd)."""
    B, H, Sq, hd = q.shape
    Kh, Sk = k.shape[1], k.shape[2]
    assert H % Kh == 0, (H, Kh)
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0 and Sk % block_k == 0, (Sq, Sk, block_q,
                                                     block_k)
    kv_blocks = Sk // block_k
    grid = (B, H, Sq // block_q, kv_blocks)
    sm_scale = 1.0 / math.sqrt(hd)
    g = H // Kh

    kernel = functools.partial(
        _attn_kernel, block_q=block_q, block_k=block_k, causal=causal,
        sliding_window=sliding_window, sm_scale=sm_scale,
        kv_blocks=kv_blocks)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd),
                         lambda b, h, q_, k_: (b, h, q_, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, q_, k_: (b, h // g, k_, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, q_, k_: (b, h // g, k_, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd),
                               lambda b, h, q_, k_: (b, h, q_, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, hd), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
