"""Pure-jnp oracles for the Pallas kernels (exact, non-blocked math).

Each oracle computes the mathematically-direct form (full softmax /
sequential recurrence), so kernel tests verify the blockwise algorithms
against ground truth rather than against another blocked implementation.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, *, causal: bool = True, sliding_window=None):
    """q: (B,H,Sq,hd); k/v: (B,Kh,Sk,hd) -> (B,H,Sq,hd)."""
    B, H, Sq, hd = q.shape
    Kh, Sk = k.shape[1], k.shape[2]
    g = H // Kh
    kr = jnp.repeat(k, g, axis=1)
    vr = jnp.repeat(v, g, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kr.astype(jnp.float32)) / math.sqrt(hd)
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask = mask & (kpos <= qpos)
    if sliding_window is not None:
        mask = mask & (qpos - kpos < sliding_window)
    s = jnp.where(mask, s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", w,
                      vr.astype(jnp.float32)).astype(q.dtype)


def decode_ref(q, k, v, valid):
    """q: (B,H,hd); k/v: (B,Kh,W,hd); valid: (B,W) -> (B,H,hd)."""
    B, H, hd = q.shape
    Kh = k.shape[1]
    g = H // Kh
    kr = jnp.repeat(k, g, axis=1)
    vr = jnp.repeat(v, g, axis=1)
    s = jnp.einsum("bhd,bhwd->bhw", q.astype(jnp.float32),
                   kr.astype(jnp.float32)) / math.sqrt(hd)
    s = jnp.where(valid[:, None, :] > 0, s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhw,bhwd->bhd", w,
                      vr.astype(jnp.float32)).astype(q.dtype)


def mamba2_ref(x, Bmat, Cmat, a, dt):
    """Exact sequential recurrence. x: (B,NH,S,P); B/C: (B,S,N);
    a/dt: (B,NH,S) -> y like x."""
    B, NH, S, P = x.shape
    N = Bmat.shape[-1]

    def step(h, t):
        x_t, B_t, C_t, a_t, dt_t = t
        h = h * a_t[:, :, None, None] + jnp.einsum(
            "bhp,bn,bh->bhpn", x_t.astype(jnp.float32),
            B_t.astype(jnp.float32), dt_t)
        y = jnp.einsum("bn,bhpn->bhp", C_t.astype(jnp.float32), h)
        return h, y

    h0 = jnp.zeros((B, NH, P, N), jnp.float32)
    xs = (x.transpose(2, 0, 1, 3), Bmat.transpose(1, 0, 2),
          Cmat.transpose(1, 0, 2), a.transpose(2, 0, 1),
          dt.transpose(2, 0, 1))
    _, ys = jax.lax.scan(step, h0, xs)
    return ys.transpose(1, 2, 0, 3).astype(x.dtype)


def mlstm_ref(q, k, v, logi, logf):
    """Exact sequential mLSTM recurrence (log-space stabilized).
    q/k/v: (B,NH,S,hd); logi/logf: (B,NH,S) -> y."""
    B, NH, S, hd = q.shape

    def step(carry, t):
        C, n, m = carry
        q_t, k_t, v_t, i_t, f_t = t
        q_t = q_t.astype(jnp.float32)
        k_t = k_t.astype(jnp.float32)
        v_t = v_t.astype(jnp.float32)
        m_new = jnp.maximum(f_t + m, i_t)
        C = (jnp.exp(f_t + m - m_new)[..., None, None] * C
             + jnp.exp(i_t - m_new)[..., None, None]
             * jnp.einsum("bhk,bhp->bhkp", k_t, v_t))
        n = (jnp.exp(f_t + m - m_new)[..., None] * n
             + jnp.exp(i_t - m_new)[..., None] * k_t)
        num = jnp.einsum("bhk,bhkp->bhp", q_t, C)
        den = jnp.abs(jnp.einsum("bhk,bhk->bh", q_t, n))
        den = jnp.maximum(den, jnp.exp(-m_new))[..., None]
        return (C, n, m_new), num / den

    C0 = jnp.zeros((B, NH, hd, hd), jnp.float32)
    n0 = jnp.zeros((B, NH, hd), jnp.float32)
    m0 = jnp.full((B, NH), NEG_INF, jnp.float32)
    xs = (q.transpose(2, 0, 1, 3), k.transpose(2, 0, 1, 3),
          v.transpose(2, 0, 1, 3), logi.transpose(2, 0, 1),
          logf.transpose(2, 0, 1))
    _, ys = jax.lax.scan(step, (C0, n0, m0), xs)
    return ys.transpose(1, 2, 0, 3).astype(q.dtype)
