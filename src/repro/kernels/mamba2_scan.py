"""Mamba2 chunked-SSD Pallas TPU kernel.

The GPU reference implements the selective scan with warp-level shuffles;
the TPU-native formulation (DESIGN.md §2) is chunked SSD: the chunk is
a VMEM tile, intra-chunk work is dense (c x c) MXU matmuls, and the
inter-chunk state carry (h: P x N per head) rides VMEM scratch across the
sequential chunk grid dim.

Layout: x (B, NH, S, P); Bmat/Cmat (B, S, N); a/dt (B, NH, S).
Per (batch, head, chunk) grid cell: y = intra + inter; h' = decay*h + S_c.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, b_ref, c_ref, a_ref, dt_ref, y_ref, h_ref, *,
                chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0, 0].astype(jnp.float32)           # (c, P)
    Bm = b_ref[0].astype(jnp.float32)             # (c, N)
    Cm = c_ref[0].astype(jnp.float32)             # (c, N)
    a = a_ref[0, 0].astype(jnp.float32)           # (c,)
    dt = dt_ref[0, 0].astype(jnp.float32)         # (c,)

    la = jnp.cumsum(jnp.log(a + 1e-20))           # (c,)
    seg = la[:, None] - la[None, :]               # (c, c)
    iota = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    iotb = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    seg = jnp.where(iota >= iotb, seg, -1e30)
    G = jnp.exp(seg)
    CB = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (c,c)
    W = CB * G
    xdt = x * dt[:, None]
    y_intra = jax.lax.dot_general(W, xdt, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    # inter: y += (C decay) @ h^T  with h (P, N)
    decay_from_start = jnp.exp(la)                # (c,)
    y_inter = jax.lax.dot_general(
        Cm * decay_from_start[:, None], h_ref[...],
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)       # (c, P)
    y_ref[0, 0] = (y_intra + y_inter).astype(y_ref.dtype)

    # state update: h' = exp(la_end) h + sum_t decay_to_end_t dt_t x_t B_t^T
    decay_to_end = jnp.exp(la[-1] - la)           # (c,)
    S_c = jax.lax.dot_general(
        xdt * decay_to_end[:, None], Bm, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)       # (P, N)
    h_ref[...] = jnp.exp(la[-1]) * h_ref[...] + S_c


def mamba2_scan(x, Bmat, Cmat, a, dt, *, chunk: int = 256,
                interpret: bool = False):
    """x: (B,NH,S,P); Bmat/Cmat: (B,S,N); a/dt: (B,NH,S) -> y like x."""
    B, NH, S, P = x.shape
    N = Bmat.shape[-1]
    chunk = min(chunk, S)
    assert S % chunk == 0
    nchunk = S // chunk

    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=(B, NH, nchunk),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, P), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1, 1, chunk), lambda b, h, c: (b, h, c)),
            pl.BlockSpec((1, 1, chunk), lambda b, h, c: (b, h, c)),
        ],
        out_specs=pl.BlockSpec((1, 1, chunk, P),
                               lambda b, h, c: (b, h, c, 0)),
        out_shape=jax.ShapeDtypeStruct((B, NH, S, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(x, Bmat, Cmat, a, dt)
