"""xLSTM mLSTM chunkwise-parallel Pallas TPU kernel.

Matrix-memory linear attention with exponential gating, stabilized in log
space. Same TPU shape as the SSD kernel: (c x c) intra-chunk MXU tiles,
(hd x hd) matrix state C plus normalizer n carried in VMEM scratch across
the sequential chunk grid dim. The stabilizer max rides in the scratch
with the state in decayed-log reference frame (states are stored w.r.t.
m=0; the per-chunk weights fold exp(lf - m) in, matching the reference
formulation in models/xlstm.py).

Layout: q/k/v (B, NH, S, hd); logi/logf (B, NH, S).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _mlstm_kernel(q_ref, k_ref, v_ref, i_ref, f_ref, y_ref,
                  C_ref, n_ref, *, chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        C_ref[...] = jnp.zeros_like(C_ref)
        n_ref[...] = jnp.zeros_like(n_ref)

    q = q_ref[0, 0].astype(jnp.float32)            # (c, hd)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    logi = i_ref[0, 0].astype(jnp.float32)         # (c,)
    logf = f_ref[0, 0].astype(jnp.float32)

    lf = jnp.cumsum(logf)                          # (c,)
    seg = lf[:, None] - lf[None, :]                # (c, c)
    logD = seg + logi[None, :]
    iota = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    iotb = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    logD = jnp.where(iota >= iotb, logD, -1e30)
    m_intra = jnp.max(logD, axis=1)                # (c,)
    m = jnp.maximum(m_intra, lf)                   # stabilizer per row
    Dmat = jnp.exp(logD - m[:, None])
    QK = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    W = QK * Dmat
    y_intra = jax.lax.dot_general(W, v, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    den_intra = jnp.sum(W, axis=1)                 # (c,)

    w_init = jnp.exp(lf - m)                       # (c,)
    qw = q * w_init[:, None]
    y_inter = jax.lax.dot_general(qw, C_ref[...],
                                  (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    den_inter = jax.lax.dot_general(qw, n_ref[...][:, None],
                                    (((1,), (0,)), ((), ())),
                                    preferred_element_type=jnp.float32)[:, 0]
    num = y_intra + y_inter
    den = den_intra + den_inter
    den = jnp.maximum(jnp.abs(den), jnp.exp(-m))
    y_ref[0, 0] = (num / den[:, None]).astype(y_ref.dtype)

    decay_to_end = jnp.exp(lf[-1] - lf + logi)     # (c,)
    C_ref[...] = (jnp.exp(lf[-1]) * C_ref[...]
                  + jax.lax.dot_general(
                      k * decay_to_end[:, None], v,
                      (((0,), (0,)), ((), ())),
                      preferred_element_type=jnp.float32))
    n_ref[...] = (jnp.exp(lf[-1]) * n_ref[...]
                  + jnp.sum(k * decay_to_end[:, None], axis=0))


def mlstm_chunkwise(q, k, v, logi, logf, *, chunk: int = 256,
                    interpret: bool = False):
    """q/k/v: (B,NH,S,hd); logi/logf: (B,NH,S) -> y (B,NH,S,hd)."""
    B, NH, S, hd = q.shape
    chunk = min(chunk, S)
    assert S % chunk == 0
    nchunk = S // chunk

    kernel = functools.partial(_mlstm_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=(B, NH, nchunk),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, hd), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, chunk, hd), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, chunk, hd), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, chunk), lambda b, h, c: (b, h, c)),
            pl.BlockSpec((1, 1, chunk), lambda b, h, c: (b, h, c)),
        ],
        out_specs=pl.BlockSpec((1, 1, chunk, hd),
                               lambda b, h, c: (b, h, c, 0)),
        out_shape=jax.ShapeDtypeStruct((B, NH, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((hd, hd), jnp.float32),
            pltpu.VMEM((hd,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, logi, logf)
