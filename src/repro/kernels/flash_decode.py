"""Flash-decoding Pallas TPU kernel: one-token attention over a long KV
cache, split across KV blocks.

FlashDecoding on GPU splits K across SMs and merges per-split LSE; the TPU
adaptation runs the KV split as the sequential last grid dim with the
(acc, m, l) merge state in VMEM scratch (no cross-core merge needed: a
core streams its KV range through the MXU at full rate; the mesh-level
split across chips is handled above the kernel by the sharding layer).

Layout: q (B, H, hd); k/v (B, Kh, W, hd); valid (B, W) int32 mask
(1 = slot holds a token the query may attend to — the caller encodes
causality/ring-buffer validity in it).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(q_ref, k_ref, v_ref, valid_ref, o_ref,
                   acc_ref, m_ref, l_ref, *, block_k: int, kv_blocks: int,
                   sm_scale: float):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)             # (1, hd) row
    k = k_ref[0, 0].astype(jnp.float32)             # (bk, hd)
    v = v_ref[0, 0].astype(jnp.float32)             # (bk, hd)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    s = s * sm_scale                                # (1, bk)
    ok = valid_ref[0] > 0                           # (bk,)
    s = jnp.where(ok[None, :], s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p)
    m_ref[...] = m_new
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(ki == kv_blocks - 1)
    def _final():
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_decode(q, k, v, valid, *, block_k: int = 512,
                 interpret: bool = False):
    """q: (B,H,hd); k/v: (B,Kh,W,hd); valid: (B,W) int32 -> (B,H,hd)."""
    B, H, hd = q.shape
    Kh, W = k.shape[1], k.shape[2]
    assert H % Kh == 0
    block_k = min(block_k, W)
    assert W % block_k == 0
    kv_blocks = W // block_k
    g = H // Kh
    sm_scale = 1.0 / math.sqrt(hd)

    kernel = functools.partial(_decode_kernel, block_k=block_k,
                               kv_blocks=kv_blocks, sm_scale=sm_scale)
    q4 = q[:, :, None, :]                           # (B,H,1,hd)
    out = pl.pallas_call(
        kernel,
        grid=(B, H, kv_blocks),
        in_specs=[
            pl.BlockSpec((1, 1, 1, hd), lambda b, h, k_: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, k_: (b, h // g, k_, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, k_: (b, h // g, k_, 0)),
            pl.BlockSpec((1, block_k), lambda b, h, k_: (b, k_)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, hd), lambda b, h, k_: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, 1, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, hd), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
        ],
        interpret=interpret,
    )(q4, k, v, valid)
    return out[:, :, 0, :]
