"""jit'd public wrappers around the Pallas kernels.

``interpret`` defaults to True off-TPU (this container) and False on TPU;
callers on real hardware get the compiled kernels, tests get the
interpreter executing the same kernel bodies.
"""
from __future__ import annotations

import functools

import jax

from .bucket_combine import bucket_combine
from .flash_attention import flash_attention
from .flash_decode import flash_decode
from .mamba2_scan import mamba2_scan
from .mlstm_kernel import mlstm_chunkwise


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "sliding_window",
                                             "block_q", "block_k",
                                             "interpret"))
def flash_attention_op(q, k, v, *, causal=True, sliding_window=None,
                       block_q=128, block_k=128, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return flash_attention(q, k, v, causal=causal,
                           sliding_window=sliding_window, block_q=block_q,
                           block_k=block_k, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def flash_decode_op(q, k, v, valid, *, block_k=512, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return flash_decode(q, k, v, valid, block_k=block_k,
                        interpret=interpret)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def mamba2_scan_op(x, Bmat, Cmat, a, dt, *, chunk=256, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return mamba2_scan(x, Bmat, Cmat, a, dt, chunk=chunk,
                       interpret=interpret)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def mlstm_op(q, k, v, logi, logf, *, chunk=256, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return mlstm_chunkwise(q, k, v, logi, logf, chunk=chunk,
                           interpret=interpret)


def bucket_combine_op(acc, y, gate, *, op="add", interpret=None):
    """Fused local reduce of one collective round over the bucketed grad
    buffer (collective_exec). Not jitted here: it is traced inside the
    engine's shard_map programs."""
    interpret = _default_interpret() if interpret is None else interpret
    return bucket_combine(acc, y, gate, op=op, interpret=interpret)
