"""Fused bucket-combine Pallas kernel for the collective execution engine.

One schedule round over the bucketed gradient buffer is one fused kernel
launch: instead of ~hundreds of per-leaf adds (one XLA op per pytree
leaf), the flattened gradient rides a (n_buckets, bucket_elems) f32
buffer and the local reduce of a ``lax.ppermute`` round is a single
grid-over-buckets elementwise kernel. The round's *gate* — whether this
device is a destination of the round's partial permutation — is a scalar
in SMEM, so the same compiled kernel serves every round of the schedule:

* ``op="add"``  — reduce rounds: ``acc + gate * incoming``
* ``op="copy"`` — broadcast/hydration rounds: ``gate ? incoming : acc``

Each bucket row is one VMEM block (buckets are sized by the engine to a
few hundred KB, well under the ~16 MB VMEM budget for the three
operands); off-TPU callers run the same kernel body under the
interpreter.

**Variable-group launch**: the grid is derived from the operand's row
count, so the same kernel serves the eager executor (one launch over
the full ``(n_buckets, bucket_elems)`` buffer per round) and the
pipelined executor (one launch per readiness group per round, each with
that group's own bucket count). A zero-row group is a no-op without a
launch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# 3 operands (acc, incoming, out) must fit VMEM together; stay well clear.
MAX_BUCKET_BYTES = 4 * 1024 * 1024


def _combine_kernel(gate_ref, acc_ref, y_ref, o_ref, *, op: str):
    g = gate_ref[0, 0] != 0
    acc = acc_ref[...]
    y = y_ref[...]
    if op == "add":
        o_ref[...] = acc + jnp.where(g, y, jnp.zeros_like(y))
    else:  # "copy": round destinations take the incoming value wholesale
        o_ref[...] = jnp.where(g, y, acc)


def bucket_combine(acc: jax.Array, y: jax.Array, gate: jax.Array, *,
                   op: str = "add", interpret: bool = False) -> jax.Array:
    """Combine one ppermute round into the bucketed accumulator.

    ``acc``/``y``: (rows, bucket_elems) — the full buffer or one
    readiness group's sub-buffer (the grid follows the operand, so group
    sizes may vary launch to launch); ``gate``: scalar bool/int (is this
    device a destination this round); ``op``: "add" | "copy".
    """
    assert acc.ndim == 2 and acc.shape == y.shape, (acc.shape, y.shape)
    assert op in ("add", "copy"), op
    nb, be = acc.shape
    if nb == 0:
        return acc
    assert be * acc.dtype.itemsize <= MAX_BUCKET_BYTES, \
        f"bucket row of {be} elems exceeds the VMEM block budget"
    kernel = functools.partial(_combine_kernel, op=op)
    gate2 = jnp.asarray(gate).astype(jnp.int32).reshape(1, 1)
    return pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, be), lambda i: (i, 0)),
            pl.BlockSpec((1, be), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, be), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(acc.shape, acc.dtype),
        interpret=interpret,
    )(gate2, acc, y)
