"""Partitioned phaser control plane: one logical skip list, N processes.

``DistPhaser`` holds every protocol actor in one address space. Here the
same actors are *sharded by ownership* (the PGAS global-view recipe of
arXiv:2112.00068): process ``k`` owns the actor for participant key
``k``; the coordinator (pid ``COORD = -1``) owns the HEAD sentinel —
conveniently the same id as the HEAD key. ``PhaserActor`` is reused
unmodified: its only facade needs are ``height_of`` (deterministic hash,
computable anywhere), ``async_parent`` (populated on the joining key's
owner), ``lists_done`` (asked only about the local rank) and
``on_release`` (fires on the HEAD owner). Everything else the actors do
is messaging, and ``PartitionedNetwork`` routes any envelope whose
destination is remote through the transport endpoint; per-(src, dst)
FIFO — the protocol's only ordering assumption — is preserved because
each ordered pair maps onto one ordered stream.

Quiescence becomes a distributed property: locally ``idle()`` plus
globally "no frame in flight", which the coordinator establishes from
the shards' matching remote sent/received counters (two stable polls —
a Mattern-style termination wave; the in-process fabric needs no wave
because delivery is synchronous).
"""
from __future__ import annotations

import time
from typing import Callable, Dict, Iterable, List, Optional, Set, Tuple

from ..core.phaser import SCSL, SNSL, SIG_MODE, SIG_WAIT, WAIT_MODE, \
    PhaserActor
from ..core.runtime import Envelope, Network
from ..core.skiplist import HEAD, SkipList, det_height
from ..obs.live import WatermarkTracker
from ..obs.recorder import FlightRecorder
from ..obs.trace import Tracer
from .transport import Endpoint

COORD = -1  # coordinator pid == the HEAD sentinel key


def default_owner(key: int) -> int:
    """Participant key k lives on process k; HEAD on the coordinator."""
    return COORD if key == HEAD else key


class PartitionedNetwork(Network):
    """The local slice of the cluster-wide network: envelopes for local
    actors use the in-memory FIFO channels; remote ones leave through
    the endpoint and are re-injected into the owner's channels by
    ``ingest`` on arrival (same (src, dst) channel key, so delivery
    order stays per-channel FIFO end to end)."""

    def __init__(self, pid: int, endpoint: Endpoint,
                 owner_of: Callable[[int], int] = default_owner):
        super().__init__()
        self.pid = pid
        self.endpoint = endpoint
        self.owner_of = owner_of
        self.remote_sent = 0
        self.remote_received = 0
        # keys that left the membership: envelopes to them are swallowed,
        # mirroring the monolithic network where a departed actor receives
        # stale notifications (ADV fan-out books) and ignores them
        self.dropped: Set[int] = set()
        self.black_holed = 0
        # membership generation: bumped by the coordinator's
        # non-cooperative recovery; stamped on every outgoing envelope
        # and checked at ingest so frames from the pre-crash incarnation
        # can never reach the rebuilt actors
        self.gen = 0
        self.stale_gen = 0
        self.send_failed = 0    # remote sends to a crashed peer

    def _blackhole(self, env: Envelope) -> None:
        if self.tracer is not None and env.trace is not None:
            # the span still closes: eviction fan-out must not
            # leave dangling spans in the causal tree
            self.tracer.on_blackhole(env.trace)

    def post(self, env: Envelope) -> None:
        env.gen = self.gen
        if env.msg.dst in self.dropped:
            self.black_holed += 1
            self._blackhole(env)
            return
        owner = self.owner_of(env.msg.dst)
        if owner == self.pid:
            super().post(env)
            return
        self.sent[env.msg.kind] += 1
        try:
            self.endpoint.send(owner, "env", env)
        except (OSError, ConnectionError):
            # crash-stop peer: the frame is gone; count it and close
            # the span — detection/recovery is the coordinator's job.
            # (Socket fabrics no longer take this path: their session
            # layer defers undeliverable envelopes into the resend
            # ring instead of raising, and frames reaped for good come
            # back through the endpoint reaper -> _blackhole edge.)
            self.send_failed += 1
            self._blackhole(env)
            return
        self.remote_sent += 1

    def ingest(self, env: Envelope) -> None:
        """Arrival of a remote envelope: enqueue without re-counting the
        send (the source shard already did). Frames from an older
        membership generation are fenced here (their senders were
        rebuilt or died); their spans close as blackholed."""
        if getattr(env, "gen", 0) != self.gen:
            self.stale_gen += 1
            self._blackhole(env)
            return
        if env.msg.dst in self.dropped:
            self.black_holed += 1
            self._blackhole(env)
            return
        self.remote_received += 1
        self.channels[(env.msg.src, env.msg.dst)].append(env)

    def deliver_all(self, max_steps: int = 1_000_000) -> int:
        """Round-robin local delivery to local idleness (remote sends
        triggered along the way just leave through the endpoint)."""
        n = 0
        rr = 0
        while not self.idle():
            chans = self.nonempty_channels()
            self.deliver_from(chans[rr % len(chans)])
            rr += 1
            n += 1
            assert n <= max_steps, "local delivery did not quiesce"
        return n


class ShardPhaser:
    """Per-process facade over the locally-owned protocol actors.

    Mirrors the slice of ``DistPhaser``'s surface the actors and the
    runtime need; global topology metadata (live keys, demotions, seed)
    is replicated on every shard so each process can derive the oracle —
    and therefore its own partition view — without communication."""

    def __init__(self, pid: int, endpoint: Endpoint, *,
                 live: Iterable[int], p: float = 0.5, seed: int = 0,
                 max_height: int = 32,
                 demoted: Iterable[int] = (),
                 owner_of: Callable[[int], int] = default_owner,
                 modes: Optional[Dict[int, str]] = None,
                 obs: bool = False):
        self.pid = pid
        self.p = p
        self.seed = seed
        self.max_height = max_height
        self.owner_of = owner_of
        self.live: Set[int] = set(live)
        self.demoted: Set[int] = set(demoted)
        self.net = PartitionedNetwork(pid, endpoint, owner_of)
        # session-layer reap edge: an unacked envelope torn out of a
        # resend ring for good (peer evicted, ring overflow) is
        # blackholed through the net so its span still closes
        _sr = getattr(endpoint, "set_reaper", None)
        if _sr is not None:
            _sr(lambda payload, tag:
                self.net._blackhole(payload) if tag == "env" else None)
        # always-on obs layer: phase watermarks (counter bumps via the
        # actor hooks) and the bounded flight ring — both cheap enough
        # to never gate behind ``obs``
        self.watermarks = WatermarkTracker(pid)
        self.flight = FlightRecorder(pid)
        if obs:
            self.net.tracer = Tracer(pid)
            self.net.tracer.flight = self.flight
        self.modes: Dict[int, str] = {k: SIG_WAIT for k in self.live}
        if modes:
            self.modes.update(modes)
        for k in self.live:
            if owner_of(k) == pid:
                self.watermarks.set_mode(k, self.modes[k])
        self.async_parent: Dict[int, int] = {}
        self.release_log: List[int] = []
        self.gen = 0                 # membership incarnation (recovery)
        self.stray: List = []        # non-env frames surfaced by pump()
        self.actors: Dict[int, PhaserActor] = {}
        local = [k for k in sorted(self.live) if owner_of(k) == pid]
        if owner_of(HEAD) == pid:
            local = [HEAD] + local
        for k in local:
            a = PhaserActor(k, self.net, self.modes.get(k, SIG_WAIT),
                            phaser=self)
            self.actors[k] = a
            self.net.register(a)
        sig = [k for k in sorted(self.live)
               if self.modes[k] in (SIG_MODE, SIG_WAIT)]
        wait = [k for k in sorted(self.live)
                if self.modes[k] in (WAIT_MODE, SIG_WAIT)]
        self._init_list(SCSL, sig)
        self._init_list(SNSL, wait)
        if HEAD in self.actors:
            self.actors[HEAD].expected_base = len(sig)

    # ---------------------------------------------------------- facade API
    def height_of(self, key: int) -> int:
        if key in self.demoted:
            return 1
        return det_height(key, p=self.p, max_height=self.max_height,
                          seed=self.seed)

    def lists_done(self, rank: int) -> bool:
        a = self.actors[rank]
        ok = True
        if a.sc.member:
            ok &= a.sc.joined
        if a.sn.member:
            ok &= a.sn.joined
        return ok

    def on_release(self, k: int) -> None:
        self.release_log.append(k)
        # fires on the HEAD owner (the coordinator): one event per phase
        self.flight.event("release", phase=k)

    # watermark hooks — PhaserActor looks these up via getattr on its
    # phaser facade; the shard's tracker is always on
    def on_local_signal(self, rank: int, phase: int) -> None:
        self.watermarks.on_signal(rank, phase)

    def on_wait_advance(self, rank: int, phase: int) -> None:
        self.watermarks.on_wait_advance(rank, phase)

    # ---------------------------------------------------------- topology
    def oracle(self, keys: Optional[Iterable[int]] = None) -> SkipList:
        return SkipList.build(sorted(keys if keys is not None
                                     else self.live),
                              p=self.p, max_height=self.max_height,
                              seed=self.seed, leaf_keys=self.demoted)

    def _init_list(self, lid: int, keys: List[int],
                   phase_start: int = 0) -> None:
        """Seed the local actors' list states from the global oracle —
        every shard computes the same structure, installs its slice.
        ``phase_start`` > 0 is the crash-recovery path: the rebuilt
        incarnation opens its books at the first un-released phase, so
        the fresh state is exactly boot state shifted by the phases the
        previous incarnation already closed."""
        sl = self.oracle(keys)
        for k, a in self.actors.items():
            if k != HEAD and k not in keys:
                continue
            node = sl.nodes[k]
            st = a.st(lid)
            st.member = True
            st.joined = True
            st.height = node.height
            st.target_height = st.height
            st.nxt = list(node.nxt)
            st.prv = list(node.prv)
            st.books = {c: [[phase_start, None]] for c in sl.children(k)}
            par = sl.parent(k)
            if par is not None:
                st.adv = [[phase_start, None, par]]
            st.first_phase = phase_start
            st.closed = phase_start - 1
            if lid == SNSL:
                st.released = phase_start - 1

    def local_states(self, lid: int) -> Dict[int, Tuple[int, Tuple, Tuple]]:
        """(height, nxt, prv) for every locally-owned live actor (HEAD
        included) — matched against ``SkipList.partition``'s view of
        this owner at epoch boundaries."""
        out = {}
        for k, a in self.actors.items():
            if k != HEAD and k not in self.live:
                continue
            st = a.st(lid)
            if not st.member or (k != HEAD and not st.joined) \
                    or st.departed:
                continue
            out[k] = (st.height, tuple(st.nxt), tuple(st.prv))
        return out

    # ---------------------------------------------------------- tracing
    @property
    def tracer(self) -> Optional[Tracer]:
        return self.net.tracer

    def _root(self, op: str, key: int) -> None:
        """Open a root span before a facade op: the actor's resulting
        sends (and their remote descendants) form one causal tree."""
        if self.net.tracer is not None:
            self.net.tracer.root(op, key)

    def drain_obs(self) -> List[Dict]:
        """Hand the shard's span records to the coordinator (empty when
        tracing is off)."""
        return self.net.tracer.drain() if self.net.tracer else []

    # ---------------------------------------------------------- operations
    def create_member(self, new: int, parent: int,
                      mode: str = SIG_WAIT) -> None:
        """Owner-side half of the paper's async add: materialize the new
        key's actor (it joins via MURS_ACK once the initiator's eager
        splice reaches it)."""
        assert self.owner_of(new) == self.pid, (new, self.pid)
        a = PhaserActor(new, self.net, mode, phaser=self)
        self.actors[new] = a
        self.net.register(a)
        self.modes[new] = mode
        self.async_parent[new] = parent
        self.live.add(new)

    def start_insert(self, new: int, parent: int) -> None:
        """Initiator-side half: the (locally-owned) parent starts the
        eager level-0 search for both lists. Runs on the parent's owner;
        ``create_member`` must already have run on ``new``'s owner."""
        self._root("join", parent)
        a = self.actors[parent]
        a.start_insert(new, SCSL)
        a.start_insert(new, SNSL)

    def signal(self, rank: int) -> None:
        self._root("signal", rank)
        t0 = time.perf_counter()
        self.actors[rank].local_signal()
        self.watermarks.add_signal_time(rank, time.perf_counter() - t0)

    def drop(self, rank: int) -> None:
        self._root("evict", rank)
        self.actors[rank].local_drop()
        self.demoted.discard(rank)

    def demote(self, rank: int) -> None:
        assert self.lists_done(rank), rank
        self._root("demote", rank)
        self.demoted.add(rank)
        self.actors[rank].local_demote()

    def repromote(self, rank: int) -> None:
        self._root("repromote", rank)
        self.demoted.discard(rank)
        self.actors[rank].local_promote_to(self.height_of(rank))

    def released(self) -> int:
        if HEAD in self.actors:
            return self.actors[HEAD].head_released
        for k in sorted(self.actors):
            a = self.actors[k]
            if a.sn.member and not a.sn.departed:
                return a.sn.released
        return -1

    # ---------------------------------------------------------- membership
    def note_membership(self, live: Iterable[int],
                        demoted: Iterable[int]) -> None:
        """Install the replicated membership view (broadcast by the
        coordinator after each structural op reaches quiescence)."""
        gone = self.live - set(live)
        self.net.dropped |= gone
        self.live = set(live)
        self.demoted = set(demoted)
        for k in self.live:
            self.modes.setdefault(k, SIG_WAIT)
        self.flight.event("membership", live=sorted(self.live),
                          gone=sorted(gone))

    # ---------------------------------------------------------- recovery
    def rebuild(self, live: Iterable[int], demoted: Iterable[int],
                phase: int, gen: int) -> None:
        """Non-cooperative eviction (DESIGN.md §13): a host died without
        running the demote→evict protocol, so its actors can never
        answer the unlink handshakes. Instead of forging the dead
        owner's messages, every survivor re-seeds its shard from the
        oracle of the surviving membership — the same ``_init_list``
        path boot uses, fast-forwarded to open at ``phase + 1`` (the
        first phase HEAD has not released). In-flight envelopes of the
        old incarnation are discarded here (their spans close as
        blackholed) and fenced at ingest by the ``gen`` stamp."""
        gone = self.live - set(live)
        self.net.dropped |= gone
        self.live = set(live)
        self.demoted = set(demoted)
        for k in self.live:
            self.modes.setdefault(k, SIG_WAIT)
        # the tracker survives rebuild: watermarks are monotone across
        # generations (the rebuilt incarnation opens at phase + 1, which
        # is >= every previously observed watermark)
        self.watermarks.gen = gen
        self.flight.event("rebuild", gen=gen, phase=phase,
                          live=sorted(self.live), gone=sorted(gone))
        # drop the old incarnation's in-flight frames, closing spans so
        # the causal trees stay complete
        for q in self.net.channels.values():
            for env in q:
                self.net._blackhole(env)
        self.net.channels.clear()
        self.net.gen = gen
        self.gen = gen
        # flight counters restart at zero on every survivor at the same
        # recovery point: the Mattern balance is re-founded for the new
        # incarnation (the dead host's counters are unknowable)
        self.net.remote_sent = 0
        self.net.remote_received = 0
        self.net.actors.clear()
        self.actors.clear()
        self.async_parent.clear()
        start = phase + 1
        local = [k for k in sorted(self.live) if self.owner_of(k) == self.pid]
        if self.owner_of(HEAD) == self.pid:
            local = [HEAD] + local
        for k in local:
            a = PhaserActor(k, self.net, self.modes.get(k, SIG_WAIT),
                            phaser=self)
            a.sig_next = start
            a.wait_next = start
            self.actors[k] = a
            self.net.register(a)
        sig = [k for k in sorted(self.live)
               if self.modes[k] in (SIG_MODE, SIG_WAIT)]
        wait = [k for k in sorted(self.live)
                if self.modes[k] in (WAIT_MODE, SIG_WAIT)]
        self._init_list(SCSL, sig, phase_start=start)
        self._init_list(SNSL, wait, phase_start=start)
        if HEAD in self.actors:
            head = self.actors[HEAD]
            head.expected_base = len(sig)
            head.head_released = phase

    # ---------------------------------------------------------- pumping
    def pump(self) -> int:
        """Ingest every queued transport envelope, then deliver local
        messages to local idleness. Returns deliveries made."""
        moved = 0
        while True:
            frame = self.net.endpoint.recv(timeout=0)
            if frame is None:
                break
            src, tag, payload = frame
            if tag == "red":
                self.stray.append(frame)   # a peer's step round: held
                continue
            if tag in ("ctl", "hb"):
                continue                   # stale control frames
            if tag == "cmd":
                # A retransmitted/duplicated command raced into the inbox
                # while we were servicing another op: park it for the
                # worker main loop (which dedupes by command id).
                self.stray.append(frame)
                continue
            assert tag == "env", f"unexpected {tag} frame in pump"
            self.net.ingest(payload)
        moved += self.net.deliver_all()
        return moved

    def drain_stray(self) -> List:
        out, self.stray = self.stray, []
        return out

    def flight_counters(self) -> Tuple[int, int]:
        return self.net.remote_sent, self.net.remote_received
