"""Failure model of the multi-host control plane (DESIGN.md §13).

Crash-stop only: a process that fails stops sending forever — there is
no Byzantine tolerance anywhere in the runtime. Detection is layered:

* ``PhiDetector`` — a phi-accrual-style timeout detector over the
  coordinator's heartbeat acks. ``phi`` is the elapsed silence measured
  in units of the observed mean inter-ack interval, so a uniformly slow
  machine (CI under load) raises everyone's mean instead of raising
  false suspicion. A host is *suspected* when phi crosses
  ``phi_suspect``; it is *declared dead* only when BOTH the adaptive
  test (phi >= ``phi_dead``) and the hard floor (silence >= ``timeout``)
  hold — suspect -> confirm -> declare, never declare on one signal.
* Structured exceptions — every way a peer can fail surfaces as a typed
  error carrying the pid, so the coordinator's recovery path
  (``DistCoordinator.recover_failure``) can react mechanically.

Everything here is jax-free and import-light: worker processes and the
transport layer both import it.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional


class PeerUnreachable(ConnectionError):
    """Could not establish a transport connection to ``pid`` after
    ``attempts`` backoff retries over ``elapsed`` seconds."""

    def __init__(self, pid: int, attempts: int, elapsed: float):
        self.pid = pid
        self.attempts = attempts
        self.elapsed = elapsed
        super().__init__(f"peer {pid} unreachable after {attempts} "
                         f"connect attempts over {elapsed:.2f}s")


class HostDead(RuntimeError):
    """A host was declared dead (heartbeat timeout or simulated crash);
    the pending operation cannot complete against it."""

    def __init__(self, pid: int, reason: str = "declared dead"):
        self.pid = pid
        super().__init__(f"host {pid} {reason}")


class RpcTimeout(RuntimeError):
    """No reply for a command after retries, and the detector never
    declared the peer dead — the caller's deadline expired first."""

    def __init__(self, pid: int, cid: int, elapsed: float, attempts: int):
        self.pid = pid
        self.cid = cid
        self.elapsed = elapsed
        self.attempts = attempts
        super().__init__(f"no reply from host {pid} for cmd {cid} after "
                         f"{elapsed:.1f}s ({attempts} attempts)")


class StepInconsistent(RuntimeError):
    """After a mid-step crash, some survivors applied the step and some
    did not — params have diverged and only a checkpoint-consistent
    ``resume()`` can restore the replicated invariant."""

    def __init__(self, step: int, applied: Dict[int, int]):
        self.step = step
        self.applied = dict(applied)
        super().__init__(f"step {step} applied on a strict subset of "
                         f"survivors: {self.applied}")


def orphan_horizon(failure_timeout: float) -> float:
    """How long a worker tolerates coordinator silence before it
    self-terminates as an orphan.

    Partition-tolerance invariant: this must strictly exceed the
    coordinator's eviction horizon (``failure_timeout``), or a
    transient network partition shorter than the failure timeout —
    which the session layer heals with zero envelope loss and the
    PhiDetector resolves as suspect→recover — would still kill the
    worker from the *other* side. 3× the failure timeout (floored at
    10s so aggressive test timeouts don't make orphanhood hair-
    triggered) means any partition short enough to be survivable is
    also short enough that neither side acts on it.
    """
    return max(10.0, 3.0 * failure_timeout)


def backoff(attempt: int, base: float, cap: float, rng=None) -> float:
    """Bounded exponential backoff with optional jitter: attempt 1 waits
    ~``base``, doubling up to ``cap``; jitter spreads retries by up to
    +50% so replayed commands from many callers don't synchronize."""
    d = min(cap, base * (2 ** max(0, attempt - 1)))
    if rng is not None:
        d *= 1.0 + 0.5 * rng.random()
    return d


class PhiDetector:
    """Suspect -> confirm -> declare-dead over heartbeat acks.

    ``on_ack(pid, t)`` feeds ack arrival times; ``poll(now)`` returns
    the pids newly declared dead. All clocks are ``time.monotonic``.
    """

    ALIVE, SUSPECT, DEAD = "alive", "suspect", "dead"

    def __init__(self, *, interval: float = 0.5, timeout: float = 10.0,
                 phi_suspect: float = 4.0, phi_dead: float = 10.0,
                 window: int = 16, metrics=None):
        self.interval = max(1e-3, interval)
        self.timeout = timeout
        self.phi_suspect = phi_suspect
        self.phi_dead = phi_dead
        self.window = window
        self.metrics = metrics
        self.last: Dict[int, float] = {}        # pid -> last ack time
        self.ivals: Dict[int, List[float]] = {}  # pid -> recent intervals
        self.state: Dict[int, str] = {}
        self.declared: Dict[int, Dict] = {}      # pid -> {at, silence}

    # ------------------------------------------------------------ feeding
    def touch(self, pid: int, t: Optional[float] = None) -> None:
        """Start tracking ``pid`` (spawn time counts as the first ack,
        so a worker that never comes up still gets declared)."""
        t = time.monotonic() if t is None else t
        self.last.setdefault(pid, t)
        self.ivals.setdefault(pid, [])
        self.state.setdefault(pid, self.ALIVE)

    def on_ack(self, pid: int, t: Optional[float] = None) -> None:
        t = time.monotonic() if t is None else t
        if self.state.get(pid) == self.DEAD:
            return                    # late ack from a declared host
        prev = self.last.get(pid)
        if prev is not None:
            iv = self.ivals.setdefault(pid, [])
            iv.append(max(1e-4, t - prev))
            del iv[:-self.window]
        self.last[pid] = t
        if self.state.get(pid) == self.SUSPECT:
            self.state[pid] = self.ALIVE    # confirm failed: recovered
            if self.metrics is not None:
                self.metrics.inc("detector.recovered")
        else:
            self.state.setdefault(pid, self.ALIVE)

    def remove(self, pid: int) -> None:
        """Cooperative departure: stop tracking without declaring."""
        for d in (self.last, self.ivals, self.state, self.declared):
            d.pop(pid, None)

    # ------------------------------------------------------------ queries
    def phi(self, pid: int, now: Optional[float] = None) -> float:
        now = time.monotonic() if now is None else now
        last = self.last.get(pid)
        if last is None:
            return 0.0
        iv = self.ivals.get(pid) or []
        mean = (sum(iv) / len(iv)) if iv else self.interval
        return (now - last) / max(mean, 1e-4)

    def poll(self, now: Optional[float] = None) -> List[int]:
        """Advance every tracked host's state machine; returns pids
        newly declared dead (exactly once each)."""
        now = time.monotonic() if now is None else now
        newly: List[int] = []
        for pid in list(self.last):
            if self.state.get(pid) == self.DEAD:
                continue
            silence = now - self.last[pid]
            ph = self.phi(pid, now)
            if self.state[pid] == self.ALIVE:
                if ph >= self.phi_suspect or silence >= self.timeout / 2:
                    self.state[pid] = self.SUSPECT
                    if self.metrics is not None:
                        self.metrics.inc("detector.suspected")
            if self.state[pid] == self.SUSPECT:
                # declare only when the adaptive and hard tests agree
                if ph >= self.phi_dead and silence >= self.timeout:
                    self.state[pid] = self.DEAD
                    self.declared[pid] = {"at": now, "silence": silence}
                    newly.append(pid)
                    if self.metrics is not None:
                        self.metrics.inc("detector.declared_dead")
                        self.metrics.observe("detector.silence_seconds",
                                             silence)
        return newly
