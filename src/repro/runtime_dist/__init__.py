"""Multi-host elastic phaser runtime (DESIGN.md §11).

The skip-list control plane partitioned across N processes: each host
owns its own protocol actor, the coordinator owns the HEAD sentinel, and
envelopes whose destination lives elsewhere ride a message transport
(in-process fabric or AF_UNIX sockets) that preserves the per-(src, dst)
FIFO the protocol assumes. Membership churn happens at whole-host
granularity through the same two-phase structural ops; every epoch
boundary re-derives the oracle on every survivor, checks each local
partition against it, and re-commits the per-process program cache.

Import note: everything here except ``coordinator`` is jax-free, so
control-plane-only worker processes never pay the jax import. The
coordinator (which can drive data-plane steps and the strike policy)
loads lazily on attribute access.
"""
from .agent import HostAgent
from .exchange import exchange_schedule, run_schedule_rounds
from .failure import (HostDead, PeerUnreachable, PhiDetector, RpcTimeout,
                      StepInconsistent, backoff, orphan_horizon)
from .plane import COORD, PartitionedNetwork, ShardPhaser, default_owner
from .transport import (ChaosConfig, Endpoint, FaultyEndpoint,
                        FaultyInprocFabric, InprocEndpoint, InprocFabric,
                        LinkFault, SocketEndpoint, TcpEndpoint,
                        endpoint_cls, fabric_dir, parse_link_spec)

_LAZY = ("DistCoordinator", "DistEpoch", "HostEvent", "InprocCluster",
         "SocketCluster")

__all__ = ["HostAgent", "exchange_schedule", "run_schedule_rounds",
           "HostDead", "PeerUnreachable", "PhiDetector", "RpcTimeout",
           "StepInconsistent", "backoff", "orphan_horizon",
           "COORD", "PartitionedNetwork", "ShardPhaser", "default_owner",
           "ChaosConfig", "Endpoint", "FaultyEndpoint",
           "FaultyInprocFabric", "InprocEndpoint", "InprocFabric",
           "LinkFault", "SocketEndpoint", "TcpEndpoint", "endpoint_cls",
           "fabric_dir", "parse_link_spec"] + list(_LAZY)


def __getattr__(name):   # PEP 562: keep worker imports jax-free
    if name in _LAZY:
        from . import coordinator
        return getattr(coordinator, name)
    raise AttributeError(name)
