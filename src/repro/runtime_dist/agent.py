"""HostAgent: one process's slice of the multi-host elastic runtime.

Owns the process's ``ShardPhaser`` (control plane) and, when a data
plane is configured, the process's hierarchical sync programs: an
epoch-aware ``ProgramCache`` keyed by the *process-level* collective,
re-committed at every churn epoch boundary so each surviving host
re-lowers its slice of the composed program.

The agent is driven entirely through ``handle(cmd) -> reply`` — the
same dict-command surface whether the coordinator calls it directly
(in-process cluster) or ships frames over sockets (``worker.py``). jax
and the model stack import lazily inside the data-plane handlers, so a
control-plane-only agent (the latency benchmark's workers) never pays
the jax import.
"""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, List, Optional

from ..core.phaser import SCSL, SNSL
from ..core.skiplist import HEAD
from ..obs.metrics import MetricsRegistry
from .plane import COORD, ShardPhaser, default_owner
from .transport import Endpoint


class HostAgent:
    """``cfg`` (JSON-serializable, identical on every process except
    ``device_slice``):

      seed, p, max_height   — topology identity
      live, demoted         — initial membership view
      proc_kind             — process-level schedule kind
      data                  — None (control-plane only) or the model
                              config: {arch, reduced, layers, batch,
                              seq, lr, steps, local_kind, devices,
                              device_slice, ckpt_dir}
    """

    def __init__(self, pid: int, endpoint: Endpoint, cfg: Dict):
        self.pid = pid
        self.endpoint = endpoint
        self.cfg = cfg
        self.proc_kind = cfg.get("proc_kind", "phaser_scsl")
        self.axis_name = cfg.get("axis", "data")
        self.shard = ShardPhaser(
            pid, endpoint,
            live=cfg.get("live", ()),
            p=cfg.get("p", 0.5), seed=cfg.get("seed", 0),
            max_height=cfg.get("max_height", 32),
            demoted=cfg.get("demoted", ()),
            obs=cfg.get("obs", False))
        # this process's metrics shard (one per agent, so in-process
        # logical hosts stay isolated); merged at the coordinator
        self.metrics = MetricsRegistry()
        if getattr(endpoint, "metrics", None) is None:
            # worker endpoints are built before the agent exists:
            # adopt them here so transport.session.* counters land in
            # this shard and merge cluster-wide through _op_obs
            endpoint.metrics = self.metrics
        self.data_cfg = cfg.get("data")
        self._dp = None            # lazily-built data plane dict
        self._deferred: List = []  # env frames deferred during a step
        self._red_held: List = []  # red frames that beat our step cmd
        self.gen = cfg.get("gen", 0)   # membership incarnation (recovery)
        self.shard.gen = self.gen
        self.shard.net.gen = self.gen
        self._applied: Dict = {"step": -1}   # last applied train step

    # ------------------------------------------------------------ data plane
    def _data_plane(self) -> Dict[str, Any]:
        if self._dp is not None:
            return self._dp
        assert self.data_cfg is not None, "no data plane configured"
        import jax
        from ..collective_exec import (ProgramCache,
                                       build_hier_gradsync_program)
        from ..models.registry import get_api, get_config
        from ..optim import AdamW
        d = self.data_cfg
        cfg = get_config(d.get("arch", "smollm-135m"))
        if d.get("reduced", True):
            cfg = cfg.reduced(**({"n_layers": d["layers"]}
                                 if d.get("layers") else {}))
        api = get_api(cfg)
        opt = AdamW(lr=d.get("lr", 3e-3),
                    warmup=d.get("warmup", 10),
                    total_steps=d.get("steps", 100))
        devs = jax.devices()
        sl = d.get("device_slice")
        if sl is not None:
            devs = devs[sl[0]:sl[0] + sl[1]]
        else:
            devs = devs[:d.get("devices", 1)]
        m = len(devs)
        local_kind = d.get("local_kind", "phaser_scsl")
        cache = ProgramCache(
            lambda pc: build_hier_gradsync_program(
                api, opt, pc, local_devices=devs,
                local_kind=local_kind),
            extra_key=("hier", m, local_kind),
            metrics=self.metrics)
        params = api.init_params(jax.random.key(d.get("init_seed", 0)))
        opt_state = opt.init(params)
        ckpt = None
        if d.get("ckpt_dir"):
            from ..checkpoint import CheckpointManager
            ckpt = CheckpointManager(d["ckpt_dir"], async_write=False)
        self._dp = {"api": api, "opt": opt, "cfg": cfg, "devices": devs,
                    "m": m, "cache": cache, "params": params,
                    "opt_state": opt_state, "ckpt": ckpt,
                    "local_kind": local_kind, "pending": None}
        return self._dp

    def _proc_collective(self):
        from ..core.collective import PhaserCollective
        keys = tuple(sorted(self.shard.live))
        return PhaserCollective(len(keys), self.axis_name,
                                kind=self.proc_kind,
                                seed=self.shard.seed, p=self.shard.p,
                                keys=keys,
                                leaf_keys=tuple(sorted(
                                    self.shard.demoted
                                    & self.shard.live)))

    def program_key(self) -> Dict:
        """JSON identity of the current epoch's hierarchical program:
        the elastic ``epoch_key`` (member set = the *local* device
        ranks) extended with the process set — what checkpoint
        manifests must record so resume can pre-compile the
        surviving-host program (not the pre-churn one)."""
        dp = self._data_plane()
        return {"process_set": sorted(self.shard.live),
                "member_set": list(range(dp["m"])),
                "kind": self.proc_kind,
                "local_kind": dp["local_kind"],
                "seed": self.shard.seed, "p": self.shard.p,
                "axis": self.axis_name,
                "leaf_keys": sorted(self.shard.demoted
                                    & self.shard.live)}

    def _local_batch(self, step: int):
        import numpy as np
        from ..data.synthetic import make_batch
        from ..utils import to_device_copy
        dp = self._data_plane()
        d = self.data_cfg
        m = dp["m"]
        # global worker id of (process key, local device) — a process's
        # data stream follows its phaser key, like worker streams in the
        # single-host elastic runtime
        bs = [make_batch(dp["cfg"].vocab_size, d.get("batch", 4),
                         d.get("seq", 64),
                         seed=1000 + self.pid * m + i, step=step)
              for i in range(m)]
        return {k: to_device_copy(np.stack([b[k] for b in bs]))
                for k in bs[0]}

    # ------------------------------------------------------------- commands
    def handle(self, cmd: Dict) -> Dict:
        op = cmd["op"]
        fn = getattr(self, f"_op_{op}", None)
        assert fn is not None, f"agent {self.pid}: unknown op {op!r}"
        try:
            out = fn(cmd) or {}
        except Exception as e:  # surfaced by the coordinator
            return {"ok": False, "error": f"{type(e).__name__}: {e}"}
        return {"ok": True, **out}

    def _op_ping(self, c):
        return {"pid": self.pid}

    def _op_create_member(self, c):
        self.shard.create_member(c["new"], c["parent"],
                                 c.get("mode", "SIG_WAIT"))

    def _op_start_insert(self, c):
        self.shard.start_insert(c["new"], c["parent"])

    def _op_drop(self, c):
        self.shard.drop(c["key"])

    def _op_demote(self, c):
        self.shard.demote(c["key"])

    def _op_repromote(self, c):
        self.shard.repromote(c["key"])

    def _op_signal(self, c):
        self.shard.signal(c.get("key", self.pid))

    def _op_note_membership(self, c):
        self.shard.note_membership(c["live"], c["demoted"])

    def _op_force_evict(self, c):
        """Non-cooperative eviction, survivor side: re-seed this shard
        from the surviving membership's oracle at the coordinator's
        released phase, adopt the new generation (fencing the old
        incarnation's in-flight frames), and drop any held step rounds
        from the dead generation."""
        gone = set(self.shard.live) - set(c["live"]) - {self.pid}
        self.shard.rebuild(c["live"], c["demoted"], c["phase"], c["gen"])
        self.gen = c["gen"]
        self._red_held = [f for f in self._red_held
                          if f[2][0] == self.gen]
        self._deferred.clear()   # old-gen envs would be fenced anyway
        # tear down sessions to the evicted peers: unacked ring frames
        # are reaped (their spans close as blackholed) instead of being
        # replayed at a corpse forever
        fp = getattr(self.endpoint, "forget_peer", None)
        if fp is not None:
            for pid in gone:
                fp(pid)
        self.metrics.inc("failure.force_evict")
        return {"gen": self.gen, "phase": c["phase"],
                "live": sorted(self.shard.live)}

    def _op_step_status(self, c):
        """Post-crash consistency probe: which train step this host
        last applied (and its metrics) — the coordinator uses this to
        decide between retrying the step and falling back to a
        checkpoint-consistent resume."""
        return dict(self._applied)

    def hold_red(self, frame) -> None:
        """A peer's reduction round arriving outside our step (worker
        main loop or a status pump): held for the next step's recv."""
        self._red_held.append(frame)

    def _op_status(self, c):
        self.shard.pump()
        for f in self.shard.drain_stray():
            if f[1] == "cmd":
                # raced-in (possibly retransmitted) command: defer to the
                # worker main loop, which dedupes by command id.
                self._deferred.append(f)
            else:
                self.hold_red(f)
        sent, received = self.shard.flight_counters()
        return {"idle": self.shard.net.idle(), "sent": sent,
                "received": received,
                "released": self.shard.released(),
                "max_depth": self.shard.net.max_depth,
                "messages": dict(self.shard.net.sent)}

    def _op_obs(self, c):
        """Drain this shard's span records + metrics snapshot (the
        coordinator collects after every quiescent advance)."""
        return {"spans": self.shard.drain_obs(),
                "metrics": self.metrics.snapshot(),
                "watermarks": self.shard.watermarks.snapshot(),
                "frames": {"sent": self.endpoint.frames_sent,
                           "received": self.endpoint.frames_received}}

    def _op_link_fault(self, c):
        """Install a link-fault window (chaos): each endpoint computes
        its own local wall-clock window from ``dur`` at receipt — no
        shared clock — and auto-heals when it expires, so a heal never
        depends on reaching anyone through the partition."""
        alf = getattr(self.endpoint, "add_link_fault", None)
        if alf is None:
            return {"installed": False}
        # activation grace: the window must not swallow this very
        # command's reply (or the installing RPC degenerates into a
        # wait-for-heal), so it starts a beat after the rep escapes
        now = time.monotonic() + 0.15
        alf(c["a"], c["b"], now, now + float(c["dur"]),
            oneway=bool(c.get("oneway", False)))
        return {"installed": True}

    def _op_link_clear(self, c):
        clf = getattr(self.endpoint, "clear_link_faults", None)
        if clf is not None:
            clf()

    def _op_inject_reset(self, c):
        """Hard-close cached outbound streams (chaos reset storm)."""
        ir = getattr(self.endpoint, "inject_reset", None)
        hit = 0
        if ir is not None:
            for dst in c.get("dsts", []):
                hit += bool(ir(dst))
        return {"reset": hit}

    def _op_flight_flush(self, c):
        """Flush this shard's flight ring to disk (coordinator asks at
        failure edges: cooperative leave, and on every survivor after a
        non-cooperative eviction)."""
        from ..obs.recorder import flight_path
        path = c.get("path") or flight_path(c["dir"], self.pid)
        n = self.shard.flight.flush(path, c.get("reason", "request"))
        return {"path": path, "records": n}

    def _op_derive_epoch(self, c):
        """Boundary: install the membership view, verify this shard's
        partition against the global oracle, fingerprint, and re-commit
        the process-level program cache."""
        self.shard.note_membership(c["live"], c["demoted"])
        sl = self.shard.oracle()
        views = sl.partition(self.shard.owner_of)
        view = views.get(self.pid)
        if view is not None:
            for lid in (SCSL, SNSL):
                d = view.diff(self.shard.local_states(lid))
                assert not d, f"pid {self.pid} lid {lid}: {d}"
        out = {"fingerprint": sl.fingerprint(), "epoch": c.get("index")}
        if self.data_cfg is not None and self.pid in self.shard.live:
            dp = self._data_plane()
            pc = self._proc_collective()
            dp["cache"].get(pc)            # re-lower this host's slice
            out["cache"] = dp["cache"].stats()
            out["program_key"] = self.program_key()
        return out

    # ------------------------------------------------------------ stepping
    def _op_step_local(self, c):
        """Local half: per-device grads + local reduce -> flat buffer."""
        import jax.numpy as jnp
        import numpy as np
        dp = self._data_plane()
        t0 = time.perf_counter()
        prog = dp["cache"].get(self._proc_collective())
        params = prog._replicated(dp["params"])
        opt_state = prog._replicated(dp["opt_state"])
        batch = self._local_batch(c["step"])
        alive = jnp.ones((dp["m"],), jnp.float32)
        flat, pm = prog.local_grads(params, opt_state, batch, alive)
        dp["params"], dp["opt_state"] = params, opt_state
        dp["pending"] = {"prog": prog, "t0": t0,
                         "loss": float(np.asarray(pm["loss"]).sum()
                                       / dp["m"])}
        return {"buf": np.asarray(flat)}

    def _op_step_apply(self, c):
        """Global half: apply the fully-reduced buffer."""
        import jax.numpy as jnp
        import numpy as np
        dp = self._data_plane()
        pend = dp["pending"]
        assert pend is not None, "step_apply without step_local"
        dp["pending"] = None
        prog = pend["prog"]
        new_p, new_o, om = prog.apply(dp["params"], dp["opt_state"],
                                      jnp.asarray(c["buf"]))
        dp["params"], dp["opt_state"] = new_p, new_o
        if c.get("delay"):
            time.sleep(c["delay"])   # test hook: straggling process
        dt = time.perf_counter() - pend["t0"]
        self.metrics.observe("agent.step_seconds", dt)
        self.shard.watermarks.add_compute_time(self.pid, dt)
        self.shard.flight.event("step", step=int(c.get("step", -1)),
                                dt=round(dt, 6))
        out = {"loss": pend["loss"], "dt": dt,
               "gnorm": float(np.asarray(om.get("gnorm", 0.0)))}
        self._applied = {"step": int(c.get("step", -1)), **out}
        return out

    def _op_step(self, c):
        """Whole step with peer-to-peer exchange over the transport
        (socket mode): local grads, the process-level schedule's rounds
        as real frames between the live processes, then apply. Round
        frames carry the membership generation so a step retried after
        crash recovery can never consume a dead incarnation's rounds;
        a coordinator ``ctl`` abort (or the recv deadline) unwinds the
        exchange into an ``aborted`` reply instead of a 300 s hang."""
        import numpy as np
        from .exchange import exchange_schedule
        local = self._op_step_local(c)
        dp = self._data_plane()
        prog = dp["pending"]["prog"]
        pids = list(prog.pc_proc.keys)
        rank = pids.index(self.pid)
        step = c["step"]
        gen = self.gen

        class _StepAbort(Exception):
            pass

        def send(dst, rnd, arr):
            try:
                self.endpoint.send(dst, "red", (gen, step, rnd, arr))
            except (OSError, ConnectionError):
                # peer died mid-step: unwind; the coordinator resolves
                self.metrics.inc("step.send_failed")
                raise _StepAbort("peer send failed")

        def match(payload, src, rnd):
            return (payload[0] == gen and payload[1] == step
                    and payload[2] == rnd)

        def recv(src, rnd):
            for i, f in enumerate(self._red_held):
                if f[0] == src and match(f[2], src, rnd):
                    return self._red_held.pop(i)[2][3]
            deadline = time.monotonic() + c.get("timeout", 300.0)
            while True:
                frame = self.endpoint.recv(timeout=0.2)
                if frame is None:
                    if time.monotonic() >= deadline:
                        raise _StepAbort(f"no round {rnd} from {src}")
                    continue
                fsrc, tag, payload = frame
                if tag == "red":
                    if payload[0] != gen or payload[1] < step:
                        self.metrics.inc("step.stale_red")   # fenced
                    elif fsrc == src and match(payload, src, rnd):
                        return payload[3]
                    else:
                        self._red_held.append(frame)
                elif tag == "ctl":
                    kind = payload[0]
                    if kind == "abort_step" and payload[1] >= step:
                        raise _StepAbort("coordinator abort")
                    # stale abort for an older step: ignore
                elif tag == "env":
                    # stray protocol frame waits until the step ends
                    self._deferred.append(frame)
                elif tag == "cmd":
                    # a retried command while we're mid-step: the reply
                    # the main loop already sent was dropped; park the
                    # frame so the main loop's dedupe cache replays it
                    self._deferred.append(frame)

        try:
            buf = exchange_schedule(prog.proc_schedule, rank, pids,
                                    local["buf"], send=send, recv=recv,
                                    metrics=self.metrics)
        except _StepAbort as e:
            dp["pending"] = None
            self.metrics.inc("step.aborted")
            return {"aborted": True, "step": step, "reason": str(e)}
        return self._op_step_apply({**c, "buf": buf})

    def drain_deferred(self) -> List:
        out, self._deferred = self._deferred, []
        return out

    # --------------------------------------------------------- checkpointing
    def _op_save(self, c):
        dp = self._data_plane()
        assert dp["ckpt"] is not None, "no ckpt_dir configured"
        dp["ckpt"].save(c["step"], dp["params"], dp["opt_state"],
                        extra={"process_set": sorted(self.shard.live)},
                        program_key=self.program_key())
        return {"step": c["step"]}

    def _op_precompile(self, c):
        """Resume pre-compile from a manifest program key: build the
        program for the key's *process set* — the surviving hosts —
        before the first step touches the cache."""
        from ..core.collective import PhaserCollective
        dp = self._data_plane()
        pk = c["program_key"]
        pc = PhaserCollective(len(pk["process_set"]), pk["axis"],
                              kind=pk["kind"], seed=pk["seed"],
                              p=pk["p"],
                              keys=tuple(pk["process_set"]),
                              leaf_keys=tuple(pk.get("leaf_keys", ())))
        before = dp["cache"].stats()["misses"]
        prog = dp["cache"].get(pc)
        return {"compiled": dp["cache"].stats()["misses"] > before,
                "keys": list(prog.pc_proc.keys)}

    def _op_manifest_key(self, c):
        """Read the program key recorded in the checkpoint manifest —
        the process set that was live at save time, i.e. the program a
        resume must pre-compile (manifest-only, no array reads)."""
        dp = self._data_plane()
        assert dp["ckpt"] is not None, "no ckpt_dir configured"
        return {"program_key": dp["ckpt"].program_key(c.get("step")),
                "step": c.get("step", dp["ckpt"].latest_step())}

    def _op_restore(self, c):
        dp = self._data_plane()
        assert dp["ckpt"] is not None, "no ckpt_dir configured"
        from ..optim import OptState
        tpl = {"params": dp["params"], "opt": dp["opt_state"]._asdict()}
        step, tree, extra = dp["ckpt"].restore(tpl, c.get("step"))
        dp["params"] = tree["params"]
        dp["opt_state"] = OptState(**tree["opt"])
        return {"step": step, "extra": extra}

    def _op_loss_probe(self, c):
        """Deterministic probe: loss of the current params on a fixed
        batch — equal across processes iff params stayed replicated."""
        import numpy as np
        dp = self._data_plane()
        from ..data.synthetic import make_batch
        b = make_batch(dp["cfg"].vocab_size,
                       self.data_cfg.get("batch", 4),
                       self.data_cfg.get("seq", 64),
                       seed=c.get("seed", 7), step=c.get("step", 0))
        loss, _ = dp["api"].loss_fn(dp["params"], b)
        return {"loss": float(np.asarray(loss))}

    def _op_shutdown(self, c):
        return {"bye": True}
