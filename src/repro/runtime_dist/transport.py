"""Message transports for the multi-process control plane.

The phaser protocol only assumes point-to-point FIFO channels
(``core/runtime.py``); crossing a process boundary therefore needs just
one primitive: an ordered, typed frame stream between two process ids.
Two fabrics provide it:

* ``InprocFabric``  — N *logical* processes inside one OS process, with
  instant delivery into per-endpoint deques. Deterministic (no threads,
  no sockets), so tier-1 tests drive real partitioned control-plane
  code without subprocess machinery.
* ``SocketFabric``  — real OS processes over ``multiprocessing
  .connection`` AF_UNIX sockets. Every endpoint owns a listener at a
  path derived from its pid, so the address book is implicit: any
  process can reach any other from ``(directory, pid)`` alone —
  arrivals (elastic joins) need no address gossip. Connections are
  lazy and unidirectional (one per ordered (src, dst) pair, preserving
  the per-channel FIFO the protocol assumes); a reader thread per
  connection feeds one inbound queue.

Frames are ``(src, tag, payload)``; tags in use: ``"env"`` (a protocol
``Envelope``), ``"cmd"``/``"rep"`` (coordinator RPC), ``"red"``
(data-plane reduction buffers), ``"hello"`` (stream header).
"""
from __future__ import annotations

import os
import queue
import tempfile
import threading
import time
from collections import deque
from typing import Any, Dict, Optional, Tuple

Frame = Tuple[int, str, Any]  # (src pid, tag, payload)


class Endpoint:
    """One process's port on a fabric."""

    def __init__(self, pid: int):
        self.pid = pid
        self.frames_sent = 0
        self.frames_received = 0

    def send(self, dst: int, tag: str, payload: Any) -> None:
        raise NotImplementedError

    def recv(self, timeout: Optional[float] = None) -> Optional[Frame]:
        """Next inbound frame, or None on timeout (timeout=0: poll)."""
        raise NotImplementedError

    def close(self) -> None:
        pass


# ---------------------------------------------------------------------------
# In-process fabric (deterministic, single-threaded)
# ---------------------------------------------------------------------------
class InprocEndpoint(Endpoint):
    def __init__(self, pid: int, fabric: "InprocFabric"):
        super().__init__(pid)
        self.fabric = fabric
        self.inbox: deque = deque()

    def send(self, dst: int, tag: str, payload: Any) -> None:
        ep = self.fabric.endpoints.get(dst)
        assert ep is not None, f"send to unknown pid {dst}"
        self.frames_sent += 1
        ep.inbox.append((self.pid, tag, payload))

    def recv(self, timeout: Optional[float] = None) -> Optional[Frame]:
        if not self.inbox:
            return None  # same thread: nothing can arrive while we wait
        self.frames_received += 1
        return self.inbox.popleft()


class InprocFabric:
    """All endpoints share one OS process; delivery is an append."""

    def __init__(self):
        self.endpoints: Dict[int, InprocEndpoint] = {}

    def endpoint(self, pid: int) -> InprocEndpoint:
        assert pid not in self.endpoints, pid
        ep = InprocEndpoint(pid, self)
        self.endpoints[pid] = ep
        return ep

    def drop_endpoint(self, pid: int) -> None:
        self.endpoints.pop(pid, None)

    def pending(self) -> int:
        return sum(len(ep.inbox) for ep in self.endpoints.values())


# ---------------------------------------------------------------------------
# Socket fabric (real processes)
# ---------------------------------------------------------------------------
def fabric_dir() -> str:
    return tempfile.mkdtemp(prefix="phaser-fabric-")


def _sock_path(directory: str, pid: int) -> str:
    return os.path.join(directory, f"ep{pid}.sock")


class SocketEndpoint(Endpoint):
    """AF_UNIX endpoint: own listener + lazy outbound connections."""

    def __init__(self, pid: int, directory: str):
        super().__init__(pid)
        from multiprocessing.connection import Listener
        self.directory = directory
        self.path = _sock_path(directory, pid)
        self._listener = Listener(self.path, "AF_UNIX")
        self._inbox: "queue.Queue[Frame]" = queue.Queue()
        self._out: Dict[int, Any] = {}
        self._closed = False
        self._accept_thread = threading.Thread(target=self._accept_loop,
                                               daemon=True)
        self._accept_thread.start()

    # -- inbound ------------------------------------------------------------
    def _accept_loop(self) -> None:
        while not self._closed:
            try:
                conn = self._listener.accept()
            except (OSError, EOFError):
                return
            threading.Thread(target=self._read_loop, args=(conn,),
                             daemon=True).start()

    def _read_loop(self, conn) -> None:
        try:
            tag, payload = conn.recv()
            assert tag == "hello", tag
            src = payload
            while True:
                tag, payload = conn.recv()
                self._inbox.put((src, tag, payload))
        except (EOFError, OSError):
            pass
        finally:
            try:
                conn.close()
            except OSError:
                pass

    def recv(self, timeout: Optional[float] = None) -> Optional[Frame]:
        try:
            if timeout == 0:
                frame = self._inbox.get_nowait()
            else:
                frame = self._inbox.get(timeout=timeout)
        except queue.Empty:
            return None
        self.frames_received += 1
        return frame

    # -- outbound -----------------------------------------------------------
    def _connect(self, dst: int, timeout: float = 30.0):
        from multiprocessing.connection import Client
        path = _sock_path(self.directory, dst)
        deadline = time.monotonic() + timeout
        while True:
            try:
                conn = Client(path, "AF_UNIX")
                break
            except (FileNotFoundError, ConnectionRefusedError):
                if time.monotonic() > deadline:
                    raise TimeoutError(f"pid {self.pid}: no listener for "
                                       f"pid {dst} at {path}")
                time.sleep(0.01)
        conn.send(("hello", self.pid))
        return conn

    def send(self, dst: int, tag: str, payload: Any) -> None:
        conn = self._out.get(dst)
        if conn is None:
            conn = self._connect(dst)
            self._out[dst] = conn
        conn.send((tag, payload))
        self.frames_sent += 1

    def forget_peer(self, dst: int) -> None:
        """Drop the cached outbound connection (evicted process)."""
        conn = self._out.pop(dst, None)
        if conn is not None:
            try:
                conn.close()
            except OSError:
                pass

    def close(self) -> None:
        self._closed = True
        try:
            self._listener.close()
        except OSError:
            pass
        for dst in list(self._out):
            self.forget_peer(dst)
        try:
            os.unlink(self.path)
        except OSError:
            pass
