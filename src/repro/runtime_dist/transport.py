"""Message transports for the multi-process control plane.

The phaser protocol only assumes point-to-point FIFO channels
(``core/runtime.py``); crossing a process boundary therefore needs just
one primitive: an ordered, typed frame stream between two process ids.
Two fabrics provide it:

* ``InprocFabric``  — N *logical* processes inside one OS process, with
  instant delivery into per-endpoint deques. Deterministic (no threads,
  no sockets), so tier-1 tests drive real partitioned control-plane
  code without subprocess machinery.
* ``SocketFabric``  — real OS processes over ``multiprocessing
  .connection`` AF_UNIX sockets. Every endpoint owns a listener at a
  path derived from its pid, so the address book is implicit: any
  process can reach any other from ``(directory, pid)`` alone —
  arrivals (elastic joins) need no address gossip. Connections are
  lazy and unidirectional (one per ordered (src, dst) pair, preserving
  the per-channel FIFO the protocol assumes); a reader thread per
  connection feeds one inbound queue.

Frames are ``(src, tag, payload)``; tags in use: ``"env"`` (a protocol
``Envelope``), ``"cmd"``/``"rep"`` (coordinator RPC), ``"red"``
(data-plane reduction buffers), ``"hb"`` (heartbeat, echoed by the
reader thread), ``"ctl"`` (out-of-band step control, e.g. abort),
``"hello"`` (stream header).

Chaos layer (DESIGN.md §13): ``ChaosConfig`` + ``FaultyInprocFabric`` /
``FaultyEndpoint`` decorate the two fabrics with a *seeded, per-(src,
dst)* fault policy. Faults are injected only where a recovery mechanism
exists for them:

* RPC frames (``cmd``/``rep``/``hb``) may be dropped or duplicated —
  retry with idempotent command ids recovers both;
* protocol envelopes (``env``) may be *delayed and reordered across
  channels* but never dropped or duplicated within a live channel: the
  protocol's SIG counting has no retransmission and is not
  duplication-safe, and per-(src, dst) FIFO is its only ordering
  assumption — so injection queues later frames of a delayed channel
  behind the delayed head (FIFO preserved end to end), and only frames
  addressed to a *dead* endpoint are dropped (counted, and their spans
  closed as blackholed through the ``reaper`` hook);
* hard crash: ``SocketCluster.kill_pid`` (SIGKILL, no cleanup) and
  ``InprocCluster.kill_host`` (simulated crash-stop).

Every injected fault lands in the metrics registry / fault counters so
it stays attributable next to the span traces.
"""
from __future__ import annotations

import os
import queue
import random
import tempfile
import threading
import time
from collections import defaultdict, deque
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

from .failure import PeerUnreachable

Frame = Tuple[int, str, Any]  # (src pid, tag, payload)

# tags a retry + idempotency layer recovers: safe to drop/duplicate
RPC_TAGS = ("cmd", "rep", "hb")


@dataclass(frozen=True)
class ChaosConfig:
    """Seeded fault policy; every rate is per-frame, per ordered
    (src, dst) channel (each channel owns a derived rng, so one
    channel's draws never perturb another's — runs are reproducible
    under membership churn)."""

    seed: int = 0
    p_drop: float = 0.05      # RPC frames only
    p_dup: float = 0.02       # RPC frames only
    p_delay: float = 0.2      # env frames: probability of entering limbo
    delay_ticks: int = 3      # inproc: max extra delivery ticks
    max_delay: float = 0.05   # socket: max extra seconds in limbo

    def rng(self, src: int, dst: int) -> random.Random:
        return random.Random((self.seed * 1_000_003
                              + (src + 7) * 8191 + (dst + 7)) & 0x7FFFFFFF)


# ---------------------------------------------------------------------------
class Endpoint:
    """One process's port on a fabric."""

    def __init__(self, pid: int):
        self.pid = pid
        self.frames_sent = 0
        self.frames_received = 0

    def send(self, dst: int, tag: str, payload: Any) -> None:
        raise NotImplementedError

    def recv(self, timeout: Optional[float] = None) -> Optional[Frame]:
        """Next inbound frame, or None on timeout (timeout=0: poll)."""
        raise NotImplementedError

    def close(self) -> None:
        pass


# ---------------------------------------------------------------------------
# In-process fabric (deterministic, single-threaded)
# ---------------------------------------------------------------------------
class InprocEndpoint(Endpoint):
    def __init__(self, pid: int, fabric: "InprocFabric"):
        super().__init__(pid)
        self.fabric = fabric
        self.inbox: deque = deque()

    def send(self, dst: int, tag: str, payload: Any) -> None:
        self.frames_sent += 1
        self.fabric.transmit(self.pid, dst, tag, payload)

    def recv(self, timeout: Optional[float] = None) -> Optional[Frame]:
        if not self.inbox:
            return None  # same thread: nothing can arrive while we wait
        self.frames_received += 1
        return self.inbox.popleft()


class InprocFabric:
    """All endpoints share one OS process; delivery is an append."""

    def __init__(self):
        self.endpoints: Dict[int, InprocEndpoint] = {}
        self.removed: set = set()         # pids that once had an endpoint
        self.faults: Dict[str, int] = defaultdict(int)
        # span-close hook for frames swallowed at the fabric (dead
        # destination): the coordinator wires this to its tracer so the
        # causal tree never dangles
        self.reaper: Optional[Callable[[Any, str], Any]] = None

    def endpoint(self, pid: int) -> InprocEndpoint:
        assert pid not in self.endpoints, pid
        ep = InprocEndpoint(pid, self)
        self.endpoints[pid] = ep
        self.removed.discard(pid)
        return ep

    def drop_endpoint(self, pid: int) -> None:
        if self.endpoints.pop(pid, None) is not None:
            self.removed.add(pid)

    def _reap(self, tag: str, payload: Any) -> None:
        self.faults["dead_dropped"] += 1
        if self.reaper is not None:
            self.reaper(payload, tag)

    def transmit(self, src: int, dst: int, tag: str, payload: Any) -> None:
        ep = self.endpoints.get(dst)
        if ep is None:
            # crash-stop semantics: frames to a dead host vanish —
            # counted, never raised (the sender may not know yet)
            assert dst in self.removed, f"send to unknown pid {dst}"
            self._reap(tag, payload)
            return
        ep.inbox.append((src, tag, payload))

    def pending(self) -> int:
        return sum(len(ep.inbox) for ep in self.endpoints.values())

    def tick(self) -> int:
        return 0    # no time-based state in the fault-free fabric


class FaultyInprocFabric(InprocFabric):
    """Seeded delay/reorder-across-channels for the in-process fabric.

    Only ``env`` frames ride this fabric (in-proc RPC is a direct
    call), so the injected fault is exactly the one the protocol must
    tolerate: a channel's frames go into *limbo* for a bounded number
    of delivery ticks, later frames on the same channel queue behind
    the delayed head (per-channel FIFO preserved), while other
    channels' frames overtake freely. Deterministic in (seed, traffic).
    """

    def __init__(self, chaos: ChaosConfig):
        super().__init__()
        self.chaos = chaos
        self._rngs: Dict[Tuple[int, int], random.Random] = {}
        # (src, dst) -> deque of [release_tick, tag, payload]
        self.limbo: Dict[Tuple[int, int], deque] = defaultdict(deque)
        self._tick = 0

    def _rng(self, src: int, dst: int) -> random.Random:
        key = (src, dst)
        if key not in self._rngs:
            self._rngs[key] = self.chaos.rng(src, dst)
        return self._rngs[key]

    def transmit(self, src: int, dst: int, tag: str, payload: Any) -> None:
        self._tick += 1
        ch = (src, dst)
        q = self.limbo[ch]
        rng = self._rng(src, dst)
        delay = rng.random() < self.chaos.p_delay
        if q or delay:
            release = self._tick + (rng.randint(1, self.chaos.delay_ticks)
                                    if delay else 0)
            if q:
                release = max(release, q[-1][0])   # never overtake the head
            q.append([release, tag, payload])
            self.faults["delayed"] += 1
        else:
            super().transmit(src, dst, tag, payload)
        self._release_due()

    def _release_due(self) -> int:
        n = 0
        for ch in sorted(k for k, q in self.limbo.items() if q):
            q = self.limbo[ch]
            while q and q[0][0] <= self._tick:
                _, tag, payload = q.popleft()
                super().transmit(ch[0], ch[1], tag, payload)
                n += 1
                self.faults["released"] += 1
        return n

    def tick(self) -> int:
        """Advance fabric time without traffic (quiescence driver):
        limbo frames come due even when nobody is sending."""
        self._tick += 1
        return self._release_due()

    def drop_endpoint(self, pid: int) -> None:
        super().drop_endpoint(pid)
        for ch in list(self.limbo):
            if ch[1] == pid:
                for _, tag, payload in self.limbo.pop(ch):
                    self._reap(tag, payload)

    def pending(self) -> int:
        return super().pending() + sum(len(q) for q in self.limbo.values())


# ---------------------------------------------------------------------------
# Socket fabric (real processes)
# ---------------------------------------------------------------------------
def fabric_dir() -> str:
    return tempfile.mkdtemp(prefix="phaser-fabric-")


def _sock_path(directory: str, pid: int) -> str:
    return os.path.join(directory, f"ep{pid}.sock")


class SocketEndpoint(Endpoint):
    """AF_UNIX endpoint: own listener + lazy outbound connections.

    ``hb_echo=True`` (worker side) makes the *reader thread* echo
    heartbeat frames back to their source — liveness is then a
    transport property, independent of how long the main loop spends
    inside a command (a multi-second jax compile must not look like a
    death), while a SIGKILL stops the reader and therefore the echoes.
    ``last_rx`` timestamps every arrival, so an orphaned worker can
    notice its coordinator went silent.
    """

    def __init__(self, pid: int, directory: str, *, metrics=None,
                 hb_echo: bool = False):
        super().__init__(pid)
        from multiprocessing.connection import Listener
        self.directory = directory
        self.path = _sock_path(directory, pid)
        self.metrics = metrics
        self.hb_echo = hb_echo
        self.last_rx = time.monotonic()
        self._listener = Listener(self.path, "AF_UNIX")
        self._inbox: "queue.Queue[Frame]" = queue.Queue()
        self._out: Dict[int, Any] = {}
        self._ever: set = set()          # dsts we once connected to
        self._down: Dict[int, float] = {}  # dst -> last connect failure
        self._down_ttl = 1.0
        self._locks: Dict[int, threading.Lock] = {}
        self._locks_guard = threading.Lock()
        self._closed = False
        self._accept_thread = threading.Thread(target=self._accept_loop,
                                               daemon=True)
        self._accept_thread.start()

    def _inc(self, name: str) -> None:
        if self.metrics is not None:
            self.metrics.inc(name)

    # -- inbound ------------------------------------------------------------
    def _accept_loop(self) -> None:
        while not self._closed:
            try:
                conn = self._listener.accept()
            except (OSError, EOFError):
                return
            threading.Thread(target=self._read_loop, args=(conn,),
                             daemon=True).start()

    def _read_loop(self, conn) -> None:
        try:
            tag, payload = conn.recv()
            assert tag == "hello", tag
            src = payload
            while True:
                tag, payload = conn.recv()
                self.last_rx = time.monotonic()
                if tag == "hb" and self.hb_echo:
                    # echo from the reader thread: never blocks on the
                    # main loop, dies with the process on SIGKILL
                    try:
                        self.send(src, "hb", payload)
                    except (PeerUnreachable, OSError):
                        pass          # coordinator gone: orphan timer runs
                    continue
                self._inbox.put((src, tag, payload))
        except (EOFError, OSError):
            pass
        finally:
            try:
                conn.close()
            except OSError:
                pass

    def recv(self, timeout: Optional[float] = None) -> Optional[Frame]:
        try:
            if timeout == 0:
                frame = self._inbox.get_nowait()
            else:
                frame = self._inbox.get(timeout=timeout)
        except queue.Empty:
            return None
        self.frames_received += 1
        return frame

    # -- outbound -----------------------------------------------------------
    def _lock_for(self, dst: int) -> threading.Lock:
        with self._locks_guard:
            if dst not in self._locks:
                self._locks[dst] = threading.Lock()
            return self._locks[dst]

    def _connect(self, dst: int, timeout: float = 30.0):
        """Exponential backoff + jitter up to ``timeout``; raises a
        structured ``PeerUnreachable`` (not a bare TimeoutError) so
        callers can attribute the failure to a pid. A *re*connect (the
        peer was reachable before, so a refusal means it died, not
        that it is still booting) gets a short deadline, and a recent
        failure short-circuits entirely — a signal fan-out to a dead
        peer must not stall the survivor once per frame."""
        from multiprocessing.connection import Client
        down_at = self._down.get(dst)
        if down_at is not None:
            if time.monotonic() - down_at < self._down_ttl:
                self._inc("transport.connect_shortcircuit")
                raise PeerUnreachable(dst, 0, 0.0)
            self._down.pop(dst, None)
        if dst in self._ever:
            timeout = min(timeout, 1.0)
        path = _sock_path(self.directory, dst)
        t0 = time.monotonic()
        deadline = t0 + timeout
        attempts = 0
        delay = 0.005
        rng = random.Random((self.pid + 7) * 131 + dst)
        while True:
            attempts += 1
            self._inc("transport.connect_attempts")
            try:
                conn = Client(path, "AF_UNIX")
                break
            except (FileNotFoundError, ConnectionRefusedError, OSError):
                now = time.monotonic()
                if now > deadline:
                    self._inc("transport.connect_failures")
                    self._down[dst] = now
                    raise PeerUnreachable(dst, attempts, now - t0)
                time.sleep(min(delay * (1 + rng.random()),
                               max(0.0, deadline - now)))
                delay = min(delay * 1.6, 0.25)
        conn.send(("hello", self.pid))
        self._ever.add(dst)
        return conn

    def send(self, dst: int, tag: str, payload: Any) -> None:
        # per-destination lock: the heartbeat thread and the main loop
        # share outbound connections, and Connection.send is not atomic
        with self._lock_for(dst):
            conn = self._out.get(dst)
            if conn is None:
                # heartbeats are periodic: fail one fast rather than
                # let a dead peer starve the hb thread's round
                conn = self._connect(dst, timeout=(0.2 if tag == "hb"
                                                   else 30.0))
                self._out[dst] = conn
            try:
                conn.send((tag, payload))
            except (OSError, ValueError):
                # broken pipe (peer died): drop the cached conn so a
                # retry reconnects, surface the failure to the caller
                self._out.pop(dst, None)
                try:
                    conn.close()
                except OSError:
                    pass
                self._inc("transport.send_failures")
                raise
        self.frames_sent += 1

    def forget_peer(self, dst: int) -> None:
        """Drop the cached outbound connection (evicted process)."""
        with self._lock_for(dst):
            conn = self._out.pop(dst, None)
        if conn is not None:
            try:
                conn.close()
            except OSError:
                pass

    def close(self) -> None:
        self._closed = True
        try:
            self._listener.close()
        except OSError:
            pass
        for dst in list(self._out):
            self.forget_peer(dst)
        try:
            os.unlink(self.path)
        except OSError:
            pass


class FaultyEndpoint(Endpoint):
    """Chaos decorator over any endpoint (installed on the coordinator's
    socket endpoint). Faults by tag class:

    * send side: ``cmd``/``hb`` frames dropped or duplicated per the
      seeded channel rng (retry + worker-side cid dedupe recover);
    * recv side: ``rep`` frames dropped (reply lost -> retry) or
      re-delivered (coordinator ignores cids it no longer awaits);
      ``env`` frames held in per-source limbo for a bounded wall-clock
      delay — later frames of the same source queue behind the held
      head, so per-channel FIFO survives while channels reorder.
    """

    def __init__(self, inner: Endpoint, chaos: ChaosConfig, metrics=None):
        super().__init__(inner.pid)
        self.inner = inner
        self.chaos = chaos
        self.metrics = metrics
        self._rngs: Dict[Tuple[int, int], random.Random] = {}
        self._held: Dict[int, deque] = defaultdict(deque)  # src -> frames
        self._redeliver: deque = deque()

    def _inc(self, name: str) -> None:
        if self.metrics is not None:
            self.metrics.inc(name)

    def _rng(self, src: int, dst: int) -> random.Random:
        key = (src, dst)
        if key not in self._rngs:
            self._rngs[key] = self.chaos.rng(src, dst)
        return self._rngs[key]

    # -- passthrough surface -------------------------------------------------
    @property
    def last_rx(self):
        return getattr(self.inner, "last_rx", 0.0)

    def forget_peer(self, dst: int) -> None:
        fp = getattr(self.inner, "forget_peer", None)
        if fp is not None:
            fp(dst)

    def close(self) -> None:
        self.inner.close()

    # -- faulted send/recv ---------------------------------------------------
    def send(self, dst: int, tag: str, payload: Any) -> None:
        if tag in ("cmd", "hb"):
            rng = self._rng(self.pid, dst)
            if rng.random() < self.chaos.p_drop:
                self._inc(f"chaos.drop_{tag}")
                return
            if rng.random() < self.chaos.p_dup:
                self._inc(f"chaos.dup_{tag}")
                self.inner.send(dst, tag, payload)
        self.inner.send(dst, tag, payload)
        self.frames_sent += 1

    def _due(self) -> Optional[Frame]:
        if self._redeliver:
            return self._redeliver.popleft()
        now = time.monotonic()
        for src in sorted(s for s, q in self._held.items() if q):
            q = self._held[src]
            if q[0][0] <= now:
                self._inc("chaos.release_env")
                return q.popleft()[1]
        return None

    def _filter(self, frame: Frame) -> Optional[Frame]:
        src, tag, payload = frame
        rng = self._rng(src, self.pid)
        if tag == "rep":
            if rng.random() < self.chaos.p_drop:
                self._inc("chaos.drop_rep")
                return None
            if rng.random() < self.chaos.p_dup:
                self._inc("chaos.dup_rep")
                self._redeliver.append(frame)
            return frame
        if tag == "env":
            q = self._held[src]
            if q or rng.random() < self.chaos.p_delay:
                due = time.monotonic() + rng.uniform(
                    0.0, self.chaos.max_delay)
                if q:
                    due = max(due, q[-1][0])   # FIFO within the channel
                q.append((due, frame))
                self._inc("chaos.delay_env")
                return None
            return frame
        return frame

    def recv(self, timeout: Optional[float] = None) -> Optional[Frame]:
        deadline = (None if timeout is None
                    else time.monotonic() + timeout)
        while True:
            due = self._due()
            if due is not None:
                self.frames_received += 1
                return due
            if timeout == 0:
                inner_t: Optional[float] = 0
            else:
                inner_t = 0.02
                if deadline is not None:
                    inner_t = min(inner_t,
                                  max(0.0, deadline - time.monotonic()))
            frame = self.inner.recv(timeout=inner_t)
            if frame is not None:
                out = self._filter(frame)
                if out is not None:
                    self.frames_received += 1
                    return out
                continue
            if timeout == 0:
                return None
            if deadline is not None and time.monotonic() >= deadline:
                return None
