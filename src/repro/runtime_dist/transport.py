"""Message transports for the multi-process control plane.

The phaser protocol only assumes point-to-point FIFO channels
(``core/runtime.py``); crossing a process boundary therefore needs just
one primitive: an ordered, typed frame stream between two process ids.
Two fabrics provide it:

* ``InprocFabric``  — N *logical* processes inside one OS process, with
  instant delivery into per-endpoint deques. Deterministic (no threads,
  no sockets), so tier-1 tests drive real partitioned control-plane
  code without subprocess machinery.
* ``SocketFabric``  — real OS processes over ``multiprocessing
  .connection`` AF_UNIX sockets. Every endpoint owns a listener at a
  path derived from its pid, so the address book is implicit: any
  process can reach any other from ``(directory, pid)`` alone —
  arrivals (elastic joins) need no address gossip. Connections are
  lazy and unidirectional (one per ordered (src, dst) pair, preserving
  the per-channel FIFO the protocol assumes); a reader thread per
  connection feeds one inbound queue.

Frames are ``(src, tag, payload)``; tags in use: ``"env"`` (a protocol
``Envelope``), ``"cmd"``/``"rep"`` (coordinator RPC), ``"red"``
(data-plane reduction buffers), ``"hb"`` (heartbeat, echoed by the
reader thread), ``"ctl"`` (out-of-band step control, e.g. abort),
``"hello"`` (stream header).

Session layer (DESIGN.md §15): the socket fabrics (AF_UNIX and TCP)
wrap every stream in a partition-tolerant session so the channel
abstraction above survives *connection* failure, not just process
failure. Per ordered (src, dst) channel: ``env`` frames carry monotone
sequence numbers and sit in a bounded resend ring until a cumulative
ack (piggybacked on every reverse frame, topped up by standalone
``ack`` frames) covers them; every frame is CRC-framed so a torn read
is dropped unparsed (and the stream cut, forcing a replay) instead of
deserialized; a (re)connect replays everything past the last acked
seq and the receiver dedupes by seq — exactly-once, in-order envelope
delivery re-established after any reset or healed partition. Counters:
``transport.session.{resets,replays,dupes_dropped,crc_drops,...}``.

``TcpEndpoint`` is the same machinery over AF_INET: each endpoint
binds an ephemeral TCP port and advertises ``host:port`` in a registry
file (``ep<pid>.addr``) in the fabric dir — the address book stays
derivable from ``(directory, pid)`` exactly like the AF_UNIX paths.

Chaos layer (DESIGN.md §13): ``ChaosConfig`` + ``FaultyInprocFabric`` /
``FaultyEndpoint`` decorate the two fabrics with a *seeded, per-(src,
dst)* fault policy. Faults are injected only where a recovery mechanism
exists for them:

* RPC frames (``cmd``/``rep``/``hb``) may be dropped or duplicated —
  retry with idempotent command ids recovers both;
* protocol envelopes (``env``) may be *delayed and reordered across
  channels* but never dropped or duplicated within a live channel: the
  protocol's SIG counting has no retransmission and is not
  duplication-safe, and per-(src, dst) FIFO is its only ordering
  assumption — so injection queues later frames of a delayed channel
  behind the delayed head (FIFO preserved end to end), and only frames
  addressed to a *dead* endpoint are dropped (counted, and their spans
  closed as blackholed through the ``reaper`` hook);
* link-level faults the RPC layer can't paper over: seeded connection
  resets (``p_reset``: the cached stream is torn down mid-traffic, the
  session layer must reconnect + replay) and ``LinkFault`` windows —
  symmetric partitions and one-way link kills between pid sets for a
  bounded wall-clock window, enforced at the *sender's* transmit edge
  (``chaos.link_blocked``), so a heal needs no connectivity to take
  effect;
* hard crash: ``SocketCluster.kill_pid`` (SIGKILL, no cleanup) and
  ``InprocCluster.kill_host`` (simulated crash-stop).

Every injected fault lands in the metrics registry / fault counters so
it stays attributable next to the span traces.
"""
from __future__ import annotations

import os
import pickle
import queue
import random
import struct
import tempfile
import threading
import time
import zlib
from collections import defaultdict, deque
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Tuple

from .failure import PeerUnreachable

Frame = Tuple[int, str, Any]  # (src pid, tag, payload)

# tags a retry + idempotency layer recovers: safe to drop/duplicate
RPC_TAGS = ("cmd", "rep", "hb")

# tags the session layer sequences, rings, replays and dedupes: the
# protocol envelopes, whose SIG counting is neither loss- nor
# duplication-safe, and step control — the ``ctl`` abort is what
# unwinds a worker blocked in an in-step exchange, so it must survive
# the very partition that caused the abort (a lost abort leaves the
# partitioned worker pinned on its in-step recv deadline, and the
# coordinator's resolve probe pinned behind it). RPC frames keep their
# own retry+cid-dedupe layer, ``red`` rounds their own step
# abort/retry, heartbeats are ephemeral.
SESSION_TAGS = ("env", "ctl")


@dataclass(frozen=True)
class ChaosConfig:
    """Seeded fault policy; every rate is per-frame, per ordered
    (src, dst) channel (each channel owns a derived rng, so one
    channel's draws never perturb another's — runs are reproducible
    under membership churn)."""

    seed: int = 0
    p_drop: float = 0.05      # RPC frames only
    p_dup: float = 0.02       # RPC frames only
    p_delay: float = 0.2      # env frames: probability of entering limbo
    delay_ticks: int = 3      # inproc: max extra delivery ticks
    max_delay: float = 0.05   # socket: max extra seconds in limbo
    p_reset: float = 0.0      # socket: per-frame connection reset (the
    #                           cached stream is hard-closed; the session
    #                           layer must reconnect and replay). Drawn
    #                           only when > 0, so existing seeds keep
    #                           their exact fault sequences.

    def rng(self, src: int, dst: int) -> random.Random:
        return random.Random((self.seed * 1_000_003
                              + (src + 7) * 8191 + (dst + 7)) & 0x7FFFFFFF)


@dataclass(frozen=True)
class LinkFault:
    """One link-level fault window: frames from ``a`` to ``b`` (and,
    unless ``oneway``, from ``b`` to ``a``) are blocked while
    ``t1 <= now < t2`` (``time.monotonic()``, evaluated locally at the
    enforcing endpoint — windows need no shared clock, each endpoint
    computes its own from the install moment)."""

    a: frozenset
    b: frozenset
    t1: float
    t2: float
    oneway: bool = False

    def blocks(self, src: int, dst: int, now: float) -> bool:
        if not (self.t1 <= now < self.t2):
            return False
        if src in self.a and dst in self.b:
            return True
        return (not self.oneway) and src in self.b and dst in self.a


def parse_link_spec(spec: str) -> List[Dict]:
    """``"1|0,2@3+1.5;0->2@5+0.5"`` -> fault dicts for the launcher.

    Each item is ``A|B@STEP+DUR`` (symmetric partition between pid sets
    A and B) or ``A->B@STEP+DUR`` (one-way link kill: A's frames to B
    are dropped, B's to A still flow). Pid sets are comma-separated
    ints (``-1``/``coord`` is the coordinator) or ``*`` = everyone
    else. The window activates at the STEP boundary and heals DUR
    seconds later — heal is a local timer at every endpoint, so it
    fires even while the partition blocks the control plane."""

    def pids(s: str):
        s = s.strip()
        if s == "*":
            return None                      # "everyone else"
        return sorted({-1 if x.strip() in ("coord", "-1") else int(x)
                       for x in s.split(",")})

    faults = []
    for item in spec.split(";"):
        item = item.strip()
        if not item:
            continue
        body, at = item.rsplit("@", 1)
        step_s, dur_s = at.split("+", 1)
        oneway = "->" in body
        a, b = body.split("->" if oneway else "|", 1)
        if pids(a) is None:
            raise ValueError(f"link fault {item!r}: '*' only on the "
                             "right side")
        faults.append({"a": pids(a), "b": pids(b), "step": int(step_s),
                       "dur": float(dur_s), "oneway": oneway})
    return faults


# ---------------------------------------------------------------------------
class Endpoint:
    """One process's port on a fabric."""

    def __init__(self, pid: int):
        self.pid = pid
        self.frames_sent = 0
        self.frames_received = 0

    def send(self, dst: int, tag: str, payload: Any) -> None:
        raise NotImplementedError

    def recv(self, timeout: Optional[float] = None) -> Optional[Frame]:
        """Next inbound frame, or None on timeout (timeout=0: poll)."""
        raise NotImplementedError

    def close(self) -> None:
        pass


# ---------------------------------------------------------------------------
# In-process fabric (deterministic, single-threaded)
# ---------------------------------------------------------------------------
class InprocEndpoint(Endpoint):
    def __init__(self, pid: int, fabric: "InprocFabric"):
        super().__init__(pid)
        self.fabric = fabric
        self.inbox: deque = deque()

    def send(self, dst: int, tag: str, payload: Any) -> None:
        self.frames_sent += 1
        self.fabric.transmit(self.pid, dst, tag, payload)

    def recv(self, timeout: Optional[float] = None) -> Optional[Frame]:
        if not self.inbox:
            return None  # same thread: nothing can arrive while we wait
        self.frames_received += 1
        return self.inbox.popleft()


class InprocFabric:
    """All endpoints share one OS process; delivery is an append."""

    def __init__(self):
        self.endpoints: Dict[int, InprocEndpoint] = {}
        self.removed: set = set()         # pids that once had an endpoint
        self.faults: Dict[str, int] = defaultdict(int)
        # span-close hook for frames swallowed at the fabric (dead
        # destination): the coordinator wires this to its tracer so the
        # causal tree never dangles
        self.reaper: Optional[Callable[[Any, str], Any]] = None

    def endpoint(self, pid: int) -> InprocEndpoint:
        assert pid not in self.endpoints, pid
        ep = InprocEndpoint(pid, self)
        self.endpoints[pid] = ep
        self.removed.discard(pid)
        return ep

    def drop_endpoint(self, pid: int) -> None:
        if self.endpoints.pop(pid, None) is not None:
            self.removed.add(pid)

    def _reap(self, tag: str, payload: Any) -> None:
        self.faults["dead_dropped"] += 1
        if self.reaper is not None:
            self.reaper(payload, tag)

    def transmit(self, src: int, dst: int, tag: str, payload: Any) -> None:
        ep = self.endpoints.get(dst)
        if ep is None:
            # crash-stop semantics: frames to a dead host vanish —
            # counted, never raised (the sender may not know yet)
            assert dst in self.removed, f"send to unknown pid {dst}"
            self._reap(tag, payload)
            return
        ep.inbox.append((src, tag, payload))

    def pending(self) -> int:
        return sum(len(ep.inbox) for ep in self.endpoints.values())

    def tick(self) -> int:
        return 0    # no time-based state in the fault-free fabric


class FaultyInprocFabric(InprocFabric):
    """Seeded delay/reorder-across-channels for the in-process fabric.

    Only ``env`` frames ride this fabric (in-proc RPC is a direct
    call), so the injected fault is exactly the one the protocol must
    tolerate: a channel's frames go into *limbo* for a bounded number
    of delivery ticks, later frames on the same channel queue behind
    the delayed head (per-channel FIFO preserved), while other
    channels' frames overtake freely. Deterministic in (seed, traffic).
    """

    def __init__(self, chaos: ChaosConfig):
        super().__init__()
        self.chaos = chaos
        self._rngs: Dict[Tuple[int, int], random.Random] = {}
        # (src, dst) -> deque of [release_tick, tag, payload]
        self.limbo: Dict[Tuple[int, int], deque] = defaultdict(deque)
        self._tick = 0

    def _rng(self, src: int, dst: int) -> random.Random:
        key = (src, dst)
        if key not in self._rngs:
            self._rngs[key] = self.chaos.rng(src, dst)
        return self._rngs[key]

    def transmit(self, src: int, dst: int, tag: str, payload: Any) -> None:
        self._tick += 1
        ch = (src, dst)
        q = self.limbo[ch]
        rng = self._rng(src, dst)
        delay = rng.random() < self.chaos.p_delay
        if q or delay:
            release = self._tick + (rng.randint(1, self.chaos.delay_ticks)
                                    if delay else 0)
            if q:
                release = max(release, q[-1][0])   # never overtake the head
            q.append([release, tag, payload])
            self.faults["delayed"] += 1
        else:
            super().transmit(src, dst, tag, payload)
        self._release_due()

    def _release_due(self) -> int:
        n = 0
        for ch in sorted(k for k, q in self.limbo.items() if q):
            q = self.limbo[ch]
            while q and q[0][0] <= self._tick:
                _, tag, payload = q.popleft()
                super().transmit(ch[0], ch[1], tag, payload)
                n += 1
                self.faults["released"] += 1
        return n

    def tick(self) -> int:
        """Advance fabric time without traffic (quiescence driver):
        limbo frames come due even when nobody is sending."""
        self._tick += 1
        return self._release_due()

    def drop_endpoint(self, pid: int) -> None:
        super().drop_endpoint(pid)
        for ch in list(self.limbo):
            if ch[1] == pid:
                for _, tag, payload in self.limbo.pop(ch):
                    self._reap(tag, payload)

    def pending(self) -> int:
        return super().pending() + sum(len(q) for q in self.limbo.values())


# ---------------------------------------------------------------------------
# Socket fabrics (real processes): AF_UNIX and TCP over one session layer
# ---------------------------------------------------------------------------
def fabric_dir() -> str:
    return tempfile.mkdtemp(prefix="phaser-fabric-")


def _sock_path(directory: str, pid: int) -> str:
    return os.path.join(directory, f"ep{pid}.sock")


def _addr_path(directory: str, pid: int) -> str:
    return os.path.join(directory, f"ep{pid}.addr")


def _pack_frame(seq: int, ack: int, tag: str, payload: Any) -> bytes:
    """Wire format: 4-byte big-endian CRC32 over the pickled
    ``(seq, ack, tag, payload)`` body. ``seq`` is 0 for unsequenced
    tags; ``ack`` is the sender's highest contiguously-delivered seq on
    the reverse channel (cumulative ack, piggybacked on every frame)."""
    blob = pickle.dumps((seq, ack, tag, payload),
                        protocol=pickle.HIGHEST_PROTOCOL)
    return struct.pack(">I", zlib.crc32(blob)) + blob


def _unpack_frame(buf: bytes):
    """``(seq, ack, tag, payload)``, or None for a torn/corrupt frame —
    the body is never unpickled unless the CRC matches, so garbage on
    the wire cannot reach the deserializer."""
    if len(buf) < 5:
        return None
    (want,) = struct.unpack(">I", buf[:4])
    blob = buf[4:]
    if zlib.crc32(blob) != want:
        return None
    try:
        return pickle.loads(blob)
    except Exception:
        return None


class _SendSession:
    """Sender half of one ordered (self, dst) channel: monotone seq
    assignment and the bounded resend ring of unacked frames."""

    __slots__ = ("lock", "seq", "acked", "ring", "touched", "wired")

    def __init__(self):
        self.lock = threading.Lock()
        self.seq = 0            # last assigned
        self.acked = 0          # highest cumulative ack from the peer
        self.ring: deque = deque()   # (seq, tag, payload), unacked
        self.touched = time.monotonic()  # last send or ack progress
        self.wired = 0          # highest seq ever attempted on a wire
        #                         (distinguishes a true retransmission
        #                          from a first send riding a replay)

    def unacked(self) -> int:
        with self.lock:
            return sum(1 for f in self.ring if f[0] > self.acked)


class _RecvSession:
    """Receiver half: dedupe-by-seq watermark + standalone-ack pacing."""

    __slots__ = ("delivered", "since_ack")

    def __init__(self):
        self.delivered = 0      # highest contiguously delivered seq
        self.since_ack = 0      # sequenced receipts since the last ack


class SocketEndpoint(Endpoint):
    """AF_UNIX endpoint: own listener + lazy outbound connections, with
    the partition-tolerant session layer (DESIGN.md §15) underneath.

    ``hb_echo=True`` (worker side) makes the *reader thread* echo
    heartbeat frames back to their source — liveness is then a
    transport property, independent of how long the main loop spends
    inside a command (a multi-second jax compile must not look like a
    death), while a SIGKILL stops the reader and therefore the echoes.
    ``last_rx`` timestamps every arrival, so an orphaned worker can
    notice its coordinator went silent.

    Session layer: ``env`` frames get per-(src, dst) monotone seqs and
    sit in a bounded resend ring until the peer's cumulative ack covers
    them; any (re)connect replays the unacked suffix and the receiver
    dedupes by seq, so a connection reset or healed partition never
    loses or duplicates an envelope. A blocked/undeliverable ``env`` is
    *deferred* (kept in the ring, flushed by a background thread once
    the peer is reachable) rather than surfaced — the layers above keep
    their reliable-FIFO channel assumption. Frames reaped for good
    (eviction via ``forget_peer``, ring overflow) go through ``reaper``
    so their spans still close.
    """

    def __init__(self, pid: int, directory: str, *, metrics=None,
                 hb_echo: bool = False, ack_every: int = 64,
                 ring_cap: int = 4096):
        super().__init__(pid)
        self.directory = directory
        self.metrics = metrics
        self.hb_echo = hb_echo
        self.last_rx = time.monotonic()
        self._ack_every = ack_every
        self._ring_cap = ring_cap
        self._probe_after = 1.0   # unacked-and-silent before probing
        self.reaper: Optional[Callable[[Any, str], Any]] = None
        self._listener = self._make_listener()
        self._inbox: "queue.Queue[Frame]" = queue.Queue()
        self._out: Dict[int, Any] = {}
        self._ever: set = set()          # dsts we once connected to
        self._down: Dict[int, float] = {}  # dst -> last connect failure
        self._down_ttl = 1.0
        self._locks: Dict[int, threading.Lock] = {}
        self._locks_guard = threading.Lock()
        self._send_s: Dict[int, _SendSession] = {}
        self._recv_s: Dict[int, _RecvSession] = {}
        self._rs_guard = threading.Lock()
        self._links: List[LinkFault] = []
        self._dirty: set = set()         # dsts with deferred ring frames
        self._accepted: List[Any] = []   # inbound conns, severed on close
        self._closed = False
        self._stop = threading.Event()
        self._accept_thread = threading.Thread(target=self._accept_loop,
                                               daemon=True)
        self._accept_thread.start()
        self._flush_thread = threading.Thread(target=self._flush_loop,
                                              daemon=True)
        self._flush_thread.start()

    # -- address family hooks (overridden by TcpEndpoint) -------------------
    def _make_listener(self):
        from multiprocessing.connection import Listener
        self.path = _sock_path(self.directory, self.pid)
        return Listener(self.path, "AF_UNIX")

    def _dial(self, dst: int):
        from multiprocessing.connection import Client
        return Client(_sock_path(self.directory, dst), "AF_UNIX")

    def _inc(self, name: str, n: int = 1) -> None:
        if self.metrics is not None:
            self.metrics.inc(name, n)

    # -- link faults (chaos) -------------------------------------------------
    def add_link_fault(self, a, b, t1: float, t2: float, *,
                       oneway: bool = False) -> None:
        self._links.append(LinkFault(frozenset(a), frozenset(b),
                                     t1, t2, oneway))

    def clear_link_faults(self) -> None:
        self._links = []

    def _blocked(self, dst: int) -> bool:
        if not self._links:
            return False
        now = time.monotonic()
        live = [f for f in self._links if now < f.t2]
        if len(live) != len(self._links):
            self._links = live          # expired windows fall away
        return any(f.blocks(self.pid, dst, now) for f in live)

    # -- sessions ------------------------------------------------------------
    def set_reaper(self, fn: Callable[[Any, str], Any]) -> None:
        self.reaper = fn

    def _send_session(self, dst: int) -> _SendSession:
        with self._locks_guard:
            ss = self._send_s.get(dst)
            if ss is None:
                ss = self._send_s[dst] = _SendSession()
            return ss

    def _ack_for(self, src: int) -> int:
        with self._rs_guard:
            rs = self._recv_s.get(src)
            return rs.delivered if rs is not None else 0

    def _note_ack(self, src: int, ack: int) -> None:
        ss = self._send_s.get(src)
        if ss is None:
            return
        with ss.lock:
            if ack > ss.acked:
                ss.acked = ack
                ss.touched = time.monotonic()
                while ss.ring and ss.ring[0][0] <= ack:
                    ss.ring.popleft()
                if not ss.ring:
                    self._dirty.discard(src)

    def _reap(self, tag: str, payload: Any) -> None:
        if self.reaper is not None:
            try:
                self.reaper(payload, tag)
            except Exception:
                pass            # span salvage is best effort

    def session_stats(self) -> Dict[str, int]:
        """Introspection for tests/benches: unacked frames per ring."""
        return {dst: ss.unacked() for dst, ss in self._send_s.items()}

    # -- inbound ------------------------------------------------------------
    def _accept_loop(self) -> None:
        while not self._closed:
            try:
                conn = self._listener.accept()
            except (OSError, EOFError):
                return
            self._accepted.append(conn)
            threading.Thread(target=self._read_loop, args=(conn,),
                             daemon=True).start()

    def _read_loop(self, conn) -> None:
        try:
            msg = _unpack_frame(conn.recv_bytes())
            if msg is None or msg[2] != "hello" \
                    or not isinstance(msg[3], int):
                # malformed or half-open connect: reject the stream
                # gracefully instead of dying on an assertion — the
                # accept loop (and every other reader) keeps running
                self._inc("transport.bad_hello")
                return
            src = msg[3]
            while True:
                msg = _unpack_frame(conn.recv_bytes())
                if msg is None:
                    # torn/corrupt frame: dropped unparsed; cutting the
                    # stream makes the peer reconnect and replay from
                    # the last acked seq (dropped-and-resent, never
                    # deserialized)
                    self._inc("transport.session.crc_drops")
                    return
                seq, ack, tag, payload = msg
                self.last_rx = time.monotonic()
                if ack:
                    self._note_ack(src, ack)
                if seq:
                    want_ack = dup = False
                    with self._rs_guard:
                        rs = self._recv_s.get(src)
                        if rs is None:
                            rs = self._recv_s[src] = _RecvSession()
                        if seq <= rs.delivered:
                            dup = True
                        else:
                            if seq != rs.delivered + 1:
                                # only possible after a ring-overflow
                                # eviction upstream: counted, not hidden
                                self._inc("transport.session.gaps",
                                          seq - rs.delivered - 1)
                            rs.delivered = seq
                            rs.since_ack += 1
                            if rs.since_ack >= self._ack_every:
                                rs.since_ack = 0
                                want_ack = True
                            # claim + enqueue under one lock: overlapping
                            # old/new streams from the same src stay FIFO
                            self._inbox.put((src, tag, payload))
                    if dup:
                        # a replay the previous stream already delivered:
                        # dropped (exactly-once by seq dedupe), but
                        # re-acked so the sender's stale ring drains
                        self._inc("transport.session.dupes_dropped")
                        want_ack = True
                    else:
                        self._inc("transport.session.delivered")
                    if want_ack:
                        # reverse traffic may be sparse (one-way env
                        # fan-out): top up the piggybacked acks so the
                        # peer's ring drains
                        try:
                            self.send(src, "ack", None)
                        except (PeerUnreachable, OSError, ValueError):
                            pass
                    continue
                if tag == "ack":
                    continue    # carried its ack field; nothing to queue
                if tag == "hb" and self.hb_echo:
                    # echo from the reader thread: never blocks on the
                    # main loop, dies with the process on SIGKILL
                    try:
                        self.send(src, "hb", payload)
                    except PeerUnreachable:
                        # _connect already stamped the negative cache
                        # (or short-circuited off it): re-stamping here
                        # would make the cache self-renewing and a
                        # healed coordinator unreachable forever
                        pass
                    except (OSError, ValueError):
                        # socket-level send failure: stamp the negative
                        # cache so subsequent heartbeats short-circuit
                        # instead of paying a full connect backoff
                        # each (the orphan timer is the recovery path)
                        self._down[src] = time.monotonic()
                    continue
                self._inbox.put((src, tag, payload))
        except (EOFError, OSError):
            pass
        except (TypeError, ValueError):
            # Connection isn't thread-safe against concurrent close():
            # a blocked recv raced by close() (endpoint shutdown) dies
            # with a TypeError from the nulled handle, not an OSError
            pass
        finally:
            try:
                conn.close()
            except OSError:
                pass

    def recv(self, timeout: Optional[float] = None) -> Optional[Frame]:
        try:
            if timeout == 0:
                frame = self._inbox.get_nowait()
            else:
                frame = self._inbox.get(timeout=timeout)
        except queue.Empty:
            return None
        self.frames_received += 1
        return frame

    # -- outbound -----------------------------------------------------------
    def _lock_for(self, dst: int) -> threading.Lock:
        with self._locks_guard:
            if dst not in self._locks:
                self._locks[dst] = threading.Lock()
            return self._locks[dst]

    def _connect(self, dst: int, timeout: float = 30.0):
        """Exponential backoff + jitter up to ``timeout``; raises a
        structured ``PeerUnreachable`` (not a bare TimeoutError) so
        callers can attribute the failure to a pid. A *re*connect (the
        peer was reachable before, so a refusal means it died, not
        that it is still booting) gets a short deadline, and a recent
        failure short-circuits entirely — a signal fan-out to a dead
        peer must not stall the survivor once per frame."""
        down_at = self._down.get(dst)
        if down_at is not None:
            if time.monotonic() - down_at < self._down_ttl:
                self._inc("transport.connect_shortcircuit")
                raise PeerUnreachable(dst, 0, 0.0)
            self._down.pop(dst, None)
        if dst in self._ever:
            timeout = min(timeout, 1.0)
        t0 = time.monotonic()
        deadline = t0 + timeout
        attempts = 0
        delay = 0.005
        rng = random.Random((self.pid + 7) * 131 + dst)
        while True:
            attempts += 1
            self._inc("transport.connect_attempts")
            try:
                conn = self._dial(dst)
                break
            except (FileNotFoundError, ConnectionRefusedError, OSError):
                now = time.monotonic()
                if now > deadline:
                    self._inc("transport.connect_failures")
                    self._down[dst] = now
                    raise PeerUnreachable(dst, attempts, now - t0)
                time.sleep(min(delay * (1 + rng.random()),
                               max(0.0, deadline - now)))
                delay = min(delay * 1.6, 0.25)
        conn.send_bytes(_pack_frame(0, 0, "hello", self.pid))
        self._ever.add(dst)
        return conn

    def _replay(self, dst: int, conn) -> None:
        """(Re)transmit every unacked sequenced frame to a fresh stream
        — reconnect-and-replay from the last acked seq. The receiver's
        seq dedupe drops whatever the dead stream already delivered.
        Only frames previously attempted on a wire count as replays;
        deferred frames getting their first transmission here don't."""
        ss = self._send_s.get(dst)
        if ss is None:
            return
        with ss.lock:
            frames = [f for f in ss.ring if f[0] > ss.acked]
            wired_before = ss.wired
            if frames:
                ss.wired = max(ss.wired, frames[-1][0])
        for seq, tag, payload in frames:
            conn.send_bytes(_pack_frame(seq, self._ack_for(dst), tag,
                                        payload))
        redone = sum(1 for f in frames if f[0] <= wired_before)
        if redone:
            self._inc("transport.session.replays", redone)

    def _drop_conn(self, dst: int, conn) -> None:
        self._out.pop(dst, None)
        try:
            conn.close()
        except OSError:
            pass

    def _transmit(self, dst: int, seq: int, tag: str,
                  payload: Any) -> None:
        """One framed message out, (re)establishing the stream (and
        replaying the unacked ring suffix) as needed. Caller holds the
        dst connection lock."""
        if self._blocked(dst):
            # link fault window: emulate the partition by tearing the
            # cached stream down once and refusing to transmit
            conn = self._out.pop(dst, None)
            if conn is not None:
                try:
                    conn.close()
                except OSError:
                    pass
                self._inc("chaos.link_cut")
            self._inc("chaos.link_blocked")
            raise PeerUnreachable(dst, 0, 0.0)
        short = tag in ("hb", "ack")    # periodic/advisory: fail fast
        conn = self._out.get(dst)
        if conn is None:
            # fresh stream: everything unacked (the current sequenced
            # frame included — it is already in the ring) rides the
            # replay; only unsequenced frames need a direct send
            conn = self._connect(dst, timeout=(0.2 if short else 30.0))
            try:
                self._replay(dst, conn)
                if not seq:
                    conn.send_bytes(_pack_frame(0, self._ack_for(dst),
                                                tag, payload))
            except (OSError, ValueError):
                self._drop_conn(dst, conn)
                self._inc("transport.send_failures")
                raise
            self._out[dst] = conn
            return
        if seq:
            ss = self._send_s.get(dst)
            if ss is not None:
                with ss.lock:
                    ss.wired = max(ss.wired, seq)   # attempt recorded
        try:
            conn.send_bytes(_pack_frame(seq, self._ack_for(dst), tag,
                                        payload))
        except (OSError, ValueError):
            # connection reset mid-stream: drop the dead conn, dial
            # once more and replay from the last acked seq — the
            # current frame, if sequenced, is already in the ring and
            # rides the replay
            self._inc("transport.session.resets")
            self._drop_conn(dst, conn)
            conn = self._connect(dst, timeout=(0.2 if short else 1.0))
            try:
                self._replay(dst, conn)
                if not seq:
                    conn.send_bytes(_pack_frame(0, self._ack_for(dst),
                                                tag, payload))
            except (OSError, ValueError):
                self._drop_conn(dst, conn)
                self._inc("transport.send_failures")
                raise
            self._out[dst] = conn

    def send(self, dst: int, tag: str, payload: Any) -> None:
        # per-destination lock: the heartbeat thread and the main loop
        # share outbound connections, and Connection.send is not atomic
        with self._lock_for(dst):
            seq = 0
            if tag in SESSION_TAGS:
                ss = self._send_session(dst)
                with ss.lock:
                    ss.seq += 1
                    seq = ss.seq
                    ss.touched = time.monotonic()
                    ss.ring.append((seq, tag, payload))
                    while len(ss.ring) > self._ring_cap:
                        # replay-window bound: the oldest unacked frame
                        # can no longer be resent — reaped, its span
                        # closed, the receiver counts the gap
                        _, t, p = ss.ring.popleft()
                        self._inc("transport.session.ring_evict")
                        self._reap(t, p)
                self._inc("transport.session.seq_assigned")
            try:
                self._transmit(dst, seq, tag, payload)
            except (PeerUnreachable, OSError, ValueError):
                if seq:
                    # the frame stays in the resend ring: the flusher
                    # (or the next successful send) replays it once the
                    # peer is reachable again — an envelope is never
                    # lost to a reset or a transient partition
                    self._inc("transport.session.deferred")
                    self._dirty.add(dst)
                    return
                raise
        self.frames_sent += 1

    def _flush_loop(self) -> None:
        """Background session maintenance, three duties per tick:

        * flush pending receiver acks (ack_every paces bursts, but a
          trickle below the threshold must still ack within a tick so
          peer rings drain);
        * retry deferred (dirty) channels — a one-way envelope channel
          with no reverse traffic to ride on must still replay once a
          partition heals or the peer comes back;
        * probe channels whose unacked frames went stale: a send into a
          freshly-reset TCP stream can succeed into the kernel buffer
          and vanish, with the error surfacing only on the *next* write
          — the probe is that next write, provoking the reset detection
          (and thus reconnect-and-replay) even when the application has
          gone quiet.
        """
        while not self._stop.wait(0.2):
            with self._rs_guard:
                owed = [(src, rs.delivered)
                        for src, rs in self._recv_s.items()
                        if rs.since_ack > 0]
            for src, seen in owed:
                try:
                    self.send(src, "ack", None)
                except (PeerUnreachable, OSError, ValueError):
                    continue
                with self._rs_guard:
                    rs = self._recv_s.get(src)
                    if rs is not None and rs.delivered == seen:
                        rs.since_ack = 0
            now = time.monotonic()
            for dst, ss in list(self._send_s.items()):
                stale = (ss.unacked() > 0
                         and now - ss.touched > self._probe_after)
                if not (stale or dst in self._dirty):
                    continue
                lk = self._lock_for(dst)
                if not lk.acquire(blocking=False):
                    continue
                try:
                    self._transmit(dst, 0, "ack", None)
                    self._dirty.discard(dst)
                    self._inc("transport.session.flushes")
                except (PeerUnreachable, OSError, ValueError):
                    pass        # still unreachable: retry next tick
                finally:
                    lk.release()

    # -- chaos hooks ---------------------------------------------------------
    def inject_reset(self, dst: int) -> bool:
        """Hard-close the cached outbound stream *without* forgetting it:
        the peer sees EOF, and our next send hits the dead conn —
        exercising the reset-detect + reconnect-and-replay path."""
        with self._lock_for(dst):
            conn = self._out.get(dst)
            if conn is None:
                return False
            try:
                conn.close()
            except OSError:
                pass
        self._inc("chaos.reset_inject")
        return True

    def _send_corrupt(self, dst: int) -> None:
        """Chaos/test hook: emit a deliberately torn frame (CRC cannot
        match) on the cached stream — the receiver must drop it unparsed
        and cut the stream."""
        with self._lock_for(dst):
            conn = self._out.get(dst)
            if conn is None:
                conn = self._connect(dst)
                self._out[dst] = conn
            conn.send_bytes(b"\x00\x00\x00\x00not-a-frame")

    # -- lifecycle -----------------------------------------------------------
    def forget_peer(self, dst: int) -> None:
        """Drop the cached outbound connection AND the session state for
        an evicted process: unacked ring frames are reaped (spans close
        as blackholed), the recv watermark resets so a future
        incarnation of the pid space starts a fresh session."""
        with self._lock_for(dst):
            conn = self._out.pop(dst, None)
            ss = self._send_s.pop(dst, None)
        self._dirty.discard(dst)
        with self._rs_guard:
            self._recv_s.pop(dst, None)
        if ss is not None:
            with ss.lock:
                frames = [f for f in ss.ring if f[0] > ss.acked]
                ss.ring.clear()
            for _, tag, payload in frames:
                self._inc("transport.session.reaped")
                self._reap(tag, payload)
        if conn is not None:
            try:
                conn.close()
            except OSError:
                pass
        self._down.pop(dst, None)
        self._ever.discard(dst)

    def close(self) -> None:
        self._closed = True
        self._stop.set()
        try:
            self._listener.close()
        except OSError:
            pass
        # sever inbound streams too: peers of a closed endpoint must see
        # the death (broken pipe) instead of feeding a zombie reader
        for conn in self._accepted:
            try:
                conn.close()
            except OSError:
                pass
        self._accepted = []
        for dst in list(self._out):
            with self._lock_for(dst):
                conn = self._out.pop(dst, None)
            if conn is not None:
                try:
                    conn.close()
                except OSError:
                    pass
        try:
            os.unlink(self.path)
        except OSError:
            pass


class TcpEndpoint(SocketEndpoint):
    """The socket endpoint over TCP (AF_INET loopback/host networking):
    each endpoint binds an ephemeral port and advertises ``host:port``
    in a registry file in the fabric dir, so the address book is still
    derivable from ``(directory, pid)`` alone — arrivals need no
    address gossip, exactly like the AF_UNIX path scheme. Everything
    else (session layer, backoff, negative cache, hb echo, link
    faults) is shared."""

    host = "127.0.0.1"

    def _make_listener(self):
        from multiprocessing.connection import Listener
        lst = Listener((self.host, 0), "AF_INET")
        host, port = lst.address
        self.path = _addr_path(self.directory, self.pid)
        tmp = f"{self.path}.tmp.{os.getpid()}"
        with open(tmp, "w") as f:
            f.write(f"{host}:{port}\n")
        os.replace(tmp, self.path)      # atomic: readers never see torn
        return lst

    def _dial(self, dst: int):
        from multiprocessing.connection import Client
        # FileNotFoundError (peer still booting, registry entry not
        # written yet) rides the same backoff loop as a refused connect
        with open(_addr_path(self.directory, dst)) as f:
            host, port = f.read().strip().rsplit(":", 1)
        return Client((host, int(port)), "AF_INET")


ENDPOINT_KINDS = {"unix": SocketEndpoint, "tcp": TcpEndpoint}


def endpoint_cls(kind: str):
    try:
        return ENDPOINT_KINDS[kind]
    except KeyError:
        raise ValueError(f"unknown socket fabric {kind!r} "
                         f"(want one of {sorted(ENDPOINT_KINDS)})")


class FaultyEndpoint(Endpoint):
    """Chaos decorator over any endpoint (installed on the coordinator's
    socket endpoint). Faults by tag class:

    * send side: ``cmd``/``hb`` frames dropped or duplicated per the
      seeded channel rng (retry + worker-side cid dedupe recover);
    * recv side: ``rep`` frames dropped (reply lost -> retry) or
      re-delivered (coordinator ignores cids it no longer awaits);
      ``env`` frames held in per-source limbo for a bounded wall-clock
      delay — later frames of the same source queue behind the held
      head, so per-channel FIFO survives while channels reorder.
    """

    def __init__(self, inner: Endpoint, chaos: ChaosConfig, metrics=None):
        super().__init__(inner.pid)
        self.inner = inner
        self.chaos = chaos
        self.metrics = metrics
        self._rngs: Dict[Tuple[int, int], random.Random] = {}
        self._held: Dict[int, deque] = defaultdict(deque)  # src -> frames
        self._redeliver: deque = deque()

    def _inc(self, name: str) -> None:
        if self.metrics is not None:
            self.metrics.inc(name)

    def _rng(self, src: int, dst: int) -> random.Random:
        key = (src, dst)
        if key not in self._rngs:
            self._rngs[key] = self.chaos.rng(src, dst)
        return self._rngs[key]

    # -- passthrough surface -------------------------------------------------
    @property
    def last_rx(self):
        return getattr(self.inner, "last_rx", 0.0)

    def forget_peer(self, dst: int) -> None:
        fp = getattr(self.inner, "forget_peer", None)
        if fp is not None:
            fp(dst)

    def set_reaper(self, fn) -> None:
        sr = getattr(self.inner, "set_reaper", None)
        if sr is not None:
            sr(fn)

    def add_link_fault(self, a, b, t1: float, t2: float, *,
                       oneway: bool = False) -> None:
        alf = getattr(self.inner, "add_link_fault", None)
        if alf is not None:
            alf(a, b, t1, t2, oneway=oneway)

    def clear_link_faults(self) -> None:
        clf = getattr(self.inner, "clear_link_faults", None)
        if clf is not None:
            clf()

    def inject_reset(self, dst: int) -> bool:
        ir = getattr(self.inner, "inject_reset", None)
        return bool(ir(dst)) if ir is not None else False

    def session_stats(self):
        st = getattr(self.inner, "session_stats", None)
        return st() if st is not None else {}

    def close(self) -> None:
        self.inner.close()

    # -- faulted send/recv ---------------------------------------------------
    def send(self, dst: int, tag: str, payload: Any) -> None:
        if tag in ("cmd", "hb"):
            rng = self._rng(self.pid, dst)
            if rng.random() < self.chaos.p_drop:
                self._inc(f"chaos.drop_{tag}")
                return
            if rng.random() < self.chaos.p_dup:
                self._inc(f"chaos.dup_{tag}")
                self.inner.send(dst, tag, payload)
        if self.chaos.p_reset > 0 and tag in ("cmd", "env"):
            # guard keeps the rng stream byte-identical for configs
            # that never asked for resets (seed compatibility)
            rng = self._rng(self.pid, dst)
            if rng.random() < self.chaos.p_reset:
                self.inject_reset(dst)
        self.inner.send(dst, tag, payload)
        self.frames_sent += 1

    def _due(self) -> Optional[Frame]:
        if self._redeliver:
            return self._redeliver.popleft()
        now = time.monotonic()
        for src in sorted(s for s, q in self._held.items() if q):
            q = self._held[src]
            if q[0][0] <= now:
                self._inc("chaos.release_env")
                return q.popleft()[1]
        return None

    def _filter(self, frame: Frame) -> Optional[Frame]:
        src, tag, payload = frame
        rng = self._rng(src, self.pid)
        if tag == "rep":
            if rng.random() < self.chaos.p_drop:
                self._inc("chaos.drop_rep")
                return None
            if rng.random() < self.chaos.p_dup:
                self._inc("chaos.dup_rep")
                self._redeliver.append(frame)
            return frame
        if tag == "env":
            q = self._held[src]
            if q or rng.random() < self.chaos.p_delay:
                due = time.monotonic() + rng.uniform(
                    0.0, self.chaos.max_delay)
                if q:
                    due = max(due, q[-1][0])   # FIFO within the channel
                q.append((due, frame))
                self._inc("chaos.delay_env")
                return None
            return frame
        return frame

    def recv(self, timeout: Optional[float] = None) -> Optional[Frame]:
        deadline = (None if timeout is None
                    else time.monotonic() + timeout)
        while True:
            due = self._due()
            if due is not None:
                self.frames_received += 1
                return due
            if timeout == 0:
                inner_t: Optional[float] = 0
            else:
                inner_t = 0.02
                if deadline is not None:
                    inner_t = min(inner_t,
                                  max(0.0, deadline - time.monotonic()))
            frame = self.inner.recv(timeout=inner_t)
            if frame is not None:
                out = self._filter(frame)
                if out is not None:
                    self.frames_received += 1
                    return out
                continue
            if timeout == 0:
                return None
            if deadline is not None and time.monotonic() >= deadline:
                return None
