"""DistCoordinator: membership epochs over a cluster of host processes.

The single-process ``ElasticPhaserRuntime`` drives churn through one
``DistPhaser`` holding every actor. Here the same epoch lifecycle runs
over a *partitioned* control plane: the coordinator owns the HEAD
sentinel (pid ``COORD``), each host process owns its own participant
actor, and every structural op is the paper's two-phase dance executed
with real inter-process messages — eager level-0 splice initiated on the
parent's owner, lazy multi-link handoff riding the same transport, then
a quiescence wave before the membership view is re-broadcast.

Epoch boundaries stay the swap point: at ``advance()`` after churn, each
surviving process re-derives the skip-list oracle over the *replicated*
membership view, checks its own partition of protocol state against it,
fingerprints the whole structure, and re-commits its process-level
program cache. The coordinator asserts all fingerprints (its own
included) agree — the distributed analogue of ``verify_epoch``.

Fault tolerance (DESIGN.md §13). The cooperative demote→evict path
needs the departing host to answer unlink handshakes; a crashed host
never will. So the coordinator layers:

* detection — a heartbeat thread + ``PhiDetector`` over the echo times
  (socket clusters); suspect → confirm → declare-dead, with a hard
  silence floor so one slow poll can't kill anyone;
* at-least-once RPC — ``collect`` retransmits commands with bounded
  exponential backoff; workers dedupe by command id and replay cached
  replies, making every op exactly-once end to end;
* non-cooperative eviction — ``recover_failure`` removes the dead host
  from membership, bumps the generation, re-seeds every survivor's
  shard from the surviving oracle (``ShardPhaser.rebuild``), and
  continues; ``advance``/``train_step`` retry around it. A mid-step
  crash resolves via ``step_status``: all-applied → done, none →
  retry, mixed → ``StepInconsistent`` (checkpoint resume is the only
  way back to replicated params).

Two cluster fabrics drive the same coordinator:

* ``InprocCluster``  — N logical processes in one address space over
  ``InprocFabric``; deterministic, used by tier-1 tests and the
  ``--processes N`` trainer (device slices of one jax runtime). Pass
  ``chaos=ChaosConfig(...)`` for seeded delay/reorder injection;
  ``kill_host`` simulates crash-stop.
* ``SocketCluster``  — real OS processes (``worker.py``) over AF_UNIX
  sockets; quiescence needs the Mattern-style double poll; used by the
  control-plane latency benchmark and the slow churn test. Pass
  ``chaos=`` for RPC drop/dup + env delay; ``kill_pid`` SIGKILLs a
  worker with no cleanup.
"""
from __future__ import annotations

import os
import random
import signal as _signal
import subprocess
import sys
import threading
import time
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Set, Tuple

from ..core.phaser import SCSL, SNSL
from ..obs.hub import ObsHub
from ..obs.live import LiveStreamer
from ..obs.recorder import flight_path
from .agent import HostAgent
from .exchange import run_schedule_rounds
from .failure import (HostDead, PeerUnreachable, PhiDetector, RpcTimeout,
                      StepInconsistent, backoff, orphan_horizon)
from .plane import COORD, ShardPhaser
from .transport import (ChaosConfig, FaultyEndpoint, FaultyInprocFabric,
                        InprocFabric, SocketEndpoint, endpoint_cls,
                        fabric_dir)


@dataclass
class HostEvent:
    step: int
    kind: str    # "join" | "leave" | "fail" | "straggle" | "demote"
                 # | "repromote" | "dead" (non-cooperative eviction)
    pid: int


@dataclass(frozen=True)
class DistEpoch:
    """One membership epoch of the multi-host runtime. No compiled
    collective rides here (each process compiles its own slice); the
    epoch's identity is the fingerprint every process agreed on."""
    index: int
    phase_start: int
    live: Tuple[int, ...]
    demoted: Tuple[int, ...]
    fingerprint: str
    program_key: Optional[Dict] = None

    @property
    def n(self) -> int:
        return len(self.live)


class _StepAborted(Exception):
    """Internal: one or more hosts unwound a peer-exchange step."""

    def __init__(self, step: int, pids: Sequence[int]):
        self.step = step
        self.pids = list(pids)
        super().__init__(f"step {step} aborted on {self.pids}")


class InprocCluster:
    """All host agents in this address space, coordinator included."""

    peer_exchange = False   # steps run split (local halves + central rounds)

    def __init__(self, *, chaos: Optional[ChaosConfig] = None):
        self.fabric = (FaultyInprocFabric(chaos) if chaos is not None
                       else InprocFabric())
        self.ep = self.fabric.endpoint(COORD)
        self.agents: Dict[int, HostAgent] = {}
        self.env_sink: Optional[Callable] = None   # unused (pump is direct)
        self.dead: Set[int] = set()

    def add_host(self, pid: int, cfg: Dict) -> None:
        self.agents[pid] = HostAgent(pid, self.fabric.endpoint(pid), cfg)

    def call(self, pid: int, cmd: Dict, **kw) -> Dict:
        if pid in self.dead:
            raise HostDead(pid)
        r = self.agents[pid].handle(cmd)
        assert r.get("ok"), (pid, cmd.get("op"), r)
        return r

    def post(self, pid: int, cmd: Dict):
        return self.call(pid, cmd)

    def collect(self, handle, timeout: float = 0.0, watch=None) -> Dict:
        return handle

    def kill_host(self, pid: int) -> None:
        """Simulated crash-stop: the agent vanishes without running any
        protocol; frames already addressed to it are reaped by the
        fabric, future sends to it vanish (counted)."""
        self.dead.add(pid)
        self.agents.pop(pid, None)
        self.fabric.drop_endpoint(pid)

    def mark_dead(self, pid: int) -> None:
        self.kill_host(pid)

    def poll_failures(self) -> List[int]:
        """No detector in-process — deaths are explicit ``kill_host``
        calls; report them so the coordinator can recover proactively."""
        return sorted(self.dead)

    def fault_counters(self) -> Dict[str, int]:
        return dict(self.fabric.faults)

    def drop_host(self, pid: int) -> None:
        del self.agents[pid]
        self.fabric.drop_endpoint(pid)

    def quiesce(self, coord_shard: ShardPhaser, limit: int = 100_000) -> None:
        """Synchronous sweeps: pump every shard until a full round moves
        nothing and no frame sits in any inbox. Under a chaos fabric a
        stalled sweep advances fabric time instead, so limbo frames
        come due and the sweep resumes."""
        for _ in range(limit):
            moved = coord_shard.pump()
            for pid in sorted(self.agents):
                moved += self.agents[pid].shard.pump()
            if moved == 0:
                if self.fabric.pending() == 0:
                    return
                self.fabric.tick()
        raise AssertionError("in-process cluster did not quiesce")

    def close(self) -> None:
        self.agents.clear()


class SocketCluster:
    """Host agents as OS processes (``repro.runtime_dist.worker``) over
    AF_UNIX sockets. The coordinator endpoint shares its inbox between
    protocol envelopes (routed to ``env_sink``), command replies, and
    heartbeat echoes (fed to the failure detector)."""

    peer_exchange = True    # steps run whole, with peer-to-peer rounds

    def __init__(self, *, control_only: bool = False,
                 python: Optional[str] = None,
                 hb_interval: float = 0.5,
                 failure_timeout: float = 10.0,
                 chaos: Optional[ChaosConfig] = None,
                 orphan_timeout: Optional[float] = None,
                 fabric: str = "unix"):
        from ..obs.metrics import MetricsRegistry
        self.dir = fabric_dir()
        self.metrics = MetricsRegistry()
        self.fabric_kind = fabric
        ep = endpoint_cls(fabric)(COORD, self.dir, metrics=self.metrics)
        self.ep = (FaultyEndpoint(ep, chaos, metrics=self.metrics)
                   if chaos is not None else ep)
        self.procs: Dict[int, subprocess.Popen] = {}
        self.env_sink: Optional[Callable] = None
        self.control_only = control_only
        self.python = python or sys.executable
        self.hb_interval = hb_interval
        self.failure_timeout = failure_timeout
        self.orphan_timeout = (orphan_timeout if orphan_timeout is not None
                               else orphan_horizon(failure_timeout))
        self._cid = 0
        self._reps: Dict[int, Dict] = {}
        self._pending: Dict[int, Dict] = {}   # cid -> retransmit state
        self._retry_rng = random.Random(0xC0FFEE)
        self.detector = PhiDetector(interval=hb_interval,
                                    timeout=failure_timeout,
                                    metrics=self.metrics)
        self.dead: Set[int] = set()
        # final counters of evicted hosts: their frames stay part of the
        # global sent/received balance after the process is gone
        self._ghost_sent = 0
        self._ghost_recv = 0
        self._hb_stop = threading.Event()
        self._hb_thread = threading.Thread(target=self._hb_loop,
                                           daemon=True)
        self._hb_thread.start()

    # ------------------------------------------------------------ liveness
    def _hb_loop(self) -> None:
        seq = 0
        while not self._hb_stop.wait(self.hb_interval):
            seq += 1
            for pid in list(self.procs):
                if pid in self.dead:
                    continue
                try:
                    self.ep.send(pid, "hb", (seq, time.monotonic()))
                except (PeerUnreachable, OSError, ValueError):
                    pass    # detector accounts the missing echo

    def _is_dead(self, pid: int) -> bool:
        return pid in self.dead or pid in self.detector.declared

    def poll_failures(self) -> List[int]:
        # drain queued heartbeat echoes first: between RPCs nothing else
        # empties the inbox, and acks the detector never saw would read
        # as silence from every host at once
        while self._drain(0.0):
            pass
        self.detector.poll()
        return sorted(set(self.detector.declared) - self.dead)

    def fault_counters(self) -> Dict[str, int]:
        snap = self.metrics.snapshot()["counters"]
        return {k.split("chaos.", 1)[1]: v for k, v in snap.items()
                if k.startswith("chaos.")}

    # ------------------------------------------------------------ lifecycle
    def _spawn(self, pid: int, cfg: Dict) -> None:
        env = dict(os.environ)
        root = os.getcwd()
        src = os.path.join(root, "src")
        env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
        env["PHASER_ORPHAN_TIMEOUT"] = str(self.orphan_timeout)
        data = cfg.get("data")
        if data is not None:
            env["JAX_PLATFORMS"] = "cpu"
            env["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                                f"{data.get('devices', 1)}")
        self.procs[pid] = subprocess.Popen(
            [self.python, "-m", "repro.runtime_dist.worker",
             "--dir", self.dir, "--pid", str(pid),
             "--fabric", self.fabric_kind],
            env=env, cwd=root)
        self.detector.touch(pid)

    def add_host(self, pid: int, cfg: Dict) -> None:
        self._spawn(pid, cfg)
        r = self.call(pid, {"op": "init", "cfg": cfg}, timeout=600.0)
        assert r.get("ok"), (pid, r)

    def kill_pid(self, pid: int) -> None:
        """Hard crash for tests/chaos: SIGKILL, no cleanup whatsoever —
        detection must come from the heartbeat timeout."""
        os.kill(self.procs[pid].pid, _signal.SIGKILL)

    def mark_dead(self, pid: int) -> None:
        """Non-cooperative removal after a declare-dead: reap the OS
        process, drop cached connections and in-flight commands."""
        self.dead.add(pid)
        self.detector.remove(pid)
        p = self.procs.pop(pid, None)
        if p is not None:
            try:
                p.kill()
            except OSError:
                pass
            try:
                p.wait(timeout=30)
            except Exception:
                pass
        self.ep.forget_peer(pid)
        for cid in [c for c, e in self._pending.items()
                    if e["pid"] == pid]:
            self._pending.pop(cid, None)
        self.metrics.inc("cluster.marked_dead")

    # ------------------------------------------------------------ link chaos
    def inject_link_fault(self, a, b=None, *, duration: float,
                          oneway: bool = False) -> None:
        """Install a link-fault window on every live endpoint.

        ``b=None`` means "everyone else" (a isolates itself). Each
        endpoint converts ``duration`` into a *local* wall-clock window
        at receipt and auto-heals when it expires — no shared clock,
        and a heal never needs connectivity through the partition.
        Workers are told BEFORE the coordinator installs locally: once
        our own edge is cut we may not reach workers inside it."""
        a = sorted(a)
        if b is None:
            b = sorted(({COORD} | set(self.procs)) - set(a))
        else:
            b = sorted(b)
        cmd = {"op": "link_fault", "a": a, "b": b,
               "dur": duration, "oneway": oneway}
        for pid in sorted(self.procs):
            if pid in self.dead:
                continue
            try:
                self.call(pid, cmd, timeout=10.0)
            except (HostDead, RpcTimeout, PeerUnreachable, OSError):
                pass        # best effort: its local window just stays off
        alf = getattr(self.ep, "add_link_fault", None)
        if alf is not None and (COORD in a or COORD in b):
            now = time.monotonic()
            alf(a, b, now, now + duration, oneway=oneway)
        self.metrics.inc("chaos.link_fault_installed")

    def heal_link_faults(self) -> None:
        """Force-heal every window early: clear locally FIRST (so the
        broadcast can get through a partition that included us)."""
        clf = getattr(self.ep, "clear_link_faults", None)
        if clf is not None:
            clf()
        for pid in sorted(self.procs):
            if pid in self.dead:
                continue
            try:
                self.call(pid, {"op": "link_clear"}, timeout=10.0)
            except (HostDead, RpcTimeout, PeerUnreachable, OSError):
                pass

    def inject_reset_storm(self) -> int:
        """Chaos: hard-close every cached stream everywhere (coordinator
        outbound + each worker's outbound) — the session layer must
        reconnect and replay with zero envelope loss."""
        hit = 0
        ir = getattr(self.ep, "inject_reset", None)
        if ir is not None:
            for pid in sorted(self.procs):
                hit += bool(ir(pid))
        dsts = [COORD] + sorted(self.procs)
        for pid in sorted(self.procs):
            if pid in self.dead:
                continue
            try:
                r = self.call(pid, {"op": "inject_reset",
                                    "dsts": [d for d in dsts if d != pid]},
                              timeout=10.0)
                hit += int(r.get("reset", 0))
            except (HostDead, RpcTimeout, PeerUnreachable, OSError):
                pass
        self.metrics.inc("chaos.reset_storms")
        return hit

    # ------------------------------------------------------------------ rpc
    def _drain(self, timeout: float) -> bool:
        frame = self.ep.recv(timeout=timeout)
        if frame is None:
            return False
        src, tag, payload = frame
        if tag == "rep":
            cid, reply = payload
            if cid in self._pending:
                self._pending.pop(cid)
                self._reps[cid] = reply
            else:
                # duplicated or abandoned reply (chaos / late worker)
                self.metrics.inc("rpc.stale_reps")
        elif tag == "hb":
            seq, t_sent = payload
            self.detector.on_ack(src)
            self.metrics.observe("hb.rtt_seconds",
                                 time.monotonic() - t_sent)
        elif tag == "env":
            assert self.env_sink is not None
            self.env_sink(payload)
        else:
            self.metrics.inc(f"transport.unexpected_{tag}")
        return True

    def post(self, pid: int, cmd: Dict):
        self._cid += 1
        cid = self._cid
        now = time.monotonic()
        self._pending[cid] = {
            "pid": pid, "cmd": cmd, "attempts": 1, "t0": now,
            "retry_at": now + backoff(1, 0.25, 2.0, self._retry_rng)}
        try:
            self.ep.send(pid, "cmd", (cid, cmd))
        except (PeerUnreachable, OSError):
            self.metrics.inc("rpc.post_send_failures")
        return cid

    def collect(self, cid, timeout: float = 600.0, watch=None) -> Dict:
        """Await the reply for ``cid`` with at-least-once delivery:
        retransmit on a backoff schedule (the worker's cid dedupe makes
        that safe), raise ``HostDead`` the moment the detector declares
        the target — or any ``watch``-ed pid — dead, and ``RpcTimeout``
        only if the full deadline passes with the peer still alive."""
        t0 = time.monotonic()
        deadline = t0 + timeout
        while cid not in self._reps:
            self._drain(0.05)
            while self._drain(0):
                pass
            self.detector.poll()
            ent = self._pending.get(cid)
            pid = ent["pid"] if ent is not None else None
            if pid is not None and self._is_dead(pid):
                self._pending.pop(cid, None)
                raise HostDead(pid)
            for w in (watch or ()):
                if self._is_dead(w):
                    self._pending.pop(cid, None)
                    raise HostDead(w)
            now = time.monotonic()
            if ent is not None and now >= ent["retry_at"]:
                ent["attempts"] += 1
                self.metrics.inc("rpc.retries")
                try:
                    self.ep.send(pid, "cmd", (cid, ent["cmd"]))
                except (PeerUnreachable, OSError):
                    self.metrics.inc("rpc.retry_send_failures")
                ent["retry_at"] = now + backoff(ent["attempts"], 0.25,
                                                2.0, self._retry_rng)
            if now >= deadline:
                self._pending.pop(cid, None)
                raise RpcTimeout(pid if pid is not None else -1, cid,
                                 now - t0,
                                 ent["attempts"] if ent else 0)
        r = self._reps.pop(cid)
        assert r.get("ok"), (cid, r)
        return r

    def collect_any(self, cids, timeout: float = 600.0,
                    watch=None) -> Tuple[int, Dict]:
        """Await the first available reply among ``cids`` in ARRIVAL
        order (not posting order), with the same retransmit / death /
        deadline rules as ``collect``. Returns ``(cid, reply)``.

        Arrival order is load-bearing for the step path: when a
        partition makes one worker abort its exchange while another
        blocks on its in-step recv deadline, posting-order collection
        would pin the coordinator behind the blocked worker and never
        see the abort it needs to act on."""
        t0 = time.monotonic()
        deadline = t0 + timeout
        cids = list(cids)
        while True:
            for cid in cids:
                if cid in self._reps:
                    r = self._reps.pop(cid)
                    assert r.get("ok"), (cid, r)
                    return cid, r
            self._drain(0.05)
            while self._drain(0):
                pass
            self.detector.poll()
            now = time.monotonic()
            for cid in cids:
                ent = self._pending.get(cid)
                if ent is None:
                    continue
                pid = ent["pid"]
                if self._is_dead(pid):
                    self._pending.pop(cid, None)
                    raise HostDead(pid)
                if now >= ent["retry_at"]:
                    ent["attempts"] += 1
                    self.metrics.inc("rpc.retries")
                    try:
                        self.ep.send(pid, "cmd", (cid, ent["cmd"]))
                    except (PeerUnreachable, OSError):
                        self.metrics.inc("rpc.retry_send_failures")
                    ent["retry_at"] = now + backoff(ent["attempts"],
                                                    0.25, 2.0,
                                                    self._retry_rng)
            for w in (watch or ()):
                if self._is_dead(w):
                    for cid in cids:
                        self._pending.pop(cid, None)
                    raise HostDead(w)
            if now >= deadline:
                for cid in cids:
                    self._pending.pop(cid, None)
                raise RpcTimeout(-1, cids[0] if cids else -1,
                                 now - t0, 0)

    def call(self, pid: int, cmd: Dict, timeout: float = 600.0) -> Dict:
        return self.collect(self.post(pid, cmd), timeout=timeout)

    def abandon(self, cids) -> None:
        """Stop retransmitting (and drop any cached reply for) commands
        the caller no longer awaits — a step unwound by recovery."""
        for cid in cids:
            self._pending.pop(cid, None)
            self._reps.pop(cid, None)

    def drop_host(self, pid: int) -> None:
        try:
            r = self.call(pid, {"op": "status"}, timeout=30.0)
            self._ghost_sent += r["sent"]
            self._ghost_recv += r["received"]
            self.call(pid, {"op": "shutdown"}, timeout=30.0)
        finally:
            self.detector.remove(pid)
            p = self.procs.pop(pid)
            p.wait(timeout=60)
            self.ep.forget_peer(pid)

    def quiesce(self, coord_shard: ShardPhaser, limit: int = 10_000) -> None:
        """Mattern-style termination wave: poll every host's (idle, sent,
        received) plus the coordinator's own; done after two consecutive
        polls that are stable, all-idle, and globally balanced."""
        stable = 0
        prev = None
        for _ in range(limit):
            while self._drain(timeout=0.01):
                pass
            vec = []
            for pid in sorted(self.procs):
                r = self.call(pid, {"op": "status"})
                vec.append((pid, r["idle"], r["sent"], r["received"]))
            while self._drain(timeout=0.01):
                pass
            ms, mr = coord_shard.flight_counters()
            vec.append((COORD, coord_shard.net.idle(), ms, mr))
            idle = all(v[1] for v in vec)
            balanced = (sum(v[2] for v in vec) + self._ghost_sent
                        == sum(v[3] for v in vec) + self._ghost_recv)
            if idle and balanced and vec == prev:
                stable += 1
                if stable >= 2:
                    return
            else:
                stable = 0
            prev = vec
        raise AssertionError("socket cluster did not quiesce")

    def close(self) -> None:
        self._hb_stop.set()
        if self._hb_thread.is_alive():
            self._hb_thread.join(timeout=5)
        for pid in list(self.procs):
            try:
                self.drop_host(pid)
            except Exception:
                p = self.procs.pop(pid, None)
                if p is not None:
                    try:
                        p.kill()
                        p.wait(timeout=10)
                    except Exception:
                        pass
        self.ep.close()


class DistCoordinator:
    """Epoch lifecycle of ``ElasticPhaserRuntime``, generalized to
    whole-host churn over a cluster fabric."""

    def __init__(self, cluster, n_hosts: int, *, seed: int = 0,
                 p: float = 0.5, proc_kind: str = "phaser_scsl",
                 axis_name: str = "data", data: Optional[Dict] = None,
                 data_for: Optional[Callable[[int], Dict]] = None,
                 obs: bool = False, live_out: Optional[str] = None,
                 flight_dir: Optional[str] = None):
        self.cluster = cluster
        self.seed = seed
        self.p = p
        self.proc_kind = proc_kind
        self.axis_name = axis_name
        self.data = data
        self._data_for = data_for or (lambda pid: dict(data)
                                      if data is not None else None)
        self.live: Set[int] = set(range(n_hosts))
        self.demoted: Set[int] = set()
        self.next_pid = n_hosts
        self.events: List[HostEvent] = []
        self.epochs: List[DistEpoch] = []
        self._dirty = False
        self._step = 0
        self._gen = 0            # membership incarnation (bumped per death)
        self._strikes: Dict[int, int] = {}
        self._on_epoch: List[Callable[[DistEpoch, DistEpoch], None]] = []
        # obs plane: per-frame span traces collected at every quiescent
        # advance, the O(log P) hop invariant checked per phase, shard
        # metrics merged here (DESIGN.md §12)
        self.obs = ObsHub(p=p) if (obs or live_out) else None
        # streaming telemetry: heartbeat frames appended to --live-out
        # at a bounded cadence; failure edges force a frame through
        self.live_stream = LiveStreamer(live_out) if live_out else None
        # flight-ring flush directory: when set, the coordinator asks
        # shards to flush their rings at the failure edges and flushes
        # its own alongside
        self.flight_dir = flight_dir
        if flight_dir:
            os.makedirs(flight_dir, exist_ok=True)
        # the first step after any (re)compile boundary is warmup: tag
        # it so step-time strike accounting never counts compile time.
        # Only hosts with a data plane ever compile; control-only
        # clusters keep the untagged strike accounting.
        self._has_data = data is not None or data_for is not None
        self._compile_pending = self._has_data
        self.shard = ShardPhaser(COORD, cluster.ep, live=self.live,
                                 p=p, seed=seed, obs=obs)
        # frames swallowed at the fabric (dead destination) still close
        # their spans: wire the fabric's reaper to the coordinator's
        # blackhole edge so the causal trees stay complete
        fab = getattr(cluster, "fabric", None)
        if fab is not None:
            fab.reaper = self._reap_frame
        if cluster.env_sink is None:
            cluster.env_sink = self._ingest_env
        for pid in sorted(self.live):
            cluster.add_host(pid, self._cfg_for(pid))
        self.epochs.append(self._derive_boundary(0, 0))

    # ------------------------------------------------------------ plumbing
    def _ingest_env(self, env) -> None:
        self.shard.net.ingest(env)
        self.shard.net.deliver_all()

    def _reap_frame(self, payload, tag: str) -> None:
        if tag == "env":
            self.shard.net._blackhole(payload)

    def _cfg_for(self, pid: int) -> Dict:
        return {"seed": self.seed, "p": self.p, "axis": self.axis_name,
                "proc_kind": self.proc_kind,
                "live": sorted(self.live), "demoted": sorted(self.demoted),
                "obs": self.obs is not None,
                "flight_dir": self.flight_dir,
                # a host joining after a non-cooperative eviction must be
                # born into the CURRENT incarnation, or the survivors'
                # gen-stamped frames (its own MURS_ACK included) get
                # fenced at its ingest and the splice never completes
                "gen": self._gen,
                "data": self._data_for(pid)}

    def _call(self, pid: int, cmd: Dict, **kw) -> Dict:
        """RPC to a host agent; with obs on, the round-trip latency lands
        in the coordinator's metrics shard keyed by the op name."""
        if self.obs is None:
            return self.cluster.call(pid, cmd, **kw)
        t0 = time.perf_counter()
        r = self.cluster.call(pid, cmd, **kw)
        self.obs.metrics.observe(f"rpc.{cmd['op']}.seconds",
                                 time.perf_counter() - t0)
        return r

    def _collect_obs(self) -> None:
        """Pull every shard's span records + metrics snapshot into the
        hub (the coordinator's own shard and the cluster's transport
        shard included)."""
        assert self.obs is not None
        self.obs.ingest(COORD, self.shard.drain_obs())
        self.obs.watermarks.update(COORD, self.shard.watermarks.snapshot(),
                                   gen=self._gen)
        for pid in sorted(self.live):
            r = self._call(pid, {"op": "obs"})
            self.obs.ingest(pid, r["spans"], r["metrics"])
            # merge the shard's phase watermarks: per-host monotonicity
            # asserted here, across churn and generation bumps
            self.obs.watermarks.update(pid, r.get("watermarks"),
                                       gen=self._gen)
        cm = getattr(self.cluster, "metrics", None)
        if cm is not None:
            self.obs.ingest(-2, [], cm.snapshot())
        fc = getattr(self.cluster, "fault_counters", None)
        if fc is not None:
            for k, v in fc().items():
                self.obs.metrics.set(f"fault.{k}", v)

    def export_obs(self, trace_path: Optional[str] = None,
                   metrics_path: Optional[str] = None) -> None:
        assert self.obs is not None, "coordinator built without obs=True"
        self.obs.export(trace_path, metrics_path)

    def _emit_live_frame(self, *, phase: int, force: bool = False) -> None:
        """One heartbeat frame to --live-out (rate-limited unless the
        caller forces; failure edges always force)."""
        if self.live_stream is None or self.obs is None:
            return
        det = getattr(self.cluster, "detector", None)
        phi = None
        if det is not None:
            phi = {}
            for p in sorted(self.live):
                try:
                    phi[p] = det.phi(p)
                except Exception:
                    pass
        self.live_stream.frame(
            step=self._step, phase=phase, epoch=self.epoch.index,
            gen=self._gen, live=sorted(self.live),
            watermarks=self.obs.watermarks,
            merged_metrics=self.obs.merged_metrics(), phi=phi,
            events=[[e.step, e.kind, e.pid] for e in self.events],
            force=force)

    def _flush_flight(self, reason: str,
                      pids: Optional[Sequence[int]] = None) -> None:
        """Best-effort flight-ring flush: the coordinator's own ring
        plus the given shards' (default: every live host). Never raises
        — these are failure edges."""
        if not self.flight_dir:
            return
        self.shard.flight.flush(flight_path(self.flight_dir, COORD),
                                reason)
        for pid in (sorted(self.live) if pids is None else pids):
            try:
                self._call(pid, {"op": "flight_flush",
                                 "dir": self.flight_dir,
                                 "reason": reason}, timeout=30.0)
            except Exception:
                pass    # a flush must never extend a failure cascade

    def _quiesce(self) -> None:
        self.cluster.quiesce(self.shard)

    def _broadcast_membership(self) -> None:
        live, dem = sorted(self.live), sorted(self.demoted)
        self.shard.note_membership(live, dem)
        for pid in live:
            self._call(pid, {"op": "note_membership",
                                    "live": live, "demoted": dem})

    # ------------------------------------------------------------- epochs
    @property
    def epoch(self) -> DistEpoch:
        return self.epochs[-1]

    @property
    def gen(self) -> int:
        return self._gen

    @property
    def pending_churn(self) -> bool:
        return self._dirty

    def on_epoch(self, fn: Callable[[DistEpoch, DistEpoch], None]) -> None:
        self._on_epoch.append(fn)

    def _derive_boundary(self, index: int, phase_start: int) -> DistEpoch:
        """Every process (coordinator included) re-derives the oracle,
        checks its partition, fingerprints, re-commits its cache."""
        live, dem = sorted(self.live), sorted(self.demoted)
        self.shard.note_membership(live, dem)
        t0 = self.obs.timeline.now() if self.obs is not None else 0.0
        tr = self.shard.tracer
        if tr is not None:
            # the fingerprint round is a causal tree too: one epoch root,
            # one child span per host the coordinator polls
            tr.root("epoch", index)
        sl = self.shard.oracle()
        view = sl.partition(self.shard.owner_of).get(COORD)
        if view is not None:
            for lid in (SCSL, SNSL):
                d = view.diff(self.shard.local_states(lid))
                assert not d, f"coordinator lid {lid}: {d}"
        fps = {COORD: sl.fingerprint()}
        pk = None
        for pid in live:
            if tr is not None:
                tr.span_under(index, "derive_epoch", pid)
            r = self._call(pid, {"op": "derive_epoch", "index": index,
                                        "live": live, "demoted": dem})
            fps[pid] = r["fingerprint"]
            pk = r.get("program_key", pk)
        assert len(set(fps.values())) == 1, f"fingerprint split: {fps}"
        # boundary re-commits every process's program cache: the next
        # observed step pays compile/warmup and must not strike anyone
        if self._has_data:
            self._compile_pending = True
        if self.obs is not None:
            self.obs.timeline.complete("epoch.derive", t0, cat="control",
                                       args={"index": index,
                                             "n": len(live)})
        return DistEpoch(index, phase_start, tuple(live), tuple(dem),
                         fps[COORD], pk)

    # ------------------------------------------------------------- churn
    def request_join(self, parent: Optional[int] = None, *,
                     step: Optional[int] = None) -> int:
        """Host arrival: spawn/attach the process, materialize its actor
        on its own shard (fast single-link path starts at the parent's
        owner), run the splice + lazy promotion to quiescence.

        Any host already declared dead is evicted FIRST: the cooperative
        splice assumes every participant answers, so running it against
        a membership that still contains a corpse would leave the
        structure partially linked (frames to the dead host are reaped
        at the fabric, never acked)."""
        self._check_cluster_failures(step=step)
        pid = self.next_pid
        self.next_pid += 1
        if parent is None:
            parent = min(self.live)
        self.cluster.add_host(pid, self._cfg_for(pid))
        self._call(pid, {"op": "create_member", "new": pid,
                                "parent": parent})
        self.live.add(pid)
        self._call(parent, {"op": "start_insert", "new": pid,
                                   "parent": parent})
        self._quiesce()
        self._broadcast_membership()
        self.events.append(HostEvent(self._at(step), "join", pid))
        self._dirty = True
        return pid

    def request_leave(self, pid: int, *, fail: bool = False,
                      step: Optional[int] = None) -> None:
        """Host eviction: the existing demote→evict path — DEREG lowers
        the expectation, level-by-level unlink runs to quiescence, then
        the process leaves the cluster."""
        self._check_cluster_failures(step=step)
        if pid not in self.live:
            return                    # already evicted non-cooperatively
        self._call(pid, {"op": "drop", "key": pid})
        self._quiesce()
        self.live.discard(pid)
        self.demoted.discard(pid)
        self._strikes.pop(pid, None)
        self._broadcast_membership()
        if self.obs is not None:
            # the departing host's half of the eviction tree (its root
            # span + deliveries) must be salvaged before the process goes
            r = self._call(pid, {"op": "obs"})
            self.obs.ingest(pid, r["spans"], r["metrics"])
            self.obs.watermarks.update(pid, r.get("watermarks"),
                                       gen=self._gen)
            self.obs.watermarks.retire(pid)
        if self.flight_dir:
            self._flush_flight("leave", pids=[pid])
        self.cluster.drop_host(pid)
        self.events.append(HostEvent(self._at(step),
                                     "fail" if fail else "leave", pid))
        self._dirty = True

    def request_demote(self, pid: int, *, step: Optional[int] = None) -> None:
        self._check_cluster_failures(step=step)
        if pid not in self.live or pid in self.demoted:
            return
        self._call(pid, {"op": "demote", "key": pid})
        self._quiesce()
        self.demoted.add(pid)
        self._broadcast_membership()
        self.events.append(HostEvent(self._at(step), "demote", pid))
        self._dirty = True

    def request_repromote(self, pid: int, *,
                          step: Optional[int] = None) -> None:
        self._check_cluster_failures(step=step)
        if pid not in self.live or pid not in self.demoted:
            return
        self._call(pid, {"op": "repromote", "key": pid})
        self._quiesce()
        self.demoted.discard(pid)
        self._broadcast_membership()
        self.events.append(HostEvent(self._at(step), "repromote", pid))
        self._dirty = True

    def _at(self, step: Optional[int]) -> int:
        return self._step if step is None else step

    # ----------------------------------------------------------- recovery
    def _check_cluster_failures(self, *, step: Optional[int] = None
                                ) -> List[int]:
        """Proactively recover any host the cluster's detector has
        declared dead; returns the pids recovered this call."""
        poll = getattr(self.cluster, "poll_failures", None)
        if poll is None:
            return []
        recovered = []
        for pid in poll():
            if pid in self.live:
                self.recover_failure(pid, step=step)
                recovered.append(pid)
        return recovered

    def recover_failure(self, pid: int, *,
                        step: Optional[int] = None) -> None:
        """Non-cooperative eviction of a crashed host (DESIGN.md §13).

        The dead host cannot answer unlink handshakes, so instead of the
        cooperative two-phase dance every survivor re-seeds its shard
        from the surviving membership's oracle at the coordinator's
        released phase (``ShardPhaser.rebuild``), under a bumped
        generation that fences the dead incarnation's in-flight frames.
        A survivor dying *during* recovery just extends the cascade."""
        pending = [pid]
        while pending:
            d = pending.pop(0)
            if d not in self.live:
                continue
            t0 = time.perf_counter()
            det = getattr(self.cluster, "detector", None)
            decl = (dict(det.declared[d])
                    if det is not None and d in det.declared else None)
            tr = self.shard.tracer
            if tr is not None:
                tr.root("failure", d)
            self.live.discard(d)
            self.demoted.discard(d)
            self._strikes.pop(d, None)
            self.cluster.mark_dead(d)
            self._gen += 1
            phase = self.shard.released()
            live, dem = sorted(self.live), sorted(self.demoted)
            self.shard.rebuild(live, dem, phase, self._gen)
            # the Mattern balance restarts for the new incarnation: the
            # dead host's final counters are unknowable, and rebuild
            # zeroed every survivor's flight counters
            if hasattr(self.cluster, "_ghost_sent"):
                self.cluster._ghost_sent = 0
                self.cluster._ghost_recv = 0
            for s in live:
                if tr is not None:
                    tr.span_under(d, "force_evict", s)
                try:
                    self._call(s, {"op": "force_evict", "live": live,
                                   "demoted": dem, "phase": phase,
                                   "gen": self._gen})
                except HostDead as e:
                    if e.pid not in pending:
                        pending.append(e.pid)
            self.events.append(HostEvent(self._at(step), "dead", d))
            self._dirty = True
            if self.obs is not None:
                self.obs.note_lost(d)
                # the corpse's watermark freezes at its last observed
                # value, then leaves the live view — survivors keep
                # asserting monotone against their own floors
                self.obs.watermarks.retire(d)
                self.obs.metrics.inc("failure.declared_dead")
                self.obs.metrics.observe("failure.recover_seconds",
                                         time.perf_counter() - t0)
                if decl is not None:
                    self.obs.metrics.observe("failure.detection_seconds",
                                             decl["silence"])
        # SIGKILL-survivor recovery: the corpse wrote nothing, so the
        # record of the death is every survivor's ring (+ the
        # coordinator's own), flushed now
        self._flush_flight("peer-dead")
        self._emit_live_frame(phase=self.shard.released(), force=True)

    # ----------------------------------------------------------- stepping
    def advance(self, *, step: Optional[int] = None) -> int:
        """One phase, fault-tolerant: any ``HostDead`` surfaced while
        signalling/quiescing triggers non-cooperative recovery, after
        which the whole phase is retried against the survivors (the
        rebuild reset every survivor's signal cursor, and generation
        fencing discards the aborted attempt's frames)."""
        last: Optional[HostDead] = None
        for _ in range(2 + len(self.live)):
            self._check_cluster_failures(step=step)
            if not self.live:
                raise RuntimeError("advance: no live hosts left")
            try:
                return self._advance_once(step=step)
            except HostDead as e:
                last = e
                self.recover_failure(e.pid, step=step)
        raise RuntimeError(f"advance: unrecoverable failure cascade "
                           f"({last})")

    def _advance_once(self, *, step: Optional[int] = None) -> int:
        """One phase: every live host signals its own actor, the
        protocol quiesces across processes, and a dirty boundary derives
        (and verifies) the next epoch on every survivor."""
        for pid in sorted(self.live):
            self._call(pid, {"op": "signal"})
        self._quiesce()
        released = self.shard.released()
        if self.obs is not None:
            # drain one phase's spans from every shard, then assert the
            # per-signal critical path stays within the O(log P) bound —
            # this runs at EVERY quiescent advance, churn included
            self._collect_obs()
            self.obs.check_window(len(self.live), phase=released)
            self._emit_live_frame(phase=released)
        if self._dirty:
            old = self.epoch
            new = self._derive_boundary(old.index + 1, released + 1)
            self.epochs.append(new)
            self._dirty = False
            for fn in self._on_epoch:
                fn(old, new)
        if step is not None:
            self._step = step
        self._step += 1
        return released

    def _abort_step(self, step: int) -> None:
        """Best-effort out-of-band unwind: survivors blocked inside a
        peer-exchange step can't serve commands, so the abort rides the
        raw ``ctl`` stream their in-step recv loop does watch."""
        if not getattr(self.cluster, "peer_exchange", False):
            return
        for pid in sorted(self.live):
            try:
                self.cluster.ep.send(pid, "ctl", ("abort_step", step))
            except Exception:
                pass

    def train_step(self, step: int) -> Dict[int, Dict]:
        """One data-parallel step across the cluster, fault-tolerant:
        a crash mid-step aborts the survivors' exchanges (``ctl``),
        recovers the membership, then resolves via ``step_status`` —
        every survivor already applied → the step is done; none →
        retry it against the shrunk cluster; a strict subset →
        ``StepInconsistent`` (params diverged; the caller falls back to
        a checkpoint-consistent ``resume``)."""
        for attempt in range(4):
            self._check_cluster_failures(step=step)
            if not self.live:
                raise RuntimeError("train_step: no live hosts left")
            try:
                return self._train_step_once(step)
            except HostDead as e:
                self._abort_step(step)
                self.recover_failure(e.pid, step=step)
            except _StepAborted:
                self._abort_step(step)
                self._check_cluster_failures(step=step)
            res = self._resolve_step(step)
            if res is not None:
                return res
        raise RuntimeError(f"train_step {step}: retries exhausted")

    def _train_step_once(self, step: int) -> Dict[int, Dict]:
        """One data-parallel step across the cluster: local grads + local
        reduce on every host, the process-level schedule between hosts,
        jitted apply everywhere. Socket mode exchanges the rounds
        peer-to-peer; in-process mode mirrors them centrally (bitwise
        identical — see ``exchange``)."""
        pids = sorted(self.live)
        if self.cluster.peer_exchange:
            handles = [(pid, self.cluster.post(pid, {"op": "step",
                                                     "step": step,
                                                     "gen": self._gen}))
                       for pid in pids]
            out = {}
            try:
                # collect in ARRIVAL order: the first "aborted" reply
                # triggers the out-of-band unwind immediately, so a
                # peer blocked on its in-step recv (e.g. behind a link
                # partition) is released by the sequenced ctl abort
                # instead of pinning this loop on its 300 s deadline
                waiting = {h: pid for pid, h in handles}
                abort_sent = False
                while waiting:
                    h, r = self.cluster.collect_any(list(waiting),
                                                    watch=pids)
                    out[waiting.pop(h)] = r
                    if r.get("aborted") and not abort_sent:
                        abort_sent = True
                        self._abort_step(step)
            except BaseException:
                ab = getattr(self.cluster, "abandon", None)
                if ab is not None:
                    ab([h for _, h in handles])
                raise
            aborted = [p for p, r in out.items() if r.get("aborted")]
            if aborted:
                raise _StepAborted(step, aborted)
            return out
        bufs = {pid: self._call(pid, {"op": "step_local",
                                             "step": step})["buf"]
                for pid in pids}
        red = run_schedule_rounds(self._proc_schedule(), bufs)
        return {pid: self._call(pid, {"op": "step_apply",
                                             "buf": red[pid],
                                             "step": step})
                for pid in pids}

    def _resolve_step(self, step: int) -> Optional[Dict[int, Dict]]:
        """Post-crash consistency probe: ask every survivor which step
        it last applied. All applied ``step`` → return their recorded
        results; none → None (the caller retries the step); a strict
        subset → ``StepInconsistent``."""
        while True:
            stat: Dict[int, Dict] = {}
            try:
                for pid in sorted(self.live):
                    stat[pid] = self._call(pid, {"op": "step_status"})
            except HostDead as e:
                self.recover_failure(e.pid, step=step)
                continue
            if not stat:
                return None
            applied = {p for p, s in stat.items()
                       if s.get("step") == step}
            if not applied:
                return None
            if applied == set(stat):
                if self.obs is not None:
                    self.obs.metrics.inc("failure.step_resolved_applied")
                return {p: {k: v for k, v in stat[p].items()
                            if k != "ok"} for p in stat}
            raise StepInconsistent(step, {p: s.get("step", -1)
                                          for p, s in stat.items()})

    def _proc_schedule(self):
        from ..core.collective import PhaserCollective
        keys = tuple(sorted(self.live))
        pc = PhaserCollective(len(keys), self.axis_name,
                              kind=self.proc_kind, seed=self.seed,
                              p=self.p, keys=keys,
                              leaf_keys=tuple(sorted(self.demoted)))
        sched = pc.unified_schedule()
        assert sched is not None, self.proc_kind
        return sched

    # --------------------------------------------------------- stragglers
    def record_step_times(self, step: int, times: Dict[int, float], *,
                          slack: float = 3.0, demote_after: int = 2,
                          evict_after: int = 3) -> List[int]:
        """Whole-host straggler policy — the same ``StrikeEscalation``
        the single-process runtime applies to workers, applied to
        processes: straggle, demote to a leaf, then evict."""
        from ..runtime_elastic.strikes import StrikeAction, StrikeEscalation
        esc = StrikeEscalation(slack=slack, demote_after=demote_after,
                               evict_after=evict_after,
                               strikes=self._strikes,
                               metrics=self.obs.metrics if self.obs
                               else None)
        evicted: List[int] = []

        def apply(act: StrikeAction) -> None:
            if act.action == "straggle":
                self.events.append(HostEvent(step, "straggle", act.worker))
            elif act.action == "evict":
                self.request_leave(act.worker, fail=True, step=step)
                evicted.append(act.worker)
            elif act.action == "demote":
                self.request_demote(act.worker, step=step)
            elif act.action == "recover":
                self.request_repromote(act.worker, step=step)

        compile_step = self._compile_pending
        self._compile_pending = False
        # wait attribution: a host slow because it *waited* on peers is
        # a victim, not a culprit — its blocked-on-WAIT seconds since
        # the last policy call are subtracted before the median test
        waits = (self.obs.watermarks.take_wait_deltas()
                 if self.obs is not None else None)
        esc.observe(self.live, times, demoted=self.demoted,
                    on_action=apply, compile_step=compile_step,
                    waits=waits)
        return evicted

    # ------------------------------------------------------- checkpointing
    def save_checkpoint(self, step: int) -> Dict:
        """Boundary checkpoint, written by the lowest live host (its
        manifest records the process set via the agent's program key)."""
        return self._call(min(self.live), {"op": "save",
                                                  "step": step})

    def precompile_all(self, program_key: Dict) -> Dict[int, bool]:
        """Compile (or cache-hit) the program identified by a manifest
        key on every live host; returns pid -> freshly-compiled flag."""
        return {pid: self._call(
                    pid, {"op": "precompile",
                          "program_key": program_key})["compiled"]
                for pid in sorted(self.live)}

    def restore_all(self, step: Optional[int] = None) -> int:
        steps = {pid: self._call(pid, {"op": "restore",
                                              **({"step": step}
                                                 if step is not None
                                                 else {})})["step"]
                 for pid in sorted(self.live)}
        assert len(set(steps.values())) == 1, steps
        return next(iter(steps.values()))

    def resume(self, step: Optional[int] = None) -> Dict:
        """Resume from the checkpoint manifest: read the recorded
        program key (the process set live AT SAVE TIME — after an
        eviction that is the surviving-host set, not the boot set),
        pre-compile that program on every live host, then restore the
        arrays. The pre-compile runs BEFORE the restore so the first
        post-resume step hits an already-built executable."""
        rep = self._call(min(self.live),
                                {"op": "manifest_key",
                                 **({"step": step} if step is not None
                                    else {})})
        pk = rep["program_key"]
        assert pk is not None, "checkpoint manifest has no program key"
        compiled = self.precompile_all(pk)
        restored = self.restore_all(step)
        return {"step": restored, "program_key": pk,
                "compiled": compiled}

    # --------------------------------------------------------- inspection
    def control_stats(self) -> Dict:
        """Cluster-wide control-plane counters (quiescent state)."""
        per = {pid: self._call(pid, {"op": "status"})
               for pid in sorted(self.live)}
        ms, mr = self.shard.flight_counters()
        frames = sum(v["sent"] for v in per.values()) + ms
        depth = max([v["max_depth"] for v in per.values()]
                    + [self.shard.net.max_depth])
        out = {"live": sorted(self.live), "epoch": self.epoch.index,
               "phase": self.shard.released(),
               "remote_frames": frames, "critical_path": depth,
               "per_host": per}
        if self.obs is not None:
            out["obs"] = self.obs.summary()
        return out

    def close(self) -> None:
        if self.obs is not None and self.live:
            try:
                self._collect_obs()   # epoch spans since the last advance
                self._emit_live_frame(phase=self.shard.released(),
                                      force=True)
            except Exception:
                pass                  # never let teardown fail on obs
        if self.live_stream is not None:
            self.live_stream.close()
        self.cluster.close()
