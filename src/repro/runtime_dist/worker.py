"""Host-process entry point: ``python -m repro.runtime_dist.worker``.

One OS process of the multi-host runtime. Joins the socket fabric at the
well-known path for its pid, waits for the coordinator's ``init`` command
(which carries the full agent config), then serves the frame loop:

  env  — protocol envelope for a locally-owned actor: ingest + deliver
         (deliveries may send further envelopes out through the fabric)
  cmd  — coordinator command: dispatch to ``HostAgent.handle``, reply
         on the ``rep`` stream
  red  — a peer's reduction round arriving outside a step (the peer is
         already inside its step): held for this process's next step

Control-plane-only configs (``data: null``) never import jax — the
latency benchmark spawns these by the dozen.
"""
from __future__ import annotations

import argparse
import sys

from .agent import HostAgent
from .transport import SocketEndpoint


def serve(pid: int, directory: str) -> int:
    ep = SocketEndpoint(pid, directory)
    agent = None
    pending = []            # env frames that beat the init command
    try:
        while True:
            frame = ep.recv(timeout=1.0)
            if frame is None:
                continue
            src, tag, payload = frame
            if tag == "env":
                if agent is None:
                    pending.append(payload)
                    continue
                agent.shard.net.ingest(payload)
                agent.shard.net.deliver_all()
            elif tag == "red":
                assert agent is not None
                agent._deferred.append(frame)
            elif tag == "cmd":
                cid, cmd = payload
                if cmd["op"] == "init":
                    agent = HostAgent(pid, ep, cmd["cfg"])
                    for env in pending:
                        agent.shard.net.ingest(env)
                    pending.clear()
                    agent.shard.net.deliver_all()
                    reply = {"ok": True, "pid": pid}
                elif cmd["op"] == "shutdown":
                    ep.send(src, "rep", (cid, {"ok": True}))
                    return 0
                else:
                    reply = agent.handle(cmd)
                    for f in agent.drain_deferred():
                        agent.shard.net.ingest(f[2])
                    agent.shard.net.deliver_all()
                ep.send(src, "rep", (cid, reply))
            else:
                raise AssertionError(f"worker {pid}: bad tag {tag!r}")
    finally:
        ep.close()


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", required=True)
    ap.add_argument("--pid", type=int, required=True)
    args = ap.parse_args(argv)
    return serve(args.pid, args.dir)


if __name__ == "__main__":
    sys.exit(main())
