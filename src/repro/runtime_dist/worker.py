"""Host-process entry point: ``python -m repro.runtime_dist.worker``.

One OS process of the multi-host runtime. Joins the socket fabric at the
well-known path for its pid, waits for the coordinator's ``init`` command
(which carries the full agent config), then serves the frame loop:

  env  — protocol envelope for a locally-owned actor: ingest + deliver
         (deliveries may send further envelopes out through the fabric)
  cmd  — coordinator command: dispatch to ``HostAgent.handle``, reply
         on the ``rep`` stream. Replies are cached per command id and
         replayed verbatim for a duplicated/retried cmd — every op is
         therefore exactly-once even under at-least-once delivery.
  red  — a peer's reduction round arriving outside a step (the peer is
         already inside its step): held for this process's next step
  ctl  — out-of-band step control (abort); outside a step it is stale
  hb   — heartbeats never reach this loop: the endpoint's reader
         thread echoes them (``hb_echo``), so liveness stays decoupled
         from command latency (a long jax compile is not a death)

Orphan exit (DESIGN.md §13): if no frame — heartbeats included —
arrives for ``PHASER_ORPHAN_TIMEOUT`` seconds the coordinator is
presumed dead; the worker flushes its span shard to
``<dir>/worker<pid>.spans.jsonl`` and exits with code 2 instead of
spinning forever.

Control-plane-only configs (``data: null``) never import jax — the
latency benchmark spawns these by the dozen.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from collections import OrderedDict, deque

from .agent import HostAgent
from .transport import endpoint_cls

_DEDUPE_CAP = 512       # replay window of cached (cid -> reply) entries


def _flush_spans(agent, directory: str, pid: int) -> None:
    """Salvage this shard's span records to disk before an orphan exit
    (the coordinator that would normally collect them is gone)."""
    try:
        spans = agent.shard.drain_obs() if agent is not None else []
        path = os.path.join(directory, f"worker{pid}.spans.jsonl")
        with open(path, "w") as f:
            for r in spans:
                f.write(json.dumps(r) + "\n")
    except Exception:
        pass                    # best effort: never mask the exit path


def _flush_flight(agent, directory: str, pid: int, reason: str) -> None:
    """Flush the flight ring on a failure edge (crash / orphan exit):
    the bounded window of recent records survives even though the
    coordinator will never collect this shard again."""
    if agent is None:
        return
    try:
        from ..obs.recorder import flight_path
        d = agent.cfg.get("flight_dir") or directory
        agent.shard.flight.event("exit", reason=reason)
        agent.shard.flight.flush(flight_path(d, pid), reason)
    except Exception:
        pass                    # best effort: never mask the exit path


def _send_rep(ep, src: int, cid: int, reply: dict) -> None:
    """A lost reply must not kill the worker: the RPC layer is
    at-least-once, so the coordinator retransmits the command, the cid
    dedupe replays the cached reply, and a transient partition (link
    fault, coordinator restart in flight) heals instead of escalating
    a heal-able outage into a worker crash."""
    from .failure import PeerUnreachable
    try:
        ep.send(src, "rep", (cid, reply))
    except (PeerUnreachable, OSError, ValueError):
        pass


def serve(pid: int, directory: str,
          orphan_timeout: float | None = None,
          fabric: str = "unix") -> int:
    if orphan_timeout is None:
        orphan_timeout = float(os.environ.get("PHASER_ORPHAN_TIMEOUT",
                                              "30"))
    ep = endpoint_cls(fabric)(pid, directory, hb_echo=True)
    agent = None
    pending = []            # env frames that beat the init command
    pending_red = []        # red frames that beat the init command
    done: "OrderedDict[int, dict]" = OrderedDict()   # cid -> reply
    backlog: deque = deque()    # cmd frames deferred during a step
    try:
        while True:
            frame = backlog.popleft() if backlog else ep.recv(timeout=1.0)
            if frame is None:
                if time.monotonic() - ep.last_rx > orphan_timeout:
                    # coordinator silent past the heartbeat horizon:
                    # flush observability state and exit cleanly
                    _flush_spans(agent, directory, pid)
                    _flush_flight(agent, directory, pid, "orphan")
                    return 2
                continue
            src, tag, payload = frame
            if tag == "env":
                if agent is None:
                    pending.append(payload)
                    continue
                agent.shard.net.ingest(payload)
                agent.shard.net.deliver_all()
            elif tag == "red":
                if agent is None:
                    pending_red.append(frame)
                else:
                    agent.hold_red(frame)
            elif tag in ("ctl", "hb"):
                continue        # stale outside a step / unechoed hb
            elif tag == "cmd":
                cid, cmd = payload
                if cid in done:
                    # duplicated or retried command: replay the cached
                    # reply without re-executing (idempotency)
                    _send_rep(ep, src, cid, done[cid])
                    continue
                if cmd["op"] == "init":
                    agent = HostAgent(pid, ep, cmd["cfg"])
                    for env in pending:
                        agent.shard.net.ingest(env)
                    pending.clear()
                    for f in pending_red:
                        agent.hold_red(f)
                    pending_red.clear()
                    agent.shard.net.deliver_all()
                    reply = {"ok": True, "pid": pid}
                elif cmd["op"] == "shutdown":
                    ep.send(src, "rep", (cid, {"ok": True}))
                    return 0
                else:
                    reply = agent.handle(cmd)
                    for f in agent.drain_deferred():
                        if f[1] == "env":
                            agent.shard.net.ingest(f[2])
                        elif f[1] == "cmd":
                            backlog.append(f)
                    agent.shard.net.deliver_all()
                done[cid] = reply
                while len(done) > _DEDUPE_CAP:
                    done.popitem(last=False)
                _send_rep(ep, src, cid, reply)
            else:
                raise AssertionError(f"worker {pid}: bad tag {tag!r}")
    except Exception:
        # crash path: the ring is the only record of what this shard
        # was doing — flush it before the traceback propagates
        _flush_flight(agent, directory, pid, "crash")
        raise
    finally:
        ep.close()


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", required=True)
    ap.add_argument("--pid", type=int, required=True)
    ap.add_argument("--orphan-timeout", type=float, default=None)
    ap.add_argument("--fabric", default="unix", choices=["unix", "tcp"])
    args = ap.parse_args(argv)
    return serve(args.pid, args.dir, orphan_timeout=args.orphan_timeout,
                 fabric=args.fabric)


if __name__ == "__main__":
    sys.exit(main())
