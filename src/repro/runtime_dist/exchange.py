"""Process-level schedule execution: ppermute rounds over the transport.

The hierarchical program's level-1 sync runs the epoch's round schedule
(``Schedule``: partial permutations with per-round add/copy ops) between
*processes*. Two executors produce bitwise-identical f32 results:

* ``run_schedule_rounds``  — central, round-major, over a dict of host
  buffers. The in-process cluster uses it (one thread can't block on
  peer receives), and it doubles as the reference mirror.
* ``exchange_schedule``    — the per-process half: each participant
  sends its pre-round buffer and applies at most one incoming buffer
  per round (schedules are partial permutations, so a destination
  receives exactly one message per round — same single-port model as
  the protocol's FIFO channels).

Equality across the two holds because each destination applies exactly
one combine per round, in round order, in f32.
"""
from __future__ import annotations

from typing import TYPE_CHECKING, Callable, Dict, Sequence

import numpy as np

if TYPE_CHECKING:  # core.collective imports jax; control-plane-only
    from ..core.collective import Schedule  # processes must stay jax-free


def run_schedule_rounds(sched: "Schedule",
                        bufs: Dict[int, np.ndarray], *,
                        metrics=None) -> Dict[int, np.ndarray]:
    """Execute ``sched`` centrally over per-rank f32 buffers (rank i of
    the schedule = sorted key i of ``bufs``). Returns the final buffers
    keyed like the input. ``metrics`` (an ``obs.MetricsRegistry``)
    accounts rounds and mirrored bytes."""
    keys = sorted(bufs)
    assert len(keys) == sched.n, (keys, sched.n)
    vals = [np.asarray(bufs[k], dtype=np.float32) for k in keys]
    for r, pairs in enumerate(sched.rounds):
        incoming = {d: vals[s].copy() for s, d in pairs}
        op = sched.op(r)
        for d, v in incoming.items():
            vals[d] = vals[d] + v if op == "add" else v
        if metrics is not None:
            metrics.inc("exchange.rounds")
            metrics.inc("exchange.bytes_moved",
                        sum(v.nbytes for v in incoming.values()))
    return {k: vals[i] for i, k in enumerate(keys)}


def exchange_schedule(sched: "Schedule", rank: int, pids: Sequence[int],
                      buf: np.ndarray, *,
                      send: Callable[[int, int, np.ndarray], None],
                      recv: Callable[[int, int], np.ndarray],
                      metrics=None) -> np.ndarray:
    """One participant's walk through ``sched``. ``pids[i]`` is the
    process id executing schedule rank ``i``; ``send(dst_pid, round,
    arr)`` / ``recv(src_pid, round)`` are the transport hooks (recv
    blocks until the peer's frame for that round arrives). ``metrics``
    accounts this participant's rounds and bytes sent."""
    buf = np.asarray(buf, dtype=np.float32)
    for r, pairs in enumerate(sched.rounds):
        out = [d for s, d in pairs if s == rank]
        inc = [s for s, d in pairs if d == rank]
        for d in out:
            send(pids[d], r, buf.copy())
        if metrics is not None:
            metrics.inc("exchange.rounds")
            if out:
                metrics.inc("exchange.bytes_sent",
                            buf.nbytes * len(out))
        if inc:
            (s,) = inc  # partial permutation: at most one per round
            v = recv(pids[s], r)
            buf = buf + v if sched.op(r) == "add" else v
    return buf
