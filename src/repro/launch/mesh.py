"""Production mesh construction. A FUNCTION, not a module-level constant:
importing this module never touches jax device state."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips (data, model). Multi-pod: 2 pods of
    16x16 = 512 chips (pod, data, model); the pod axis multiplies data
    parallelism and is the axis the dry-run proves out for cross-pod
    (DCN-class) collectives."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    return jax.make_mesh(tuple(shape), tuple(axes))


# TPU v5e-class hardware constants used by the roofline analysis.
HW = {
    "peak_flops_bf16": 197e12,   # per chip
    "hbm_bw": 819e9,             # bytes/s per chip
    "ici_bw": 50e9,              # bytes/s per link (~per-direction)
    "hbm_bytes": 16e9,           # capacity per chip
}
