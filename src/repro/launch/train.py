"""Training launcher CLI.

  PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
      --steps 200 --batch 8 --seq 256 --reduced --ckpt-dir /tmp/ckpt

``--reduced`` trains the family-reduced config on CPU (the end-to-end
example path); full configs target real accelerators with the same code.

Elastic mode attaches the phaser-epoch control plane
(runtime_elastic.elastic_phaser) and drives membership churn from a
schedule of events, e.g.:

  ... --workers 4 --elastic "join@30,join@35,fail@60,leave@80"

Each event is ``kind@step`` (kind: join | leave | fail; leave/fail may
pin a worker with ``kind:wid@step``). The loop re-lowers its compiled
step at every epoch boundary and prints the epoch log.

``--host-devices 8`` splits the host CPU into a simulated 8-device mesh
(must be the first thing to touch jax, so it is applied before any
device use) and ``--device-collective`` forces gradient sync through the
execution engine's compiled shard_map programs; by default the engine is
used automatically whenever more than one device is visible and the
batch divides the team. ``--overlap-sync`` compiles the pipelined
programs (DESIGN.md §5): reverse-topo bucket groups sync while the
backward pass still runs, and with ``--microbatches N`` each
microbatch's bucket stream overlaps the next microbatch's backward.

``--pipeline-stages S`` (DESIGN.md §6) compiles the 2-D program
instead: the stacked blocks shard over a stage axis
(workers x S devices), microbatches flow through the wave-synchronous
1F1B schedule derived from the point-to-point phaser graph, and each
stage row syncs gradients over the data axis through the epoch's
collective schedule — churn re-derives both at the same boundary.
``--interleave v`` runs the INTERLEAVED 1F1B order: each device owns v
non-contiguous model chunks, cutting the pipeline bubble fraction from
(S-1)/(M+S-1) to (S-1)/(vM+S-1); requires the scan length to divide by
S*v and ``--microbatches`` to divide by S.
"""
from __future__ import annotations

import argparse
import json
import os

import jax

from ..checkpoint import CheckpointManager
from ..data import SyntheticLM
from ..models.registry import get_api, get_config
from ..optim import AdamW
from ..runtime_elastic import ElasticPhaserRuntime
from ..train.loop import TrainLoop


def parse_elastic(spec: str):
    """'join@30,fail@60,leave:2@80' -> {30: [("join", None)], ...}."""
    events = {}
    for item in spec.split(","):
        item = item.strip()
        if not item:
            continue
        if "@" not in item:
            raise ValueError(f"elastic event {item!r}: expected kind@step "
                             "(e.g. join@30, leave:2@80)")
        kind, step = item.split("@", 1)
        wid = None
        if ":" in kind:
            kind, w = kind.split(":", 1)
            wid = int(w)
        if kind not in ("join", "leave", "fail"):
            raise ValueError(f"elastic event kind {kind!r}: expected "
                             "join | leave | fail")
        events.setdefault(int(step), []).append((kind, wid))
    return events


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--layers", type=int, default=None,
                    help="override the config's layer count (e.g. to "
                         "make the scan axis divide stages*interleave)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--workers", type=int, default=4,
                    help="initial elastic worker-group size")
    ap.add_argument("--elastic", default=None,
                    help='churn schedule, e.g. "join@30,fail@60"')
    ap.add_argument("--sync-kind", default="phaser_scsl",
                    choices=["phaser_scsl", "recursive_doubling",
                             "halving_doubling", "xla_psum"],
                    help="per-epoch gradient-sync schedule (every kind "
                         "now covers non-power-of-two teams)")
    ap.add_argument("--host-devices", type=int, default=None,
                    help="split the host into N simulated devices "
                         "(XLA_FLAGS; must precede first jax device use)")
    ap.add_argument("--device-collective", action="store_true",
                    help="require gradient sync through the compiled "
                         "shard_map engine (default: auto)")
    ap.add_argument("--overlap-sync", action="store_true",
                    help="pipeline gradient sync against the backward "
                         "pass (reverse-topo bucket groups, "
                         "double-buffered rounds; device path only)")
    ap.add_argument("--pipeline-stages", type=int, default=1,
                    help="pipeline parallelism: shard the stacked "
                         "blocks over a stage axis and run the 1F1B "
                         "wave schedule on a 2-D (stage x data) mesh; "
                         "needs workers*stages devices and "
                         "--microbatches as the pipeline depth "
                         "(device path only)")
    ap.add_argument("--interleave", type=int, default=1,
                    help="virtual stages per device: run the "
                         "interleaved 1F1B schedule (v non-contiguous "
                         "model chunks per device, bubble fraction "
                         "(S-1)/(vM+S-1)); scan length must divide by "
                         "stages*interleave and --microbatches by "
                         "stages")
    args = ap.parse_args(argv)

    if args.host_devices:
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={args.host_devices}"
        ).strip()
        if len(jax.devices()) != args.host_devices:
            print(f"# --host-devices {args.host_devices}: backend already "
                  f"initialized with {len(jax.devices())} devices; set "
                  "XLA_FLAGS before launch instead")

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced(**({"n_layers": args.layers}
                             if args.layers else {}))
    elif args.layers:
        import dataclasses
        cfg = dataclasses.replace(cfg, n_layers=args.layers)
    api = get_api(cfg)
    opt = AdamW(lr=args.lr, warmup=min(20, args.steps // 5),
                total_steps=args.steps)
    data = SyntheticLM(vocab=cfg.vocab_size, batch=args.batch,
                       seq=args.seq, seed=args.seed)
    ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    runtime = events = None
    if (args.elastic is not None or args.device_collective
            or args.overlap_sync or args.pipeline_stages > 1
            or args.interleave > 1):
        # --device-collective/--overlap-sync/--pipeline-stages without
        # churn still need the runtime: the engine's programs are keyed
        # by its epochs (a static team is just a single epoch)
        runtime = ElasticPhaserRuntime(args.workers, seed=args.seed,
                                       kind=args.sync_kind)
    if args.elastic is not None:
        try:
            events = parse_elastic(args.elastic)
        except ValueError as e:
            ap.error(str(e))
    loop = TrainLoop(api=api, opt=opt, data=data, ckpt=ckpt,
                     ckpt_every=args.ckpt_every,
                     microbatches=args.microbatches,
                     runtime=runtime,
                     elastic_events=events or {},
                     device_collective=(True if args.device_collective
                                        or args.overlap_sync
                                        or args.pipeline_stages > 1
                                        or args.interleave > 1
                                        else None),
                     overlap_sync=args.overlap_sync,
                     pipeline_stages=args.pipeline_stages,
                     interleave=args.interleave)
    try:
        loop.run(args.steps, resume=args.resume)
    except ValueError as e:
        print(f"# elastic schedule error: {e}")
        return 2
    for m in loop.metrics_log:
        print(json.dumps(m))
    for e in loop.epoch_log:
        print(json.dumps({"epoch_boundary": e}))
    first = loop.metrics_log[0]["loss"]
    last = loop.metrics_log[-1]["loss"]
    print(f"# loss {first:.4f} -> {last:.4f} "
          f"({'DECREASED' if last < first else 'NOT DECREASED'})")
    return 0 if last < first else 1


if __name__ == "__main__":
    raise SystemExit(main())
