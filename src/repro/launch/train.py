"""Training launcher CLI.

  PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
      --steps 200 --batch 8 --seq 256 --reduced --ckpt-dir /tmp/ckpt

``--reduced`` trains the family-reduced config on CPU (the end-to-end
example path); full configs target real accelerators with the same code.

Elastic mode attaches the phaser-epoch control plane
(runtime_elastic.elastic_phaser) and drives membership churn from a
schedule of events, e.g.:

  ... --workers 4 --elastic "join@30,join@35,fail@60,leave@80"

Each event is ``kind@step`` (kind: join | leave | fail; leave/fail may
pin a worker with ``kind:wid@step``). The loop re-lowers its compiled
step at every epoch boundary and prints the epoch log.

``--host-devices 8`` splits the host CPU into a simulated 8-device mesh
(must be the first thing to touch jax, so it is applied before any
device use) and ``--device-collective`` forces gradient sync through the
execution engine's compiled shard_map programs; by default the engine is
used automatically whenever more than one device is visible and the
batch divides the team. ``--overlap-sync`` compiles the pipelined
programs (DESIGN.md §5): reverse-topo bucket groups sync while the
backward pass still runs, and with ``--microbatches N`` each
microbatch's bucket stream overlaps the next microbatch's backward.

``--pipeline-stages S`` (DESIGN.md §6) compiles the 2-D program
instead: the stacked blocks shard over a stage axis
(workers x S devices), microbatches flow through the wave-synchronous
1F1B schedule derived from the point-to-point phaser graph, and each
stage row syncs gradients over the data axis through the epoch's
collective schedule — churn re-derives both at the same boundary.
``--interleave v`` runs the INTERLEAVED 1F1B order: each device owns v
non-contiguous model chunks, cutting the pipeline bubble fraction from
(S-1)/(M+S-1) to (S-1)/(vM+S-1); requires the scan length to divide by
S*v and ``--microbatches`` to divide by S.

``--processes N`` (DESIGN.md §11) runs the MULTI-HOST elastic runtime
instead: N logical host processes, each owning a slice of the visible
devices, the phaser skip list partitioned over them (coordinator owns
HEAD), and gradient sync running hierarchically — local shard_map
reduce inside each process, the process-level phaser schedule between
them. Elastic events then churn whole hosts:

  ... --host-devices 4 --processes 2 --elastic "join@4,fail:1@8"

(a joining host needs spare devices: leave ``host-devices`` headroom
or churn down first). Checkpoints record the surviving process set in
the manifest so ``--resume`` pre-compiles the surviving-host program.
"""
from __future__ import annotations

import argparse
import json
import math
import os

import jax

from ..checkpoint import CheckpointManager
from ..data import SyntheticLM
from ..models.registry import get_api, get_config
from ..optim import AdamW
from ..runtime_elastic import ElasticPhaserRuntime
from ..train.loop import TrainLoop


def parse_elastic(spec: str):
    """'join@30,fail@60,leave:2@80' -> {30: [("join", None)], ...}.

    ``kill`` (``--processes`` mode only) is a hard crash: the host is
    SIGKILLed (socket fabric) or dropped without protocol (in-process),
    and the coordinator must *detect* and recover non-cooperatively —
    unlike ``fail``, which still runs the cooperative eviction."""
    events = {}
    for item in spec.split(","):
        item = item.strip()
        if not item:
            continue
        if "@" not in item:
            raise ValueError(f"elastic event {item!r}: expected kind@step "
                             "(e.g. join@30, leave:2@80)")
        kind, step = item.split("@", 1)
        wid = None
        if ":" in kind:
            kind, w = kind.split(":", 1)
            wid = int(w)
        if kind not in ("join", "leave", "fail", "kill"):
            raise ValueError(f"elastic event kind {kind!r}: expected "
                             "join | leave | fail | kill")
        events.setdefault(int(step), []).append((kind, wid))
    return events


def run_processes(args, ap):
    """--processes N: the multi-host elastic runtime. With the default
    in-process fabric each logical host owns ndev/N device slices of
    this jax runtime; with ``--fabric socket`` each host is a real OS
    process with its own jax runtime (and the coordinator runs the
    heartbeat failure detector). Churn happens at whole-host
    granularity; ``kill`` events crash hosts non-cooperatively."""
    from ..runtime_dist import (DistCoordinator, InprocCluster,
                                SocketCluster, StepInconsistent)
    n = args.processes
    chaos = None
    if args.chaos is not None:
        from ..runtime_dist import ChaosConfig
        chaos = ChaosConfig(seed=args.chaos, p_reset=args.chaos_reset)
    elif args.chaos_reset > 0:
        # reset storms without the RPC drop/dup/delay chaos: exercises
        # the session layer in isolation
        from ..runtime_dist import ChaosConfig
        chaos = ChaosConfig(seed=13, p_drop=0.0, p_dup=0.0, p_delay=0.0,
                            p_reset=args.chaos_reset)
    link_faults = {}
    if args.chaos_links is not None:
        if args.fabric not in ("socket", "tcp"):
            ap.error("--chaos-links needs --fabric socket|tcp")
        from ..runtime_dist import parse_link_spec
        try:
            for f in parse_link_spec(args.chaos_links):
                link_faults.setdefault(f["step"], []).append(f)
        except ValueError as e:
            ap.error(str(e))
    slot_of = {}
    if args.fabric in ("socket", "tcp"):
        m = max(1, args.host_devices or 1)   # devices per host process
        per_dev_batch = max(1, args.batch // (n * m))

        def data_for(pid):
            return {"arch": args.arch, "reduced": args.reduced,
                    "layers": args.layers, "batch": per_dev_batch,
                    "seq": args.seq, "lr": args.lr,
                    "warmup": min(20, args.steps // 5),
                    "steps": args.steps, "devices": m,
                    "ckpt_dir": args.ckpt_dir,
                    "local_kind": "phaser_scsl"}

        cluster = SocketCluster(hb_interval=args.heartbeat_interval,
                                failure_timeout=args.failure_timeout,
                                chaos=chaos,
                                fabric=("tcp" if args.fabric == "tcp"
                                        else "unix"))
    else:
        ndev = len(jax.devices())
        if ndev < n:
            ap.error(f"--processes {n} needs at least {n} devices "
                     f"(have {ndev}; use --host-devices)")
        m = ndev // n
        slots = ndev // m                   # slice headroom for joins
        per_dev_batch = max(1, args.batch // (n * m))

        def data_for(pid):
            if pid not in slot_of:
                used = set(slot_of.values())
                free = [i for i in range(slots) if i not in used]
                if not free:
                    raise ValueError(f"no free device slice for host "
                                     f"{pid} ({slots} slices of {m} "
                                     "devices)")
                slot_of[pid] = free[0]
            return {"arch": args.arch, "reduced": args.reduced,
                    "layers": args.layers, "batch": per_dev_batch,
                    "seq": args.seq, "lr": args.lr,
                    "warmup": min(20, args.steps // 5),
                    "steps": args.steps,
                    "devices": ndev,
                    "device_slice": [slot_of[pid] * m, m],
                    "ckpt_dir": args.ckpt_dir,
                    "local_kind": "phaser_scsl"}

        cluster = InprocCluster(chaos=chaos)

    events = {}
    if args.elastic is not None:
        try:
            events = parse_elastic(args.elastic)
        except ValueError as e:
            ap.error(str(e))
    obs = bool(args.trace or args.metrics_out or args.live_out)
    rt = DistCoordinator(cluster, n, seed=args.seed,
                         proc_kind=args.sync_kind, data_for=data_for,
                         obs=obs, live_out=args.live_out,
                         flight_dir=args.flight_dir)
    start = 0
    if args.resume and args.ckpt_dir:
        mk = rt.cluster.call(min(rt.live),
                             {"op": "manifest_key"})["program_key"]
        if mk is not None:
            # the manifest records the process set live at save time;
            # a naive restart boots the original set — shed the rest
            # so resume pre-compiles the surviving-host program
            for pid in sorted(set(rt.live) - set(mk["process_set"])):
                rt.request_leave(pid, step=0)
                slot_of.pop(pid, None)
            out = rt.resume()
            start = out["step"]
            print(f"# resumed at step {start}; manifest process_set="
                  f"{mk['process_set']} compiled={out['compiled']}")
    metrics = []
    for step in range(start, args.steps):
        for f in link_faults.get(step, []):
            # bounded wall-clock window with local auto-heal timers at
            # every endpoint: the heal fires even while the partition
            # stalls this very loop
            rt.cluster.inject_link_fault(
                f["a"], f["b"], duration=f["dur"], oneway=f["oneway"])
            print(f"# step {step}: link fault "
                  f"{f['a']}{'->' if f['oneway'] else '|'}"
                  f"{f['b'] if f['b'] is not None else '*'} "
                  f"for {f['dur']}s")
        for kind, wid in events.get(step, []):
            if kind == "join":
                rt.request_join(step=step)
            elif kind == "kill":
                # hard crash: no protocol, no goodbye — the coordinator
                # must detect the silence and evict non-cooperatively
                victim = wid if wid is not None else max(rt.live)
                if hasattr(rt.cluster, "kill_pid"):
                    rt.cluster.kill_pid(victim)
                else:
                    rt.cluster.kill_host(victim)
                slot_of.pop(victim, None)
            else:
                victim = wid if wid is not None else max(rt.live)
                rt.request_leave(victim, fail=(kind == "fail"),
                                 step=step)
                slot_of.pop(victim, None)   # slice freed for later joins
        t0 = rt.obs.timeline.now() if obs else 0.0
        try:
            out = rt.train_step(step)
        except StepInconsistent as e:
            # params diverged across survivors: only a checkpoint-
            # consistent resume restores the replicated invariant
            if not args.ckpt_dir:
                raise
            rep = rt.resume()
            print(f"# step {step}: {e}; resumed from checkpoint at "
                  f"step {rep['step']}")
            out = rt.train_step(step)
        rt.advance(step=step)
        if obs:
            rt.obs.timeline.complete("train.step", t0,
                                     args={"step": step,
                                           "hosts": len(rt.live)})
        loss = sum(r["loss"] for r in out.values()) / len(out)
        if step % max(1, args.steps // 10) == 0 or step == args.steps - 1:
            metrics.append({"step": step, "loss": loss,
                            "hosts": len(rt.live),
                            "epoch": rt.epoch.index})
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            rt.save_checkpoint(step + 1)
    if args.ckpt_dir:
        rt.save_checkpoint(args.steps)
    st = rt.control_stats()
    for mrow in metrics:
        print(json.dumps(mrow))
    print(json.dumps({"control_plane": {
        "live": st["live"], "epochs": rt.epoch.index + 1,
        "remote_frames": st["remote_frames"],
        "critical_path": st["critical_path"],
        "events": [[e.step, e.kind, e.pid] for e in rt.events]}}))
    rt.close()                       # final obs collection rides close()
    if obs:
        rt.export_obs(args.trace, args.metrics_out)
        print(json.dumps({"obs": rt.obs.summary()}))
    if not metrics:
        print("# no steps to run (checkpoint already at --steps)")
        return 0
    first, last = metrics[0]["loss"], metrics[-1]["loss"]
    print(f"# loss {first:.4f} -> {last:.4f} "
          f"({'DECREASED' if last < first else 'NOT DECREASED'})")
    # a short resume tail (a couple of steps after the checkpoint) is
    # loss noise on the reduced configs — gate those on finiteness only
    if len(metrics) < 4:
        return 0 if math.isfinite(last) else 1
    return 0 if last < first else 1


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--layers", type=int, default=None,
                    help="override the config's layer count (e.g. to "
                         "make the scan axis divide stages*interleave)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--workers", type=int, default=4,
                    help="initial elastic worker-group size")
    ap.add_argument("--elastic", default=None,
                    help='churn schedule, e.g. "join@30,fail@60"')
    ap.add_argument("--sync-kind", default="phaser_scsl",
                    choices=["phaser_scsl", "recursive_doubling",
                             "halving_doubling", "xla_psum"],
                    help="per-epoch gradient-sync schedule (every kind "
                         "now covers non-power-of-two teams)")
    ap.add_argument("--host-devices", type=int, default=None,
                    help="split the host into N simulated devices "
                         "(XLA_FLAGS; must precede first jax device use)")
    ap.add_argument("--device-collective", action="store_true",
                    help="require gradient sync through the compiled "
                         "shard_map engine (default: auto)")
    ap.add_argument("--overlap-sync", action="store_true",
                    help="pipeline gradient sync against the backward "
                         "pass (reverse-topo bucket groups, "
                         "double-buffered rounds; device path only)")
    ap.add_argument("--pipeline-stages", type=int, default=1,
                    help="pipeline parallelism: shard the stacked "
                         "blocks over a stage axis and run the 1F1B "
                         "wave schedule on a 2-D (stage x data) mesh; "
                         "needs workers*stages devices and "
                         "--microbatches as the pipeline depth "
                         "(device path only)")
    ap.add_argument("--processes", type=int, default=1,
                    help="multi-host elastic runtime: N logical host "
                         "processes, each owning ndev/N devices; the "
                         "skip-list control plane partitions over them "
                         "and gradient sync runs hierarchically (local "
                         "shard_map reduce, then the process-level "
                         "schedule). Elastic events churn whole hosts.")
    ap.add_argument("--fabric", default="inproc",
                    choices=["inproc", "socket", "tcp"],
                    help="--processes transport: in-process logical "
                         "hosts (deterministic), real OS processes "
                         "over AF_UNIX sockets, or real processes over "
                         "TCP loopback (host:port registry files; same "
                         "session layer + failure detection)")
    ap.add_argument("--chaos", type=int, default=None, metavar="SEED",
                    help="inject seeded transport faults (RPC drop/dup "
                         "+ bounded env delay/reorder; DESIGN.md §13)")
    ap.add_argument("--chaos-links", default=None, metavar="SPEC",
                    help="link-level chaos on the socket fabrics: "
                         "'A|B@STEP+DUR' (symmetric partition between "
                         "pid sets, healing after DUR seconds) or "
                         "'A->B@STEP+DUR' (one-way link kill); "
                         "';'-separated, '-1'/'coord' = coordinator, "
                         "'*' = everyone else. A window shorter than "
                         "--failure-timeout must heal with zero "
                         "evictions (DESIGN.md §15)")
    ap.add_argument("--chaos-reset", type=float, default=0.0,
                    metavar="P",
                    help="socket fabrics: per-frame probability of a "
                         "connection reset injected on cmd/env sends "
                         "(the session layer must reconnect + replay; "
                         "usable without --chaos)")
    ap.add_argument("--heartbeat-interval", type=float, default=0.5,
                    help="socket fabric: coordinator heartbeat period "
                         "(seconds)")
    ap.add_argument("--failure-timeout", type=float, default=10.0,
                    help="socket fabric: hard silence floor before a "
                         "host is declared dead")
    ap.add_argument("--trace", default=None,
                    help="write a Chrome-trace/Perfetto JSON of the run "
                         "(wall-clock step/boundary spans + the compiled "
                         "programs' logical schedule grids); with "
                         "--processes the control plane's span log lands "
                         "in a sibling .spans.jsonl")
    ap.add_argument("--metrics-out", default=None,
                    help="write the merged metrics-registry JSON "
                         "(counters/gauges/histograms across shards)")
    ap.add_argument("--live-out", default=None,
                    help="with --processes: append live heartbeat "
                         "frames (phase watermarks, metric deltas, phi "
                         "scores) to this JSONL file at a bounded "
                         "cadence; tail it mid-run with "
                         "`python -m repro.obs.watch`")
    ap.add_argument("--flight-dir", default=None,
                    help="with --processes: directory where per-process "
                         "flight-recorder rings are flushed on crash, "
                         "orphan exit, eviction, and failure recovery "
                         "(*.flight.jsonl)")
    ap.add_argument("--interleave", type=int, default=1,
                    help="virtual stages per device: run the "
                         "interleaved 1F1B schedule (v non-contiguous "
                         "model chunks per device, bubble fraction "
                         "(S-1)/(vM+S-1)); scan length must divide by "
                         "stages*interleave and --microbatches by "
                         "stages")
    args = ap.parse_args(argv)

    if args.host_devices:
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={args.host_devices}"
        ).strip()
        if len(jax.devices()) != args.host_devices:
            print(f"# --host-devices {args.host_devices}: backend already "
                  f"initialized with {len(jax.devices())} devices; set "
                  "XLA_FLAGS before launch instead")

    if args.processes > 1:
        return run_processes(args, ap)
    if args.elastic is not None and "kill" in args.elastic:
        try:
            ev = parse_elastic(args.elastic)
        except ValueError as e:
            ap.error(str(e))
        if any(k == "kill" for evs in ev.values() for k, _ in evs):
            ap.error("kill events need --processes > 1 (hard host "
                     "crashes only exist in the multi-host runtime)")

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced(**({"n_layers": args.layers}
                             if args.layers else {}))
    elif args.layers:
        import dataclasses
        cfg = dataclasses.replace(cfg, n_layers=args.layers)
    api = get_api(cfg)
    opt = AdamW(lr=args.lr, warmup=min(20, args.steps // 5),
                total_steps=args.steps)
    data = SyntheticLM(vocab=cfg.vocab_size, batch=args.batch,
                       seq=args.seq, seed=args.seed)
    ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    runtime = events = None
    if (args.elastic is not None or args.device_collective
            or args.overlap_sync or args.pipeline_stages > 1
            or args.interleave > 1):
        # --device-collective/--overlap-sync/--pipeline-stages without
        # churn still need the runtime: the engine's programs are keyed
        # by its epochs (a static team is just a single epoch)
        runtime = ElasticPhaserRuntime(args.workers, seed=args.seed,
                                       kind=args.sync_kind)
    if args.elastic is not None:
        try:
            events = parse_elastic(args.elastic)
        except ValueError as e:
            ap.error(str(e))
    timeline = metrics_reg = None
    if args.trace or args.metrics_out:
        from ..obs import MetricsRegistry, Timeline
        timeline = Timeline()
        metrics_reg = MetricsRegistry()
    loop = TrainLoop(api=api, opt=opt, data=data, ckpt=ckpt,
                     ckpt_every=args.ckpt_every,
                     microbatches=args.microbatches,
                     timeline=timeline, metrics=metrics_reg,
                     runtime=runtime,
                     elastic_events=events or {},
                     device_collective=(True if args.device_collective
                                        or args.overlap_sync
                                        or args.pipeline_stages > 1
                                        or args.interleave > 1
                                        else None),
                     overlap_sync=args.overlap_sync,
                     pipeline_stages=args.pipeline_stages,
                     interleave=args.interleave)
    try:
        loop.run(args.steps, resume=args.resume)
    except ValueError as e:
        print(f"# elastic schedule error: {e}")
        return 2
    if args.trace:
        timeline.save(args.trace)
    if args.metrics_out:
        from ..obs import MetricsRegistry
        with open(args.metrics_out, "w") as f:
            json.dump({"metrics": MetricsRegistry.merge(
                [metrics_reg.snapshot()])}, f, indent=2)
    for m in loop.metrics_log:
        print(json.dumps(m))
    for e in loop.epoch_log:
        print(json.dumps({"epoch_boundary": e}))
    first = loop.metrics_log[0]["loss"]
    last = loop.metrics_log[-1]["loss"]
    print(f"# loss {first:.4f} -> {last:.4f} "
          f"({'DECREASED' if last < first else 'NOT DECREASED'})")
    return 0 if last < first else 1


if __name__ == "__main__":
    raise SystemExit(main())
