"""Training launcher CLI.

  PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
      --steps 200 --batch 8 --seq 256 --reduced --ckpt-dir /tmp/ckpt

``--reduced`` trains the family-reduced config on CPU (the end-to-end
example path); full configs target real accelerators with the same code.
"""
from __future__ import annotations

import argparse
import json

import jax

from ..checkpoint import CheckpointManager
from ..data import SyntheticLM
from ..models.registry import get_api, get_config
from ..optim import AdamW
from ..train.loop import TrainLoop


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    api = get_api(cfg)
    opt = AdamW(lr=args.lr, warmup=min(20, args.steps // 5),
                total_steps=args.steps)
    data = SyntheticLM(vocab=cfg.vocab_size, batch=args.batch,
                       seq=args.seq, seed=args.seed)
    ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    loop = TrainLoop(api=api, opt=opt, data=data, ckpt=ckpt,
                     ckpt_every=args.ckpt_every,
                     microbatches=args.microbatches)
    loop.run(args.steps, resume=args.resume)
    for m in loop.metrics_log:
        print(json.dumps(m))
    first = loop.metrics_log[0]["loss"]
    last = loop.metrics_log[-1]["loss"]
    print(f"# loss {first:.4f} -> {last:.4f} "
          f"({'DECREASED' if last < first else 'NOT DECREASED'})")
    return 0 if last < first else 1


if __name__ == "__main__":
    raise SystemExit(main())
