"""Serving launcher CLI: batched decode with continuous slot refill.

  PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m \
      --reduced --requests 8 --max-new 16
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from ..models.registry import get_api, get_config
from ..serve.engine import Request, ServeEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--window", type=int, default=128)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=12)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    api = get_api(cfg)
    params = api.init_params(jax.random.key(0))
    eng = ServeEngine(api, params, batch=args.batch, window=args.window)

    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        args.prompt_len).astype(np.int32),
                    max_new=args.max_new)
            for i in range(args.requests)]
    for r in reqs:
        eng.submit(r)
    t0 = time.time()
    steps = 0
    while any(not r.done for r in reqs):
        if eng.step() == 0 and not eng.queue:
            break
        steps += 1
    dt = time.time() - t0
    done = sum(r.done for r in reqs)
    toks = sum(len(r.out) for r in reqs)
    print(f"served {done}/{len(reqs)} requests, {toks} tokens in "
          f"{steps} steps, {dt:.2f}s ({toks/max(dt,1e-9):.1f} tok/s)")
    print(f"phase-gated batch membership: {eng.epoch} schedule swaps "
          f"({len(eng.gate.epochs)} epochs) over "
          f"{eng.gate.ph.released() + 1} phases, "
          f"{len(eng.gate.events)} join/leave events")
    for r in reqs[:3]:
        print(f"  req {r.rid}: {list(r.prompt)} -> {r.out}")
    return 0 if done == len(reqs) else 1


if __name__ == "__main__":
    raise SystemExit(main())
