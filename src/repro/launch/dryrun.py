import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
# The two lines above MUST run before any other import (jax locks the
# device count at first init). Everything below is ordinary.

"""Multi-pod dry-run: lower + compile every (architecture x input-shape)
cell on the production meshes and emit the roofline terms.

  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-72b \
      --shape train_4k --mesh single          # one cell
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both \
      --out results/dryrun                    # the full table

Success criteria (deliverable e): .lower().compile() succeeds, memory
analysis shows the program fits per-chip HBM, and cost/collective analysis
feeds EXPERIMENTS.md §Dry-run / §Roofline.
"""
import argparse
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from ..configs import SHAPES_BY_NAME, ALL_ARCHS, cell_applicable
from ..models.registry import get_api, get_config
from ..optim import AdamW
from ..roofline.analysis import analyze_compiled, model_flops
from ..sharding.policies import make_rules
from .mesh import HW, make_production_mesh


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
               fsdp=None, moe_mode=None, remat: bool = True,
               microbatches: int = 1, seq_shard: bool = False,
               dp_over_model: bool = False, decode_split_k: bool = False,
               moe_nogroup: bool = False):
    """Lower one (arch x shape x mesh) cell; returns (lowered, meta)."""
    import dataclasses
    cfg = get_config(arch)
    if moe_nogroup:
        cfg = dataclasses.replace(cfg, moe_group_size=0)
    shape = SHAPES_BY_NAME[shape_name]
    ok, why = cell_applicable(cfg, shape)
    if not ok:
        return None, {"skipped": why}
    api = get_api(cfg)
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = make_rules(mesh, cfg, fsdp=fsdp, moe_mode=moe_mode,
                       seq_shard=seq_shard, dp_over_model=dp_over_model)

    from ..train.step import (build_decode_step, build_prefill_step,
                              build_train_step)

    if shape.kind == "train":
        opt = AdamW()
        ts = build_train_step(api, opt, rules=rules, remat=remat,
                              microbatches=microbatches, donate=True)
        pspec = api.param_spec()
        ospec = jax.eval_shape(opt.init, pspec)
        bspec = api.input_specs(shape)
        with mesh:
            lowered = ts.jitted.lower(pspec, ospec, bspec)
    elif shape.kind == "prefill":
        fn, _ = build_prefill_step(api, rules=rules)
        pspec = api.param_spec()
        bspec = api.input_specs(shape)
        with mesh:
            lowered = fn.lower(pspec, bspec)
    else:  # decode
        fn, _ = build_decode_step(api, rules=rules,
                                  batch=shape.global_batch,
                                  window=shape.seq_len,
                                  split_k=decode_split_k)
        pspec = api.param_spec()
        stspec = api.decode_state_spec(shape.global_batch, shape.seq_len)
        bspec = api.input_specs(shape)
        with mesh:
            lowered = fn.lower(pspec, stspec, bspec)
    return lowered, {"cfg": cfg, "shape": shape, "mesh": mesh}


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             **kw):
    t0 = time.time()
    res = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16"}
    try:
        lowered, meta = lower_cell(arch, shape_name, multi_pod=multi_pod,
                                   **kw)
        if lowered is None:
            res["status"] = "skipped"
            res["why"] = meta["skipped"]
            return res
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        chips = 512 if multi_pod else 256
        mem = compiled.memory_analysis()
        hlo_text = compiled.as_text()
        # primary: while-aware parse (trip-count-correct; cost_analysis
        # counts scan bodies once — see roofline/hlo_parse.py)
        from ..roofline.hlo_parse import HloModule
        from ..roofline.analysis import Roofline
        cost = HloModule(hlo_text).cost()
        rl = Roofline(
            flops=cost.flops * chips, hbm_bytes=cost.bytes * chips,
            coll_bytes=cost.coll_bytes * chips, chips=chips,
            peak_flops=HW["peak_flops_bf16"], hbm_bw=HW["hbm_bw"],
            ici_bw=HW["ici_bw"],
            coll_detail={k: v * chips for k, v in cost.coll.items()})
        # secondary: raw cost_analysis (loop bodies counted once)
        rl_ca = analyze_compiled(compiled, chips, HW, hlo_text=hlo_text)
        cfg, shape = meta["cfg"], meta["shape"]
        mf = model_flops(cfg, shape)
        res.update({
            "status": "ok",
            "t_lower_s": round(t_lower, 1),
            "t_compile_s": round(t_compile, 1),
            "bytes_per_device": getattr(mem, "temp_size_in_bytes", None),
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "peak_bytes": (getattr(mem, "temp_size_in_bytes", 0) or 0)
            + (getattr(mem, "argument_size_in_bytes", 0) or 0),
            "roofline": rl.to_dict(),
            "cost_analysis_raw": rl_ca.to_dict(),
            "model_flops": mf,
            "model_flops_ratio": mf / rl.flops if rl.flops else None,
            "roofline_fraction": rl.fraction_of_roofline(mf),
        })
    except Exception as e:  # noqa: BLE001 — report, don't crash the sweep
        res["status"] = "error"
        res["error"] = f"{type(e).__name__}: {e}"
        res["trace"] = traceback.format_exc()[-2000:]
    return res


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None, help="write JSONL here")
    ap.add_argument("--fsdp", default=None,
                    choices=[None, "on", "off"], nargs="?")
    ap.add_argument("--moe-mode", default=None, choices=[None, "ep", "tp"],
                    nargs="?")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--seq-shard", action="store_true")
    ap.add_argument("--dp-over-model", action="store_true",
                    help="hillclimb B: fold model axis into pure DP")
    ap.add_argument("--decode-split-k", action="store_true",
                    help="hillclimb C: shard KV window over model axis")
    ap.add_argument("--moe-nogroup", action="store_true",
                    help="hillclimb A baseline: ungrouped MoE dispatch")
    args = ap.parse_args(argv)

    cells = []
    archs = ALL_ARCHS if (args.all or args.arch is None) else [args.arch]
    shapes = (list(SHAPES_BY_NAME) if (args.all or args.shape is None)
              else [args.shape])
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    for mp in meshes:
        for a in archs:
            for s in shapes:
                cells.append((a, s, mp))

    kw = dict(fsdp={"on": True, "off": False}.get(args.fsdp),
              moe_mode=args.moe_mode, remat=not args.no_remat,
              microbatches=args.microbatches, seq_shard=args.seq_shard,
              dp_over_model=args.dp_over_model,
              decode_split_k=args.decode_split_k,
              moe_nogroup=args.moe_nogroup)
    out_f = open(args.out, "a") if args.out else None
    n_ok = n_skip = n_err = 0
    for a, s, mp in cells:
        res = run_cell(a, s, multi_pod=mp, **kw)
        n_ok += res["status"] == "ok"
        n_skip += res["status"] == "skipped"
        n_err += res["status"] == "error"
        line = json.dumps(res)
        print(line if res["status"] != "error"
              else json.dumps({k: v for k, v in res.items()
                               if k != "trace"}), flush=True)
        if out_f:
            out_f.write(line + "\n")
            out_f.flush()
    print(f"# dry-run: {n_ok} ok, {n_skip} skipped, {n_err} errors",
          file=sys.stderr)
    return 1 if n_err else 0


if __name__ == "__main__":
    sys.exit(main())
