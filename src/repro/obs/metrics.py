"""Typed metrics registry with per-process shards.

Every process (host agent, worker subprocess, the coordinator, the
serve engine) owns a ``MetricsRegistry``; the coordinator merges the
shards' ``snapshot()`` dicts into one cluster view at collection time
(``MetricsRegistry.merge``). Three metric types, all jax-free:

* ``Counter``    — monotone; merge = sum across shards;
* ``Gauge``      — last-set level; merge = max across shards (levels
  like decode occupancy compare, they don't add);
* ``Histogram``  — count/total/min/max plus a bounded reservoir of
  recent samples for a median AND a fixed log-spaced bucket ladder
  (shared across every shard, so merge is an elementwise sum of
  bucket counts); p50/p99 derive from the cumulative bucket counts
  (``quantile``) — the latency numbers the serve autoscaler and the
  live-telemetry frames read.

Names are dot-separated, subsystem first: ``serve.prefill.traces``,
``rpc.derive_epoch.seconds``, ``exchange.bytes_sent``,
``program_cache.hits``, ``strikes.straggle`` (DESIGN.md §12).
"""
from __future__ import annotations

from collections import deque
from typing import Deque, Dict, Iterable, List, Optional

_RESERVOIR = 64

# Shared bucket ladder: geometric, 1 µs .. 25 s in 1/2.5/5 decades
# (seconds-denominated metrics land mid-ladder; anything above the top
# bound falls into the implicit +inf bucket). Every shard uses the SAME
# ladder, which is what makes merge a plain elementwise sum.
BUCKET_BOUNDS = tuple(m * (10.0 ** e)
                      for e in range(-6, 2) for m in (1.0, 2.5, 5.0))
_NB = len(BUCKET_BOUNDS) + 1          # + the +inf overflow bucket


class Counter:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n


class Gauge:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


def _bucket_index(v: float) -> int:
    lo, hi = 0, len(BUCKET_BOUNDS)
    while lo < hi:                     # first bound >= v (upper bound)
        mid = (lo + hi) // 2
        if BUCKET_BOUNDS[mid] >= v:
            hi = mid
        else:
            lo = mid + 1
    return lo                          # == len(BOUNDS) -> +inf bucket


def quantile_from_buckets(buckets: List[int], q: float,
                          vmax: Optional[float] = None
                          ) -> Optional[float]:
    """Quantile estimate from cumulative bucket counts: the upper bound
    of the bucket where the cumulative count crosses ``q`` (the +inf
    bucket reports ``vmax`` when known). Works on a live ``Histogram``'s
    buckets and on merged snapshot dicts alike."""
    total = sum(buckets)
    if not total:
        return None
    target = q * total
    cum = 0
    for i, n in enumerate(buckets):
        cum += n
        if cum >= target:
            if i < len(BUCKET_BOUNDS):
                return BUCKET_BOUNDS[i]
            return vmax if vmax is not None else BUCKET_BOUNDS[-1]
    return vmax if vmax is not None else BUCKET_BOUNDS[-1]


class Histogram:
    __slots__ = ("count", "total", "vmin", "vmax", "recent", "buckets")

    def __init__(self):
        self.count = 0
        self.total = 0.0
        self.vmin: Optional[float] = None
        self.vmax: Optional[float] = None
        self.recent: Deque[float] = deque(maxlen=_RESERVOIR)
        self.buckets: List[int] = [0] * _NB

    def observe(self, v: float) -> None:
        v = float(v)
        self.count += 1
        self.total += v
        self.vmin = v if self.vmin is None else min(self.vmin, v)
        self.vmax = v if self.vmax is None else max(self.vmax, v)
        self.recent.append(v)
        self.buckets[_bucket_index(v)] += 1

    def median(self) -> Optional[float]:
        if not self.recent:
            return None
        s = sorted(self.recent)
        return s[len(s) // 2]

    def mean(self) -> Optional[float]:
        return self.total / self.count if self.count else None

    def quantile(self, q: float) -> Optional[float]:
        """Bucket-derived quantile (p50: ``quantile(0.5)``, p99:
        ``quantile(0.99)``); resolution is the ladder's decade thirds."""
        return quantile_from_buckets(self.buckets, q, self.vmax)


class MetricsRegistry:
    """One process's metric shard. ``snapshot()`` is plain dicts of
    primitives — picklable across the socket fabric and JSON-dumpable
    for ``--metrics-out``."""

    def __init__(self):
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._hists: Dict[str, Histogram] = {}

    # ------------------------------------------------------------- access
    def counter(self, name: str) -> Counter:
        c = self._counters.get(name)
        if c is None:
            c = self._counters[name] = Counter()
        return c

    def gauge(self, name: str) -> Gauge:
        g = self._gauges.get(name)
        if g is None:
            g = self._gauges[name] = Gauge()
        return g

    def histogram(self, name: str) -> Histogram:
        h = self._hists.get(name)
        if h is None:
            h = self._hists[name] = Histogram()
        return h

    # convenience one-liners for hot paths
    def inc(self, name: str, n: int = 1) -> None:
        self.counter(name).inc(n)

    def set(self, name: str, v: float) -> None:
        self.gauge(name).set(v)

    def observe(self, name: str, v: float) -> None:
        self.histogram(name).observe(v)

    # ---------------------------------------------------------- snapshots
    def snapshot(self) -> Dict:
        return {
            "counters": {k: c.value for k, c in self._counters.items()},
            "gauges": {k: g.value for k, g in self._gauges.items()},
            "hists": {k: {"count": h.count, "total": h.total,
                          "min": h.vmin, "max": h.vmax,
                          "recent": list(h.recent),
                          "buckets": list(h.buckets)}
                      for k, h in self._hists.items()},
        }

    def reset(self) -> None:
        self._counters.clear()
        self._gauges.clear()
        self._hists.clear()

    @staticmethod
    def merge(snapshots: Iterable[Dict]) -> Dict:
        """Fold per-process snapshots into one cluster-wide view."""
        out = {"counters": {}, "gauges": {}, "hists": {}}
        for snap in snapshots:
            if not snap:
                continue
            for k, v in snap.get("counters", {}).items():
                out["counters"][k] = out["counters"].get(k, 0) + v
            for k, v in snap.get("gauges", {}).items():
                cur = out["gauges"].get(k)
                out["gauges"][k] = v if cur is None else max(cur, v)
            for k, h in snap.get("hists", {}).items():
                cur = out["hists"].get(k)
                if cur is None:
                    out["hists"][k] = {**h, "recent": list(h["recent"]),
                                       "buckets": list(h.get("buckets")
                                                       or [0] * _NB)}
                    continue
                cur["count"] += h["count"]
                cur["total"] += h["total"]
                mins = [m for m in (cur["min"], h["min"]) if m is not None]
                maxs = [m for m in (cur["max"], h["max"]) if m is not None]
                cur["min"] = min(mins) if mins else None
                cur["max"] = max(maxs) if maxs else None
                cur["recent"] = (cur["recent"] + list(h["recent"]))[-_RESERVOIR:]
                # same fixed ladder on every shard: elementwise sum
                hb = h.get("buckets") or [0] * _NB
                cb = cur.get("buckets") or [0] * _NB
                cur["buckets"] = [a + b for a, b in zip(cb, hb)]
        return out

    @staticmethod
    def hist_quantile(merged_hist: Dict, q: float) -> Optional[float]:
        """Quantile from a snapshot/merged hist dict (p50/p99 for
        summary rows and live-telemetry frames)."""
        b = merged_hist.get("buckets")
        if not b:
            return None
        return quantile_from_buckets(b, q, merged_hist.get("max"))

    @staticmethod
    def summary_rows(merged: Dict) -> List[Dict]:
        """Flatten a merged snapshot into table rows (benchmarks/run.py
        prints these as the metrics summary)."""
        rows = []
        for k in sorted(merged.get("counters", {})):
            rows.append({"metric": k, "type": "counter",
                         "value": merged["counters"][k]})
        for k in sorted(merged.get("gauges", {})):
            rows.append({"metric": k, "type": "gauge",
                         "value": round(merged["gauges"][k], 4)})
        for k in sorted(merged.get("hists", {})):
            h = merged["hists"][k]
            if not h["count"]:
                rows.append({"metric": k, "type": "hist", "value": "n=0"})
                continue
            mean = h["total"] / h["count"]
            val = f"n={h['count']} mean={mean:.4g} max={h['max']:.4g}"
            p50 = MetricsRegistry.hist_quantile(h, 0.5)
            p99 = MetricsRegistry.hist_quantile(h, 0.99)
            if p50 is not None:
                val += f" p50={p50:.4g} p99={p99:.4g}"
            rows.append({"metric": k, "type": "hist", "value": val})
        return rows


_DEFAULT = MetricsRegistry()


def default_registry() -> MetricsRegistry:
    """The process-wide default shard (each OS process gets its own by
    construction; in-process logical hosts that need isolation hold
    their own ``MetricsRegistry`` instance instead)."""
    return _DEFAULT
