"""ObsHub: the coordinator-side collection point of the obs plane.

One hub per ``DistCoordinator`` (when built with ``obs=True``): it
accumulates every shard's drained span records into a ``TraceStore``,
keeps the latest per-process metrics snapshots for merging, owns the
coordinator's wall-clock ``Timeline``, and runs the per-signal
O(log P) hop assertion over each drained window — the window between
two collections is exactly one phase advance, so the invariant runs at
every phase and therefore at every epoch boundary, churn included.
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional

from .live import ClusterWatermarks
from .metrics import MetricsRegistry
from .timeline import Timeline
from .trace import TraceStore, check_signal_hops


def spans_path(trace_path: str) -> str:
    """JSONL span-log path derived from the Chrome-trace path."""
    base = trace_path[:-5] if trace_path.endswith(".json") else trace_path
    return base + ".spans.jsonl"


class ObsHub:
    def __init__(self, *, p: float = 0.5, c: float = 3.0):
        self.p = p
        self.c = c
        self.store = TraceStore()
        self.metrics = MetricsRegistry()     # coordinator-local shard
        self.shards: Dict[int, Dict] = {}    # pid -> latest snapshot
        self.timeline = Timeline(pid=-1)
        self.hop_checks = 0
        self.hop_check_log: List[Dict] = []
        self._window: List[Dict] = []        # records since last check
        # merged live phase-watermark view (fed by the coordinator from
        # every shard's tracker snapshot at each quiescent advance)
        self.watermarks = ClusterWatermarks()

    # ---------------------------------------------------------- ingestion
    def ingest(self, pid: int, spans: List[Dict],
               metrics: Optional[Dict] = None) -> None:
        self.store.add(spans)
        self._window.extend(spans)
        if metrics is not None:
            self.shards[pid] = metrics

    def note_lost(self, pid: int) -> None:
        """A host died without draining its span shard: its records are
        gone. The store (and the exported span log, so offline checks
        agree) tolerates the resulting dangling parents / missing
        closes instead of reporting an incomplete causal tree."""
        rec = {"ev": "lost", "pid": pid}
        self.store.mark_lost(pid)
        self._window.append(rec)

    # --------------------------------------------------------- invariants
    def check_window(self, n_live: int, *, phase: Optional[int] = None
                     ) -> Dict:
        """Assert the O(log P) per-signal hop bound over the records
        collected since the previous check; called after every
        quiescent phase advance."""
        res = check_signal_hops(self._window, n_live, p=self.p, c=self.c)
        self._window = []
        self.hop_checks += 1
        self.hop_check_log.append({**res, "phase": phase})
        self.metrics.inc("obs.hop_checks")
        self.metrics.set("obs.signal_depth", res["max_depth"])
        return res

    # ------------------------------------------------------------ merging
    def merged_metrics(self) -> Dict:
        return MetricsRegistry.merge(
            [self.metrics.snapshot(), *self.shards.values()])

    # ------------------------------------------------------------- export
    def span_records(self) -> List[Dict]:
        """The retained window of span records, reconstructed from the
        capped store (retention is bounded — DESIGN.md §12): a
        ``retention`` marker accounting everything evicted, the ``lost``
        markers, then per trace (oldest first) each span followed by its
        close. Offline checks over the exported log therefore agree
        with the in-memory store."""
        out: List[Dict] = []
        st = self.store
        if st.dropped_spans or st.evicted_traces:
            out.append({"ev": "retention",
                        "dropped_spans": st.dropped_spans,
                        "evicted_traces": st.evicted_traces})
        out.extend({"ev": "lost", "pid": pid} for pid in sorted(st.lost))
        for trace, sids in st._by_trace.items():
            for sid in sids:
                rec = st.spans.get(sid)
                if rec is None:
                    continue
                out.append(rec)
                status = st.status.get(sid)
                if status is not None:
                    out.append({"ev": "close", "span": list(sid),
                                "status": status, "pid": rec["pid"]})
        return out

    def export(self, trace_path: Optional[str] = None,
               metrics_path: Optional[str] = None) -> None:
        """Write the Chrome trace (+ sibling span JSONL) and/or the
        merged metrics JSON."""
        if trace_path:
            self.timeline.save(trace_path)
            with open(spans_path(trace_path), "w") as f:
                for r in self.span_records():
                    f.write(json.dumps(r) + "\n")
        if metrics_path:
            with open(metrics_path, "w") as f:
                json.dump({"metrics": self.merged_metrics(),
                           "hop_checks": self.hop_check_log}, f, indent=2)

    def summary(self) -> Dict:
        return {"spans": len(self.store.spans),
                "dropped_spans": self.store.dropped_spans,
                "hop_checks": self.hop_checks,
                "max_signal_depth": max((h["max_depth"]
                                         for h in self.hop_check_log),
                                        default=0),
                "blackholed": len(self.store.blackholed()),
                "watermarks": self.watermarks.summary()}
