"""Terminal dashboard over a ``--live-out`` heartbeat stream.

``python -m repro.obs.watch RUN.live.jsonl`` tails the frame file the
coordinator appends to mid-run (``obs/live.py``) and renders the
cluster's live state: phase watermarks per host with the wait-time
decomposition, detector phi scores, RPC latency quantiles, counter
deltas, and the membership event log. ``--once`` renders the latest
frame and exits (CI smoke); without it the view refreshes in place
until interrupted.

Everything renders from the frames alone — the watcher never talks to
the run, so it can attach to a live file, a finished one, or a copy.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import Dict, List, Optional

from .live import read_frames

_CLEAR = "\x1b[2J\x1b[H"


def _fmt_wm(rank: str, h: Dict, phi: Optional[float],
            retired: bool = False) -> str:
    tag = "dead" if retired else (h.get("mode") or "?")
    line = (f"  {rank:>5}  {tag:<8} sig={h.get('signal', -1):>5} "
            f"wait={h.get('wait', -1):>5} "
            f"wait_s={h.get('wait_s', 0.0):>8.3f} "
            f"sig_s={h.get('signal_s', 0.0):>7.3f} "
            f"comp_s={h.get('compute_s', 0.0):>8.3f}")
    if phi is not None:
        line += f" phi={phi:>6.2f}"
    return line


def render(frames: List[Dict], *, tail_events: int = 8) -> str:
    """One screenful from the frame history (the last frame carries the
    state; earlier ones only contribute the event history)."""
    if not frames:
        return "(no frames yet)"
    f = frames[-1]
    lines = []
    age = time.time() - f.get("ts", 0)
    lines.append(f"live phaser run — step {f.get('step')} "
                 f"phase {f.get('phase')} epoch {f.get('epoch')} "
                 f"gen {f.get('gen')}  "
                 f"[{len(f.get('live', []))} hosts, frame {len(frames)}, "
                 f"{age:.1f}s ago]")
    phi = {int(k): v for k, v in (f.get("phi") or {}).items()}
    wm = f.get("wm") or {}
    if wm:
        lines.append("  host   mode     signal      wait   blocked(s) "
                     " signal(s)  compute(s)")
        for rank in sorted(wm, key=int):
            lines.append(_fmt_wm(rank, wm[rank], phi.get(int(rank))))
    for rank, h in sorted((f.get("retired") or {}).items(),
                          key=lambda kv: int(kv[0])):
        lines.append(_fmt_wm(rank, h, None, retired=True))
    rpc = f.get("rpc") or {}
    if rpc:
        lines.append("  rpc latency: " + "  ".join(
            f"{op} p50={q['p50'] * 1e3:.2f}ms p99={q['p99'] * 1e3:.2f}ms"
            for op, q in sorted(rpc.items())))
    deltas = f.get("deltas") or {}
    if deltas:
        top = sorted(deltas.items(), key=lambda kv: -abs(kv[1]))[:6]
        lines.append("  deltas: " + "  ".join(f"{k}+{v:g}"
                                              for k, v in top))
    events: List = []
    for fr in frames:
        events.extend(fr.get("events") or [])
    if events:
        lines.append("  events: " + "  ".join(
            f"[{e[0]}] {e[1]}:{e[2]}" for e in events[-tail_events:]))
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="terminal dashboard over a --live-out frame stream")
    ap.add_argument("path", help="the run's --live-out JSONL file")
    ap.add_argument("--once", action="store_true",
                    help="render the latest state once and exit")
    ap.add_argument("--interval", type=float, default=0.5,
                    help="refresh period in follow mode (seconds)")
    ap.add_argument("--json", action="store_true",
                    help="with --once: dump the last frame as JSON "
                         "instead of the rendered view")
    args = ap.parse_args(argv)

    if args.once:
        try:
            frames = read_frames(args.path)
        except OSError as e:
            print(f"unreadable: {e}", file=sys.stderr)
            return 2
        if not frames:
            print("no frames", file=sys.stderr)
            return 1
        try:
            if args.json:
                print(json.dumps(frames[-1], indent=2))
            else:
                print(render(frames))
        except BrokenPipeError:
            # piped through head/grep: a closed reader is not an error
            os.dup2(os.open(os.devnull, os.O_WRONLY), sys.stdout.fileno())
        return 0

    frames: List[Dict] = []
    try:
        while True:
            try:
                frames = read_frames(args.path)
            except OSError:
                frames = []
            sys.stdout.write(_CLEAR + render(frames) + "\n")
            sys.stdout.flush()
            time.sleep(args.interval)
    except KeyboardInterrupt:
        return 0


if __name__ == "__main__":
    sys.exit(main())
