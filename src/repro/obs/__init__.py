"""Observability plane: causal traces, metrics shards, step timelines.

Three layers (DESIGN.md §12), all importable without jax so the
control-plane-only worker processes stay light:

* ``trace``    — per-envelope span contexts carried through the
  partitioned control plane; ``TraceStore`` reconstructs causal span
  trees; ``check_signal_hops`` is the runtime O(log P) invariant.
* ``metrics``  — typed counters/gauges/histograms in per-process
  ``MetricsRegistry`` shards, merged at the coordinator.
* ``timeline`` — wall-clock spans + logical schedule grids exported as
  Chrome-trace/Perfetto JSON and JSONL.

The always-on layer (DESIGN.md §14) rides on top:

* ``live``     — per-host phase watermarks + wait-time attribution
  (``WatermarkTracker`` per process, ``ClusterWatermarks`` merged at
  the coordinator) and the ``LiveStreamer`` heartbeat frames behind
  ``--live-out`` (tail with ``python -m repro.obs.watch``).
* ``recorder`` — bounded per-process flight rings flushed to
  ``*.flight.jsonl`` at failure edges; ``python -m repro.obs.recorder``
  checks coherence.
* ``regress``  — the perf-regression sentry over ``BENCH_*.json``
  (``python -m repro.obs.regress``).

``hub.ObsHub`` glues them together on the coordinator;
``python -m repro.obs.check`` asserts the invariants over an exported
span log (CI).
"""
from .hub import ObsHub, spans_path
from .live import (ClusterWatermarks, LiveStreamer, WatermarkRegression,
                   WatermarkTracker, read_frames)
from .metrics import Counter, Gauge, Histogram, MetricsRegistry, \
    default_registry
from .recorder import FlightRecorder, check_flight_file, flight_path
from .timeline import Timeline, activate, current, deactivate, \
    gradsync_round_events, pipeline_wave_events
from .trace import SpanCtx, SpanId, Tracer, TraceStore, check_signal_hops

__all__ = [
    "ClusterWatermarks", "Counter", "FlightRecorder", "Gauge",
    "Histogram", "LiveStreamer", "MetricsRegistry", "ObsHub",
    "SpanCtx", "SpanId", "Timeline", "Tracer", "TraceStore",
    "WatermarkRegression", "WatermarkTracker", "activate",
    "check_flight_file", "check_signal_hops", "current", "deactivate",
    "default_registry", "flight_path", "gradsync_round_events",
    "pipeline_wave_events", "read_frames", "spans_path",
]
