"""Observability plane: causal traces, metrics shards, step timelines.

Three layers (DESIGN.md §12), all importable without jax so the
control-plane-only worker processes stay light:

* ``trace``    — per-envelope span contexts carried through the
  partitioned control plane; ``TraceStore`` reconstructs causal span
  trees; ``check_signal_hops`` is the runtime O(log P) invariant.
* ``metrics``  — typed counters/gauges/histograms in per-process
  ``MetricsRegistry`` shards, merged at the coordinator.
* ``timeline`` — wall-clock spans + logical schedule grids exported as
  Chrome-trace/Perfetto JSON and JSONL.

``hub.ObsHub`` glues the three together on the coordinator;
``python -m repro.obs.check`` asserts the invariants over an exported
span log (CI).
"""
from .hub import ObsHub, spans_path
from .metrics import Counter, Gauge, Histogram, MetricsRegistry, \
    default_registry
from .timeline import Timeline, activate, current, deactivate, \
    gradsync_round_events, pipeline_wave_events
from .trace import SpanCtx, SpanId, Tracer, TraceStore, check_signal_hops

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "default_registry", "ObsHub", "spans_path", "SpanCtx", "SpanId",
    "Timeline", "Tracer", "TraceStore", "activate", "check_signal_hops",
    "current", "deactivate", "gradsync_round_events",
    "pipeline_wave_events",
]
