"""Causal trace layer for the distributed control plane.

Every protocol envelope leaving a traced shard carries a **span
context** — ``(trace_id, span_id, depth)`` — alongside the Lamport
``depth`` the runtime already accounts (``core/runtime.py``). The
context is a plain tuple of primitives, so it survives pickling across
the AF_UNIX socket fabric unchanged.

Span model (DESIGN.md §12):

* a **root span** opens when a facade operation starts a causal chain
  (``signal``, ``join``, ``evict``, ``demote``, ``repromote``, the
  coordinator's ``epoch`` fingerprint round);
* every ``Actor.send`` opens a child span under the sender's *current*
  context — the span of the message being handled (set at delivery) or
  the facade root that initiated the local op;
* delivery closes the span with status ``delivered`` (recorded on the
  receiving shard — the two halves meet when the coordinator merges
  the drained records); a stale notification swallowed by the
  partitioned network's black hole closes it with ``blackholed``, so
  eviction fan-out never leaves dangling spans.

Two hop measures ride each span, deliberately distinct:

* ``hop``   — the envelope's Lamport depth at send: max over *all*
  incoming paths, monotone across phases (matches
  ``Network.max_depth`` / ``BENCH_dist.json``'s ``sig_hops``);
* ``depth`` — the span-tree depth under this trace's root: parent
  chain length, which **resets per trace** — this is what the
  per-signal O(log P) invariant asserts at every epoch boundary
  (``check_signal_hops``), independent of how many phases ran before.

Everything here is jax-free: control-plane-only worker processes (the
latency bench) import it without paying the jax import.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Dict, Iterable, List, Optional, Tuple

from ..core.complexity import signal_bound

SpanId = Tuple[int, int]          # (shard pid, per-shard sequence)
SpanCtx = Tuple[str, SpanId, int]  # (trace id, span id, tree depth)

# facade ops that open root spans (name -> op recorded on the root)
ROOT_OPS = ("signal", "join", "evict", "demote", "repromote", "epoch",
            "failure")

_MAX_RECORDS = 200_000  # backstop for a shard nobody drains


class Tracer:
    """One shard's span recorder. Hooks are called by ``Actor.send`` /
    ``Network.deliver_from`` (via ``Network.tracer``) and by the
    ``ShardPhaser`` facade for root spans; ``drain()`` hands the
    accumulated records to the coordinator's ``TraceStore``."""

    def __init__(self, pid: int):
        self.pid = pid
        self.seq = 0
        self.records: List[Dict] = []
        self.dropped_records = 0
        # actor rank -> the context its next sends parent under
        self._cur: Dict[int, SpanCtx] = {}
        # optional FlightRecorder tee: every record also lands in the
        # process's bounded crash ring (set by ShardPhaser)
        self.flight = None

    # ------------------------------------------------------------ plumbing
    def _new_id(self) -> SpanId:
        self.seq += 1
        return (self.pid, self.seq)

    def _emit(self, rec: Dict) -> None:
        if self.flight is not None:
            # tee BEFORE the backstop: the flight ring keeps the most
            # recent records even when the drain buffer saturated
            self.flight.record(rec)
        if len(self.records) >= _MAX_RECORDS:
            self.dropped_records += 1
            return
        self.records.append(rec)

    # --------------------------------------------------------------- hooks
    def root(self, op: str, key: int) -> str:
        """Open a root span for a facade op on actor ``key``; the
        actor's subsequent sends become its children."""
        sid = self._new_id()
        trace = f"{op}:{key}:{self.pid}:{sid[1]}"
        self._emit({"ev": "span", "trace": trace, "span": sid,
                    "parent": None, "name": op, "src": key, "dst": key,
                    "pid": self.pid, "hop": 0, "depth": 0})
        self._cur[key] = (trace, sid, 0)
        return trace

    def on_send(self, rank: int, msg, hop: int) -> SpanCtx:
        """Child span for an outgoing envelope; returns the context the
        envelope carries."""
        sid = self._new_id()
        cur = self._cur.get(rank)
        if cur is not None:
            trace, parent, pdepth = cur
            depth = pdepth + 1
        else:
            # a send with no traced cause (e.g. state seeded outside any
            # facade op): its own root, flagged by the trace id prefix
            trace, parent, depth = (f"orphan:{msg.kind}:{self.pid}:{sid[1]}",
                                    None, 0)
        self._emit({"ev": "span", "trace": trace, "span": sid,
                    "parent": parent, "name": msg.kind, "src": msg.src,
                    "dst": msg.dst, "pid": self.pid, "hop": hop,
                    "depth": depth})
        return (trace, sid, depth)

    def on_deliver(self, ctx: SpanCtx, dst: int) -> None:
        """Close the envelope's span and make it the destination actor's
        current context (its handler's sends become children)."""
        self._emit({"ev": "close", "span": ctx[1], "status": "delivered",
                    "pid": self.pid})
        self._cur[dst] = ctx

    def on_blackhole(self, ctx: SpanCtx) -> None:
        """A stale notification to a departed key was swallowed: the
        span still closes — status records where the chain died."""
        self._emit({"ev": "close", "span": ctx[1], "status": "blackholed",
                    "pid": self.pid})

    def span_under(self, key: int, name: str, dst: int) -> SpanId:
        """Closed child span under ``key``'s current context — used for
        causal events that are not envelopes (the coordinator's
        per-host fingerprint RPCs under the ``epoch`` root)."""
        sid = self._new_id()
        cur = self._cur.get(key)
        if cur is not None:
            trace, parent, depth = cur[0], cur[1], cur[2] + 1
        else:
            trace, parent, depth = f"orphan:{name}:{self.pid}:{sid[1]}", \
                None, 0
        self._emit({"ev": "span", "trace": trace, "span": sid,
                    "parent": parent, "name": name, "src": key,
                    "dst": dst, "pid": self.pid, "hop": 0, "depth": depth})
        self._emit({"ev": "close", "span": sid, "status": "delivered",
                    "pid": self.pid})
        return sid

    def drain(self) -> List[Dict]:
        out, self.records = self.records, []
        return out


def trace_root_pid(trace: str) -> Optional[int]:
    """The pid that opened a trace's root span, parsed from the trace
    id (``op:key:pid:seq`` — ``orphan:kind:pid:seq`` for rootless
    sends). Lets ``problems`` tolerate a whole trace whose root lived
    on a lost shard, whatever order the ``lost`` marker arrived in."""
    parts = trace.split(":")
    if len(parts) != 4:
        return None
    try:
        return int(parts[2])
    except ValueError:
        return None


class TraceStore:
    """Merged span records from every shard; reconstructs causal span
    trees and answers the completeness / critical-path queries.

    Retention is bounded (``max_spans``): once the cap is crossed, the
    OLDEST whole traces are evicted — whole traces, never individual
    spans, so every retained tree stays complete and ``problems`` keeps
    its meaning over the retained window. ``dropped_spans`` counts the
    evicted spans; ``max_spans=None`` disables eviction (the per-window
    hop check builds throwaway exact stores that way)."""

    def __init__(self, max_spans: Optional[int] = 100_000):
        self.max_spans = max_spans
        self.spans: Dict[SpanId, Dict] = {}
        self.status: Dict[SpanId, str] = {}
        # shards declared dead before their records could be drained
        # (non-cooperative eviction): their spans are tolerated as
        # incomplete instead of failing the causal-tree check
        self.lost: set = set()
        self.dropped_spans = 0
        self.evicted_traces = 0
        # trace id -> span ids, in trace arrival order (eviction order)
        self._by_trace: "OrderedDict[str, List[SpanId]]" = OrderedDict()

    def mark_lost(self, pid: int) -> None:
        self.lost.add(pid)

    def add(self, records: Iterable[Dict]) -> None:
        for r in records:
            ev = r.get("ev")
            if ev == "span":
                sid = tuple(r["span"])
                self.spans[sid] = r
                self._by_trace.setdefault(r["trace"], []).append(sid)
            elif ev == "close":
                self.status[tuple(r["span"])] = r["status"]
            elif ev == "lost":
                self.lost.add(r["pid"])
            elif ev == "retention":
                # a bounded upstream store already evicted: account it
                self.dropped_spans += r.get("dropped_spans", 0)
                self.evicted_traces += r.get("evicted_traces", 0)
            # unknown ev kinds (flight events, future frames): ignored
        self._evict()

    def _evict(self) -> None:
        if self.max_spans is None:
            return
        while len(self.spans) > self.max_spans and len(self._by_trace) > 1:
            trace, sids = self._by_trace.popitem(last=False)
            for sid in sids:
                self.spans.pop(sid, None)
                self.status.pop(sid, None)
                self.dropped_spans += 1
            self.evicted_traces += 1

    # ------------------------------------------------------------ queries
    def traces(self) -> Dict[str, List[Dict]]:
        out: Dict[str, List[Dict]] = {}
        for r in self.spans.values():
            out.setdefault(r["trace"], []).append(r)
        return out

    def trace_ids(self, op: Optional[str] = None) -> List[str]:
        """Trace ids, optionally filtered by root-op prefix
        (``op="signal"`` -> every signal release chain)."""
        ids = set()
        for r in self.spans.values():
            t = r["trace"]
            if op is None or t.split(":", 1)[0] == op:
                ids.add(t)
        return sorted(ids)

    def root_of(self, trace: str) -> Optional[Dict]:
        for r in self.spans.values():
            if r["trace"] == trace and r["parent"] is None:
                return r
        return None

    def children(self, sid: SpanId) -> List[Dict]:
        sid = tuple(sid)
        return [r for r in self.spans.values()
                if r["parent"] is not None and tuple(r["parent"]) == sid]

    def tree(self, trace: str) -> Dict:
        """Nested {span, children} dict rooted at the trace's root."""
        root = self.root_of(trace)
        assert root is not None, f"trace {trace} has no root span"

        def build(rec):
            return {"span": rec,
                    "status": self.status.get(tuple(rec["span"])),
                    "children": [build(c)
                                 for c in self.children(rec["span"])]}
        return build(root)

    def problems(self, trace: str) -> List[str]:
        """Completeness check: every non-root span's parent must exist
        and every non-root span must be closed (delivered or
        blackholed). Empty list == the causal tree is complete. Spans
        whose parent or close record died with a ``lost`` shard (a
        crashed host whose records could never be drained) are
        tolerated — a crash must not fail the survivors' trees."""
        out = []
        recs = [r for r in self.spans.values() if r["trace"] == trace]
        if not any(r["parent"] is None for r in recs) \
                and trace_root_pid(trace) not in self.lost:
            out.append(f"{trace}: no root span")
        for r in recs:
            sid = tuple(r["span"])
            if r["parent"] is not None \
                    and tuple(r["parent"]) not in self.spans \
                    and r["parent"][0] not in self.lost:
                out.append(f"{trace}: span {sid} has unknown parent "
                           f"{tuple(r['parent'])}")
            if r["parent"] is not None and sid not in self.status \
                    and r["pid"] not in self.lost \
                    and r["dst"] not in self.lost:
                out.append(f"{trace}: span {sid} ({r['name']}) never "
                           "closed")
        return out

    def critical_path(self, trace: str) -> int:
        """Longest causal chain under the trace's root, in hops (the
        span-tree depth — per-trace, so per-phase for signal chains)."""
        return max((r["depth"] for r in self.spans.values()
                    if r["trace"] == trace), default=0)

    def max_hop(self, trace: str) -> int:
        """Largest Lamport envelope depth seen in this trace
        (monotone across phases; first-phase signal traces match
        ``Network.max_depth``)."""
        return max((r["hop"] for r in self.spans.values()
                    if r["trace"] == trace), default=0)

    def blackholed(self) -> List[SpanId]:
        return sorted(s for s, st in self.status.items()
                      if st == "blackholed")


def check_signal_hops(records: Iterable[Dict], n_live: int, *,
                      p: float = 0.5, c: float = 3.0) -> Dict:
    """The paper's T2a claim as a runtime invariant: every signal
    release chain in ``records`` must have critical-path depth within
    ``signal_bound(n_live)``. Raises AssertionError on violation;
    returns the measured summary. The coordinator runs this on the
    window of records drained since the previous check — i.e. at every
    phase advance, epoch boundaries included."""
    store = TraceStore(max_spans=None)   # one window: exact, uncapped
    store.add(records)
    bound = signal_bound(max(2, n_live), p=p, c=c)
    worst, worst_trace = 0, None
    traces = store.trace_ids("signal")
    for t in traces:
        d = store.critical_path(t)
        if d > worst:
            worst, worst_trace = d, t
        assert d <= bound, (
            f"signal trace {t}: critical path {d} hops exceeds the "
            f"O(log P) bound {bound} at n={n_live}")
    return {"traces": len(traces), "max_depth": worst,
            "worst_trace": worst_trace, "bound": bound, "n": n_live}
