"""Step timeline profiler: Chrome-trace/Perfetto JSON + JSONL events.

Two event sources share one ``Timeline``:

* **host spans** — wall-clock ``span()``/``complete()`` events around
  real work: train steps, epoch re-lowers, coordinator RPCs, phase
  advances. These carry real microsecond timestamps.
* **logical events** — the *structure* of a compiled program, emitted
  at trace time (inside jit, so exactly once per lowering): pipeline
  waves per stage (``pipeline_wave_events``), gradient-sync rounds
  (``gradsync_round_events``), and the overlapped pipeline's per-tick
  group/round grid. Logical timestamps are tick indices scaled to a
  fixed tick width; they land on their own Chrome-trace pid rows so
  Perfetto shows the schedule grid under the wall-clock spans.

The module-level ``activate``/``current`` hook is how trace-time code
deep inside the executors reaches the live timeline without threading
it through every builder signature; when no timeline is active the
hooks cost one ``None`` check.
"""
from __future__ import annotations

import json
import time
from contextlib import contextmanager
from typing import Dict, List, Optional

# Chrome-trace pid rows for logical (schedule-structure) events
PID_PIPELINE = 1000
PID_GRADSYNC = 1001
TICK_US = 1000.0  # one logical tick rendered as 1ms


class Timeline:
    def __init__(self, pid: int = 0):
        self.pid = pid
        self.events: List[Dict] = []
        self._t0 = time.perf_counter()

    # ------------------------------------------------------------- clock
    def now(self) -> float:
        """Microseconds since this timeline's epoch."""
        return (time.perf_counter() - self._t0) * 1e6

    # ------------------------------------------------------------ events
    @contextmanager
    def span(self, name: str, *, cat: str = "host", tid: int = 0,
             args: Optional[Dict] = None):
        t = self.now()
        try:
            yield
        finally:
            self.complete(name, t, cat=cat, tid=tid, args=args)

    def complete(self, name: str, start_us: float, *, cat: str = "host",
                 tid: int = 0, args: Optional[Dict] = None) -> None:
        """Emit an X event from an earlier ``now()`` mark to now."""
        self.events.append({"name": name, "ph": "X", "cat": cat,
                            "ts": start_us,
                            "dur": max(0.0, self.now() - start_us),
                            "pid": self.pid, "tid": tid,
                            "args": args or {}})

    def instant(self, name: str, *, cat: str = "host", tid: int = 0,
                args: Optional[Dict] = None) -> None:
        self.events.append({"name": name, "ph": "i", "cat": cat,
                            "ts": self.now(), "s": "t", "pid": self.pid,
                            "tid": tid, "args": args or {}})

    def logical(self, name: str, *, ts: float, dur: float, pid: int,
                tid: int, cat: str = "logical",
                args: Optional[Dict] = None) -> None:
        """Schedule-structure event on a logical-time pid row."""
        self.events.append({"name": name, "ph": "X", "cat": cat,
                            "ts": ts, "dur": dur, "pid": pid, "tid": tid,
                            "args": args or {}})

    def extend(self, events: List[Dict]) -> None:
        self.events.extend(events)

    # ------------------------------------------------------------ export
    def chrome(self) -> Dict:
        return {"traceEvents": self.events, "displayTimeUnit": "ms"}

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.chrome(), f)

    def save_jsonl(self, path: str) -> None:
        with open(path, "w") as f:
            for e in self.events:
                f.write(json.dumps(e) + "\n")


# ---------------------------------------------------------------------------
# active-timeline hook (trace-time emitters inside the executors)
# ---------------------------------------------------------------------------
_ACTIVE: Optional[Timeline] = None


def activate(tl: Timeline) -> None:
    global _ACTIVE
    _ACTIVE = tl


def deactivate() -> None:
    global _ACTIVE
    _ACTIVE = None


def current() -> Optional[Timeline]:
    return _ACTIVE


# ---------------------------------------------------------------------------
# logical-event derivations (consumed at program-build time)
# ---------------------------------------------------------------------------
def pipeline_wave_events(sched, *, label: str = "",
                         tick_us: float = TICK_US) -> List[Dict]:
    """Per-stage wave occupancy of a ``PipelineSchedule``: one event per
    (wave, stage) where the stage has an item — tid = stage row, name =
    F/B with (chunk group, microbatch). The gaps are the bubble."""
    out = []
    S = sched.n_stages
    for t, (kind, w) in enumerate(sched.waves):
        for s in range(S):
            it = (sched.fwd_item(w, s) if kind == "F"
                  else sched.bwd_item(w, s))
            if it is None:
                continue
            j, m = it
            out.append({"name": f"{kind} m{m}" + (f" c{j}"
                                                  if sched.interleave > 1
                                                  else ""),
                        "ph": "X", "cat": "pipeline" + label,
                        "ts": t * tick_us, "dur": tick_us,
                        "pid": PID_PIPELINE, "tid": s,
                        "args": {"wave": w, "kind": kind, "stage": s,
                                 "chunk_group": j, "microbatch": m}})
    return out


def gradsync_round_events(sched, *, group: int = 0,
                          offset: int = 0,
                          tick_us: float = TICK_US) -> List[Dict]:
    """One event per schedule round (tid = bucket group row; ``offset``
    skews overlapped groups to their pipeline tick)."""
    out = []
    for r, pairs in enumerate(sched.rounds):
        out.append({"name": f"r{r} {sched.op(r)}", "ph": "X",
                    "cat": "gradsync", "ts": (offset + r) * tick_us,
                    "dur": tick_us, "pid": PID_GRADSYNC, "tid": group,
                    "args": {"round": r, "op": sched.op(r),
                             "pairs": len(pairs), "group": group}})
    return out
