"""Perf-regression sentry over the ``BENCH_*.json`` artifacts.

``python -m repro.obs.regress`` compares freshly produced bench JSON
against the committed ``BENCH_BASELINE.json`` — schema-aware and
direction-aware: a latency that went *up* is bad, a throughput that
went *down* is bad, a boolean claim that flipped false is always bad.
Per-metric tolerance bands absorb machine noise (socket latencies get
wide bands, deterministic structure counts get none).

Each bench file has an **extractor** that flattens it to
``{metric: (value, direction, tol_pct)}``; the baseline stores only the
values (+ the schema version), so tolerances and directions live here
in code and can be tuned without re-seeding. A schema_version change
sidesteps comparison for that bench (metrics are reported as
new/retired, not regressions) — a schema bump is an intentional edit,
not a perf event.

Exit codes: 0 clean (or ``--warn-only``), 1 regression detected,
2 baseline/fresh artifacts unreadable. ``--seed`` (re)writes the
baseline from the fresh artifacts.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys
from typing import Dict, List, Optional, Tuple

# direction: "lower" = lower is better, "higher" = higher is better,
# "bool" = must stay truthy once true
Metric = Tuple[float, str, float]   # (value, direction, tol_pct)

# tolerance bands (pct): wall-clock micro timings are noisy on shared
# CI machines; structural counts are deterministic per seed
_T_LATENCY = 15.0      # model-step timings (the acceptance case: +20%)
_T_SOCKET = 60.0       # socket RPC / detection wall clocks
_T_RATE = 50.0         # chaos throughput (scheduler-sensitive)
_T_COUNT = 10.0        # protocol frame/hop counts (deterministic-ish)


def _x_collective(d: Dict) -> Dict[str, Metric]:
    out = {}
    for k, v in d.get("ms_per_step", {}).items():
        out[f"ms_per_step.{k}"] = (v, "lower", _T_LATENCY)
    if "eager_over_overlapped" in d:
        out["eager_over_overlapped"] = (d["eager_over_overlapped"],
                                        "higher", _T_LATENCY)
    if "overlapped_bitwise_equals_eager" in d:
        out["overlapped_bitwise_equals_eager"] = (
            1.0 if d["overlapped_bitwise_equals_eager"] else 0.0,
            "bool", 0.0)
    return out


def _x_pipeline(d: Dict) -> Dict[str, Metric]:
    out = {}
    for k, v in d.get("ms_per_step", {}).items():
        out[f"ms_per_step.{k}"] = (v, "lower", _T_LATENCY)
    for k, v in d.get("bubble_fraction", {}).items():
        out[f"bubble_fraction.{k}"] = (v, "lower", 5.0)
    if "loss_matches_single_axis" in d:
        out["loss_matches_single_axis"] = (
            1.0 if d["loss_matches_single_axis"] else 0.0, "bool", 0.0)
    return out


def _x_obs(d: Dict) -> Dict[str, Metric]:
    out = {}
    for row in d.get("rows", []):
        c = row["case"]
        out[f"{c}.untraced_med_ms"] = (row["untraced_med_ms"], "lower",
                                       _T_SOCKET)
        out[f"{c}.traced_med_ms"] = (row["traced_med_ms"], "lower",
                                     _T_SOCKET)
        if "streamed_med_ms" in row:
            out[f"{c}.streamed_med_ms"] = (row["streamed_med_ms"],
                                           "lower", _T_SOCKET)
    if "within_gate" in d:
        out["within_gate"] = (1.0 if d["within_gate"] else 0.0,
                              "bool", 0.0)
    return out


def _x_dist(d: Dict) -> Dict[str, Metric]:
    out = {}
    for row in d.get("rows", []):
        # schema v3 rows carry the socket fabric; v2 rows were AF_UNIX
        pre = f"{row.get('transport', 'unix')}.n{row['n']}"
        for k in ("advance_ms", "join_ms", "evict_ms"):
            out[f"{pre}.{k}"] = (row[k], "lower", _T_SOCKET)
        for k in ("sig_hops", "trace_sig_depth", "frames_per_advance"):
            out[f"{pre}.{k}"] = (row[k], "lower", _T_COUNT)
    for k in ("sublinear_hop_growth", "signal_hops_within_bound"):
        if k in d:
            out[k] = (1.0 if d[k] else 0.0, "bool", 0.0)
    if "log_fit_r2" in d:
        out["log_fit_r2"] = (d["log_fit_r2"], "higher", 10.0)
    return out


def _x_chaos(d: Dict) -> Dict[str, Metric]:
    out = {}
    for row in d.get("detection", []):
        key = f"hb{row['hb_interval_s']:g}"
        out[f"{key}.detect_s"] = (row["detect_s"], "lower", _T_SOCKET)
        out[f"{key}.evict_and_advance_s"] = (row["evict_and_advance_s"],
                                             "lower", _T_SOCKET)
    for row in d.get("degradation", []):
        key = f"drop{row['p_drop']:g}"
        out[f"{key}.phases_per_s"] = (row["phases_per_s"], "higher",
                                      _T_RATE)
    return out


def _x_tcp(d: Dict) -> Dict[str, Metric]:
    out = {}
    for row in d.get("reset_replay", []):
        key = f"storm{row['storm']}"
        out[f"{key}.storm_advance_ms"] = (row["storm_advance_ms"],
                                          "lower", _T_SOCKET)
    s = d.get("session", {})
    if "balance_ok" in s:
        out["session.balance_ok"] = (1.0 if s["balance_ok"] else 0.0,
                                     "bool", 0.0)
    heal = d.get("partition_heal", {})
    if heal:
        out["heal.heal_to_advance_ms"] = (heal["heal_to_advance_ms"],
                                          "lower", _T_SOCKET)
        out["heal.zero_evictions"] = (
            1.0 if heal.get("evictions", 1) == 0 else 0.0, "bool", 0.0)
    return out


EXTRACTORS = {
    "BENCH_collective.json": _x_collective,
    "BENCH_pipeline.json": _x_pipeline,
    "BENCH_obs.json": _x_obs,
    "BENCH_dist.json": _x_dist,
    "BENCH_chaos.json": _x_chaos,
    "BENCH_tcp.json": _x_tcp,
}

BASELINE_NAME = "BENCH_BASELINE.json"


def extract(name: str, d: Dict) -> Dict[str, Metric]:
    fn = EXTRACTORS.get(name)
    return fn(d) if fn is not None else {}


def _load(path: str) -> Dict:
    with open(path) as f:
        return json.load(f)


def seed_baseline(fresh_dir: str, out_path: str) -> Dict:
    """(Re)write the baseline from the bench artifacts in fresh_dir."""
    benches = {}
    for name in sorted(EXTRACTORS):
        path = os.path.join(fresh_dir, name)
        if not os.path.exists(path):
            continue
        d = _load(path)
        benches[name] = {
            "schema_version": d.get("schema_version"),
            "metrics": {k: v for k, (v, _, _) in
                        sorted(extract(name, d).items())}}
    base = {"v": 1, "benches": benches}
    with open(out_path, "w") as f:
        json.dump(base, f, indent=2, sort_keys=True)
        f.write("\n")
    return base


def compare(baseline: Dict, fresh_dir: str) -> Dict:
    """Fresh artifacts vs the baseline. Returns a report with
    ``regressions`` (tolerance band exceeded in the bad direction),
    ``improvements``, and ``warnings`` (new / retired / missing /
    schema-changed — never failures)."""
    regressions: List[Dict] = []
    improvements: List[Dict] = []
    warnings: List[str] = []
    compared = 0
    for name, entry in sorted(baseline.get("benches", {}).items()):
        path = os.path.join(fresh_dir, name)
        if not os.path.exists(path):
            warnings.append(f"{name}: fresh artifact missing")
            continue
        d = _load(path)
        if d.get("schema_version") != entry.get("schema_version"):
            warnings.append(
                f"{name}: schema_version "
                f"{entry.get('schema_version')} -> "
                f"{d.get('schema_version')} (comparison skipped; "
                "re-seed the baseline)")
            continue
        fresh = extract(name, d)
        base = entry.get("metrics", {})
        for m in sorted(set(base) - set(fresh)):
            warnings.append(f"{name}:{m}: retired (in baseline only)")
        for m in sorted(set(fresh) - set(base)):
            warnings.append(f"{name}:{m}: new (not in baseline)")
        for m in sorted(set(base) & set(fresh)):
            bval = base[m]
            fval, direction, tol = fresh[m]
            compared += 1
            rec = {"bench": name, "metric": m, "baseline": bval,
                   "fresh": fval, "direction": direction,
                   "tol_pct": tol}
            if direction == "bool":
                if bval and not fval:
                    regressions.append({**rec, "why": "flipped false"})
                continue
            if bval == 0:
                continue        # no band to scale from
            delta_pct = 100.0 * (fval - bval) / abs(bval)
            rec["delta_pct"] = round(delta_pct, 2)
            bad = delta_pct > tol if direction == "lower" \
                else delta_pct < -tol
            good = delta_pct < -tol if direction == "lower" \
                else delta_pct > tol
            if bad:
                regressions.append(
                    {**rec,
                     "why": f"{delta_pct:+.1f}% beyond the "
                            f"{tol:g}% band ({direction} is better)"})
            elif good:
                improvements.append(rec)
    return {"compared": compared, "regressions": regressions,
            "improvements": improvements, "warnings": warnings,
            "ok": not regressions}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="perf-regression sentry over BENCH_*.json")
    ap.add_argument("--fresh", default=".",
                    help="directory holding the fresh BENCH_*.json")
    ap.add_argument("--baseline", default=None,
                    help=f"baseline path (default <fresh>/{BASELINE_NAME})")
    ap.add_argument("--seed", action="store_true",
                    help="(re)write the baseline from the fresh "
                         "artifacts instead of comparing")
    ap.add_argument("--warn-only", action="store_true",
                    help="report regressions but exit 0 (CI smoke on "
                         "shared machines)")
    ap.add_argument("--json", default=None,
                    help="also write the full report to this path")
    args = ap.parse_args(argv)

    base_path = args.baseline or os.path.join(args.fresh, BASELINE_NAME)
    if args.seed:
        try:
            base = seed_baseline(args.fresh, base_path)
        except (OSError, ValueError) as e:
            print(f"seed failed: {e}", file=sys.stderr)
            return 2
        print(f"seeded {base_path} from "
              f"{len(base['benches'])} bench artifacts")
        return 0

    try:
        baseline = _load(base_path)
    except (OSError, ValueError) as e:
        print(f"baseline unreadable: {e}", file=sys.stderr)
        return 2
    try:
        report = compare(baseline, args.fresh)
    except (OSError, ValueError) as e:
        print(f"fresh artifacts unreadable: {e}", file=sys.stderr)
        return 2

    if args.json:
        with open(args.json, "w") as f:
            json.dump(report, f, indent=2)
    for w in report["warnings"]:
        print(f"warn: {w}")
    for r in report["improvements"]:
        print(f"good: {r['bench']}:{r['metric']} "
              f"{r['baseline']:g} -> {r['fresh']:g} "
              f"({r['delta_pct']:+.1f}%)")
    for r in report["regressions"]:
        print(f"REGRESSION: {r['bench']}:{r['metric']} "
              f"{r['baseline']:g} -> {r['fresh']:g} — {r['why']}")
    print(f"{report['compared']} metrics compared, "
          f"{len(report['regressions'])} regressions, "
          f"{len(report['improvements'])} improvements, "
          f"{len(report['warnings'])} warnings")
    if report["regressions"] and not args.warn_only:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
