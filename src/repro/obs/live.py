"""Live phase watermarks, wait attribution, and streaming telemetry.

The paper's claim is about *who waits on whom and for how long*; the
natural live observable (the Formalization-of-Phase-Ordering framing)
is the **phase watermark**: per participant, the last phase it signaled
and the last phase released to it, with the time between the two being
exactly the interval the participant would have blocked in WAIT. Three
pieces, all jax-free and always-on:

* ``WatermarkTracker`` — per-process. The protocol actors call into it
  through getattr-guarded facade hooks (``core/phaser.py``): a counter
  bump per signal / release advance, plus a bounded map of outstanding
  signal timestamps so the signal→release gap accumulates into the
  per-host wait-time decomposition (``wait_s`` blocked-on-WAIT vs
  ``signal_s`` local signaling work vs ``compute_s`` step time).

* ``ClusterWatermarks`` — coordinator-side merge, updated at every
  quiescent advance. Asserts per-host monotonicity across churn and
  generation bumps (a rebuild fast-forwards phases, it never rewinds
  them); a dead host's watermark is frozen at its last observed value,
  then retired out of the live view.

* ``LiveStreamer`` — appends compact JSONL heartbeat frames (watermark
  view, merged counter deltas, detector phi scores, RPC latency
  quantiles, membership events) to ``--live-out`` at a bounded cadence;
  ``python -m repro.obs.watch`` tails the file and renders the
  dashboard mid-run.

Frame schema (DESIGN.md §14): one JSON object per line,
``{"v": 1, "ts", "step", "phase", "epoch", "gen", "live": [pids],
"wm": {pid: {"signal", "wait", "mode", "wait_s", "signal_s",
"compute_s"}}, "retired": {pid: wm}, "deltas": {counter: +n},
"phi": {pid: score}, "rpc": {op: {"p50", "p99"}}, "events":
[[step, kind, pid], ...]}``.
"""
from __future__ import annotations

import json
import time
from typing import Dict, List, Optional

from .metrics import MetricsRegistry

FRAME_VERSION = 1

# outstanding signal timestamps kept per rank (signals can run ahead of
# releases; the runtime's advance loop keeps this at ~1)
_MAX_OUTSTANDING = 256
# per-phase wait decomposition retained per rank (latest K phases)
_MAX_PHASE_WAITS = 32


class WatermarkTracker:
    """Per-process phase watermarks + wait-time decomposition for the
    locally-owned participants. Hot-path cost is a dict write; wall
    clocks are ``perf_counter`` reads only on signal/release edges
    (once per phase per rank), never per envelope."""

    def __init__(self, pid: int):
        self.pid = pid
        self.gen = 0
        self._hosts: Dict[int, Dict] = {}
        self.dropped_outstanding = 0

    def _host(self, rank: int) -> Dict:
        h = self._hosts.get(rank)
        if h is None:
            h = self._hosts[rank] = {
                "signal": -1, "wait": -1, "mode": "SIG_WAIT",
                "wait_s": 0.0, "signal_s": 0.0, "compute_s": 0.0,
                "sig_t": {},          # outstanding phase -> t_signal
                "phase_waits": {},    # phase -> wait_s (last K)
            }
        return h

    def set_mode(self, rank: int, mode: str) -> None:
        self._host(rank)["mode"] = mode

    # ------------------------------------------------------------- hooks
    def on_signal(self, rank: int, phase: int) -> None:
        h = self._host(rank)
        if phase > h["signal"]:
            h["signal"] = phase
        sig_t = h["sig_t"]
        if len(sig_t) >= _MAX_OUTSTANDING and phase not in sig_t:
            self.dropped_outstanding += 1
            sig_t.pop(next(iter(sig_t)))
        sig_t[phase] = time.perf_counter()

    def on_wait_advance(self, rank: int, phase: int) -> None:
        h = self._host(rank)
        if phase <= h["wait"]:
            return
        h["wait"] = phase
        sig_t = h["sig_t"]
        if sig_t:
            now = time.perf_counter()
            for p in [p for p in sig_t if p <= phase]:
                dt = now - sig_t.pop(p)
                h["wait_s"] += dt
                pw = h["phase_waits"]
                pw[p] = round(dt, 6)
                while len(pw) > _MAX_PHASE_WAITS:
                    pw.pop(next(iter(pw)))

    def add_signal_time(self, rank: int, dt: float) -> None:
        self._host(rank)["signal_s"] += dt

    def add_compute_time(self, rank: int, dt: float) -> None:
        self._host(rank)["compute_s"] += dt

    # ---------------------------------------------------------- snapshot
    def snapshot(self) -> Dict:
        """Plain-dict view (picklable / JSON-able) the agent ships in
        its ``obs`` reply; merged by ``ClusterWatermarks``."""
        return {"pid": self.pid, "gen": self.gen,
                "dropped_outstanding": self.dropped_outstanding,
                "hosts": {r: {"signal": h["signal"], "wait": h["wait"],
                              "mode": h["mode"],
                              "wait_s": round(h["wait_s"], 6),
                              "signal_s": round(h["signal_s"], 6),
                              "compute_s": round(h["compute_s"], 6),
                              "outstanding": len(h["sig_t"]),
                              "phase_waits": dict(h["phase_waits"])}
                         for r, h in self._hosts.items()}}


class WatermarkRegression(AssertionError):
    """A merged watermark moved backwards — phases are monotone by
    construction (rebuild fast-forwards, never rewinds), so regression
    means shard state corruption or a stale-generation leak."""


class ClusterWatermarks:
    """Coordinator-side merged watermark view over every shard's
    tracker snapshots; the single logical view (the PGAS global-view
    presentation) over per-process state."""

    def __init__(self):
        self.view: Dict[int, Dict] = {}      # rank -> merged watermark
        self.retired: Dict[int, Dict] = {}   # rank -> frozen final wm
        self.updates = 0
        self._gen: Dict[int, int] = {}       # rank -> last source gen
        self._strike_base: Dict[int, float] = {}   # rank -> wait_s mark

    def update(self, pid: int, snap: Optional[Dict],
               gen: Optional[int] = None) -> None:
        """Fold one shard's snapshot in; asserts monotonicity per rank
        across churn and generation bumps."""
        if not snap:
            return
        self.updates += 1
        for rank, h in snap.get("hosts", {}).items():
            rank = int(rank)
            if rank in self.retired:
                continue              # frozen: a corpse reports nothing
            cur = self.view.get(rank)
            if cur is not None:
                if h["signal"] < cur["signal"] or h["wait"] < cur["wait"]:
                    raise WatermarkRegression(
                        f"rank {rank}: watermark regressed "
                        f"(signal {cur['signal']}->{h['signal']}, "
                        f"wait {cur['wait']}->{h['wait']}, "
                        f"gen {self._gen.get(rank)}->{gen})")
            self.view[rank] = {k: h[k] for k in
                               ("signal", "wait", "mode", "wait_s",
                                "signal_s", "compute_s")}
            if gen is not None:
                self._gen[rank] = gen

    def retire(self, rank: int) -> Optional[Dict]:
        """A host left (cooperatively or not): freeze its last observed
        watermark and remove it from the live view. Survivor updates
        keep asserting monotone against their own history — retirement
        never resets anyone else's floor."""
        wm = self.view.pop(rank, None)
        if wm is not None:
            self.retired[rank] = wm
        self._gen.pop(rank, None)
        self._strike_base.pop(rank, None)
        return wm

    def wait_seconds(self) -> Dict[int, float]:
        return {r: h["wait_s"] for r, h in self.view.items()}

    def take_wait_deltas(self) -> Dict[int, float]:
        """Per-rank blocked-on-WAIT seconds accumulated since the last
        call — the straggler policy's attribution input (a host slow
        because it *waited* is a victim, not a culprit)."""
        out = {}
        for r, h in self.view.items():
            base = self._strike_base.get(r, 0.0)
            out[r] = max(0.0, h["wait_s"] - base)
            self._strike_base[r] = h["wait_s"]
        return out

    def summary(self) -> Dict:
        return {"live": {r: dict(h) for r, h in sorted(self.view.items())},
                "retired": {r: dict(h)
                            for r, h in sorted(self.retired.items())},
                "updates": self.updates}


class LiveStreamer:
    """Appends heartbeat frames to ``--live-out`` at a bounded cadence.

    Cost model (the <3% traced-step gate covers this): per advance one
    ``monotonic`` read; a frame is serialized only when ``min_interval``
    elapsed (or the caller forces one — failure events must not be
    rate-limited away). Counter deltas are computed against the
    previously framed snapshot so the stream stays compact."""

    def __init__(self, path: str, *, min_interval: float = 0.25):
        self.path = path
        self.min_interval = min_interval
        self.frames = 0
        self.suppressed = 0
        self._f = None
        self._last_t = 0.0
        self._last_counters: Dict[str, float] = {}
        self._last_events = 0

    # ------------------------------------------------------------ frames
    def frame(self, *, step: int, phase: int, epoch: int, gen: int,
              live: List[int], watermarks: Optional[Dict] = None,
              merged_metrics: Optional[Dict] = None,
              phi: Optional[Dict] = None,
              events: Optional[List] = None,
              rpc_quantiles: bool = True,
              force: bool = False) -> bool:
        """Emit one frame if the cadence allows (always on ``force``).
        Returns True iff a frame was written."""
        now = time.monotonic()
        if not force and now - self._last_t < self.min_interval:
            self.suppressed += 1
            return False
        self._last_t = now
        rec = {"v": FRAME_VERSION, "ts": round(time.time(), 3),
               "step": step, "phase": phase, "epoch": epoch,
               "gen": gen, "live": list(live)}
        if watermarks is not None:
            rec["wm"] = {str(r): h for r, h in
                         sorted(watermarks.view.items())}
            if watermarks.retired:
                rec["retired"] = {str(r): h for r, h in
                                  sorted(watermarks.retired.items())}
        if merged_metrics is not None:
            counters = merged_metrics.get("counters", {})
            deltas = {}
            for k, v in counters.items():
                d = v - self._last_counters.get(k, 0)
                if d:
                    deltas[k] = d
            self._last_counters = dict(counters)
            if deltas:
                rec["deltas"] = deltas
            if rpc_quantiles:
                rpc = {}
                for k, h in merged_metrics.get("hists", {}).items():
                    if not k.startswith("rpc.") or not h.get("count"):
                        continue
                    op = k.split(".")[1]
                    p50 = MetricsRegistry.hist_quantile(h, 0.5)
                    p99 = MetricsRegistry.hist_quantile(h, 0.99)
                    if p50 is not None:
                        rpc[op] = {"p50": round(p50, 6),
                                   "p99": round(p99, 6)}
                if rpc:
                    rec["rpc"] = rpc
        if phi:
            rec["phi"] = {str(p): round(v, 3) for p, v in phi.items()}
        if events is not None:
            new = events[self._last_events:]
            self._last_events = len(events)
            if new:
                rec["events"] = new
        self._write(rec)
        return True

    def _write(self, rec: Dict) -> None:
        if self._f is None:
            self._f = open(self.path, "a")
        self._f.write(json.dumps(rec, separators=(",", ":")) + "\n")
        self._f.flush()               # tailers read mid-run
        self.frames += 1

    def close(self) -> None:
        if self._f is not None:
            self._f.close()
            self._f = None


def read_frames(path: str, *, offset: int = 0) -> List[Dict]:
    """Parse frames from a live-out file, tolerating a torn final line
    (the writer may be mid-append)."""
    out = []
    with open(path) as f:
        if offset:
            f.seek(offset)
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                out.append(json.loads(line))
            except ValueError:
                break                 # torn tail: next poll rereads it
    return out
