"""CLI invariant checker over an exported span log (CI's dist-smoke).

  python -m repro.obs.check /tmp/dist-trace.spans.jsonl \\
      --hosts 2 --require-ops signal,evict

Loads the JSONL span records a traced run exported (``--trace``),
reconstructs the causal span trees, and asserts:

* completeness — every non-root span has a known parent and closed
  (delivered or blackholed);
* the O(log P) hop invariant — every signal release chain's critical
  path is within ``signal_bound(hosts)``;
* (optional) presence — at least one complete trace per required op.

``lost`` markers (a crashed shard's records are gone) and ``retention``
markers (a bounded store evicted old traces before export) may appear
anywhere in the file, interleaved with spans.

Exit codes: 0 all invariants hold; 1 an invariant is violated;
2 the log itself is unreadable (missing file / non-JSON lines) —
distinct so CI can tell a broken export from a broken protocol.
"""
from __future__ import annotations

import argparse
import json
import sys

from ..core.complexity import signal_bound
from .trace import TraceStore


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("spans", help="span JSONL exported by a traced run")
    ap.add_argument("--hosts", type=int, required=True,
                    help="max live host count of the run (sets the "
                         "O(log P) bound)")
    ap.add_argument("--p", type=float, default=0.5)
    ap.add_argument("--require-ops", default=None,
                    help="comma list of root ops that must each have "
                         "at least one complete trace (e.g. "
                         "signal,join,evict)")
    ap.add_argument("--summary", action="store_true",
                    help="print a one-line human summary instead of "
                         "the full JSON report")
    args = ap.parse_args(argv)

    # the exported log already reflects any upstream retention cap:
    # check exactly what is in the file, evict nothing further
    store = TraceStore(max_spans=None)
    try:
        with open(args.spans) as f:
            records = [json.loads(line) for line in f if line.strip()]
    except (OSError, ValueError) as e:
        print(f"span log unreadable: {e}", file=sys.stderr)
        return 2
    store.add(records)

    failures = []
    per_op = {}
    for trace in store.trace_ids():
        op = trace.split(":", 1)[0]
        probs = store.problems(trace)
        if probs:
            failures.extend(probs)
        else:
            per_op[op] = per_op.get(op, 0) + 1

    bound = signal_bound(max(2, args.hosts), p=args.p)
    worst = 0
    for trace in store.trace_ids("signal"):
        d = store.critical_path(trace)
        worst = max(worst, d)
        if d > bound:
            failures.append(f"{trace}: critical path {d} > O(log P) "
                            f"bound {bound} at hosts={args.hosts}")

    if args.require_ops:
        for op in args.require_ops.split(","):
            op = op.strip()
            if op and not per_op.get(op):
                failures.append(f"no complete {op!r} trace in the log")

    if args.summary:
        ops = " ".join(f"{op}={n}" for op, n in sorted(per_op.items()))
        verdict = "OK" if not failures else f"FAIL({len(failures)})"
        print(f"{verdict} spans={len(store.spans)} "
              f"dropped={store.dropped_spans} lost={sorted(store.lost)} "
              f"sig_depth={worst}/{bound} {ops}")
        for msg in failures[:5]:
            print(f"  {msg}")
    else:
        print(json.dumps({
            "spans": len(store.spans),
            "dropped_spans": store.dropped_spans,
            "lost_pids": sorted(store.lost),
            "traces": len(store.trace_ids()),
            "complete_traces_per_op": per_op,
            "blackholed_spans": len(store.blackholed()),
            "signal_bound": bound,
            "max_signal_depth": worst,
            "failures": failures[:20],
            "ok": not failures,
        }, indent=2))
    return 0 if not failures else 1


if __name__ == "__main__":
    sys.exit(main())
