"""Flight recorder: a bounded per-process ring of recent spans/events.

The obs plane's export path only runs when a run ends cleanly — the
moments worth debugging (SIGKILL, orphan exit, eviction) are exactly
when it never runs. Every ``ShardPhaser`` therefore owns a
``FlightRecorder`` unconditionally (always-on: the ring is a deque
append, no I/O, no timestamps on the hot tee path), and the runtime
flushes it to ``*.flight.jsonl`` at the failure edges:

* worker **crash**      — the serve loop's exception path;
* worker **orphan exit** — coordinator silent past the heartbeat
  horizon (exit code 2);
* cooperative **eviction** — the coordinator asks the departing host
  to flush before shutdown;
* **SIGKILL-survivor recovery** — after a non-cooperative eviction the
  coordinator flushes its own ring and every survivor's, so the
  last-N-records window around the death is on disk even though the
  corpse itself wrote nothing.

Ring contents: the ``Tracer``'s span/close records (teed by reference —
no copy), plus sparse timestamped lifecycle events (release, rebuild,
membership, step) that bracket the spans in wall-clock time.

``python -m repro.obs.recorder DIR`` checks a directory of flight
files for coherence (CI's chaos-smoke asserts a non-empty post-kill
record): exit 0 coherent, 1 incoherent/empty, 2 unreadable.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys
import time
from collections import deque
from typing import Dict, List, Optional

_DEFAULT_CAP = 4096

# record kinds a coherent flight file may contain
_KNOWN_EV = {"flight", "span", "close", "lost", "event"}


class FlightRecorder:
    """Bounded ring of recent obs records for one process."""

    def __init__(self, pid: int, *, cap: int = _DEFAULT_CAP):
        self.pid = pid
        self.cap = cap
        self.dropped = 0
        self.flushes = 0
        self._ring: deque = deque(maxlen=cap)

    def __len__(self) -> int:
        return len(self._ring)

    def record(self, rec: Dict) -> None:
        """Tee one record (by reference — the hot path of the tracer
        must not copy)."""
        if len(self._ring) == self.cap:
            self.dropped += 1
        self._ring.append(rec)

    def event(self, kind: str, **fields) -> None:
        """Sparse lifecycle event; carries a wall-clock stamp so the
        surrounding span records are bracketed in time."""
        self.record({"ev": "event", "kind": kind, "pid": self.pid,
                     "t": round(time.time(), 6), **fields})

    def flush(self, path: str, reason: str) -> int:
        """Write header + ring to ``path`` (latest flush wins: the ring
        IS the last-N-records window). Returns records written; never
        raises — the flush sites are exit paths."""
        try:
            recs = list(self._ring)
            header = {"ev": "flight", "pid": self.pid, "reason": reason,
                      "t": round(time.time(), 6), "n": len(recs),
                      "dropped": self.dropped, "cap": self.cap}
            tmp = f"{path}.tmp.{os.getpid()}"
            with open(tmp, "w") as f:
                f.write(json.dumps(header) + "\n")
                for r in recs:
                    f.write(json.dumps(r) + "\n")
            os.replace(tmp, path)       # readers never see a torn file
            self.flushes += 1
            return len(recs)
        except Exception:
            return 0


def flight_path(directory: str, pid: int) -> str:
    name = "coord" if pid < 0 else f"worker{pid}"
    return os.path.join(directory, f"{name}.flight.jsonl")


def check_flight_file(path: str) -> Dict:
    """Coherence check of one flight file: parses line-by-line, header
    first, known record kinds, event timestamps monotone. Returns a
    summary dict with ``problems`` (empty == coherent)."""
    problems: List[str] = []
    records = 0
    events = 0
    spans = 0
    header: Optional[Dict] = None
    last_t: Optional[float] = None
    with open(path) as f:
        for i, line in enumerate(f):
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
            except ValueError:
                problems.append(f"line {i + 1}: not JSON")
                continue
            ev = rec.get("ev")
            if i == 0:
                if ev != "flight":
                    problems.append("first record is not the flight "
                                    "header")
                else:
                    header = rec
                continue
            records += 1
            if ev not in _KNOWN_EV:
                problems.append(f"line {i + 1}: unknown ev {ev!r}")
            if ev == "span":
                spans += 1
            elif ev == "event":
                events += 1
                t = rec.get("t")
                if t is not None:
                    if last_t is not None and t < last_t - 1.0:
                        problems.append(f"line {i + 1}: event time "
                                        "regressed")
                    last_t = t
    if header is not None and header.get("n") != records:
        problems.append(f"header n={header.get('n')} but "
                        f"{records} records follow")
    if records == 0:
        problems.append("empty flight record")
    return {"path": path, "records": records, "spans": spans,
            "events": events,
            "pid": header.get("pid") if header else None,
            "reason": header.get("reason") if header else None,
            "dropped": header.get("dropped") if header else None,
            "problems": problems}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="flight-record coherence checker (chaos-smoke CI)")
    ap.add_argument("dir", help="directory of *.flight.jsonl files")
    ap.add_argument("--min-files", type=int, default=1,
                    help="fail unless at least this many flight files "
                         "exist (post-kill recovery flushes one per "
                         "survivor + the coordinator)")
    args = ap.parse_args(argv)

    paths = sorted(glob.glob(os.path.join(args.dir, "*.flight.jsonl")))
    summaries = []
    failures: List[str] = []
    if len(paths) < args.min_files:
        print(json.dumps({"dir": args.dir, "files": len(paths),
                          "ok": False,
                          "failures": [f"found {len(paths)} flight "
                                       f"files, need {args.min_files}"]},
                         indent=2))
        return 1
    for path in paths:
        try:
            s = check_flight_file(path)
        except OSError as e:
            print(json.dumps({"dir": args.dir, "ok": False,
                              "failures": [f"{path}: unreadable ({e})"]},
                             indent=2))
            return 2
        summaries.append(s)
        failures.extend(f"{os.path.basename(path)}: {p}"
                        for p in s["problems"])
    print(json.dumps({"dir": args.dir, "files": len(paths),
                      "records": sum(s["records"] for s in summaries),
                      "per_file": [{k: s[k] for k in
                                    ("path", "pid", "reason", "records",
                                     "spans", "events", "dropped")}
                                   for s in summaries],
                      "failures": failures[:20],
                      "ok": not failures}, indent=2))
    return 0 if not failures else 1


if __name__ == "__main__":
    sys.exit(main())
