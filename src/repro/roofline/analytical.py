"""Analytical (kernelized-path) roofline terms per (arch x shape).

The HLO-parse terms (hlo_parse.py) are exact for the compiled CPU module
but systematically overstate HBM traffic for a TPU: the CPU backend fuses
far less than the TPU backend, and the pure-jnp reference layers
materialize intermediates that the Pallas kernels keep in VMEM. This
module computes the minimum-traffic terms of the kernelized TPU path from
closed-form per-family models — the numbers a well-implemented TPU run
is bounded by. EXPERIMENTS.md reports BOTH (structural evidence from the
compiled artifact + projected TPU terms).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

from ..configs.base import ModelConfig, ShapeConfig
from .analysis import model_flops


def analytic_terms(cfg: ModelConfig, shape: ShapeConfig, hw: Dict,
                   chips: int, *, remat: bool = True, tp: int = 16,
                   dp_replicated_attention: bool = False) -> Dict:
    P = cfg.param_count()
    D, F, V, L = cfg.d_model, cfg.d_ff, cfg.vocab_size, cfg.n_layers
    hd, H, Kh = cfg.hd, cfg.n_heads, cfg.n_kv_heads
    B, S = shape.global_batch, shape.seq_len
    mf = model_flops(cfg, shape)

    if shape.kind in ("train", "prefill"):
        tokens = B * S
        S_eff = min(S, cfg.sliding_window or S)
        # attention score+value flops (causal ~ S_eff/2 average context)
        attn = 4.0 * L * tokens * (S_eff / 2) * H * hd
        if cfg.family == "hybrid":
            attn = attn / cfg.hybrid_attn_every   # shared block every k
        if cfg.attention_free or (cfg.family == "ssm" and cfg.slstm_every):
            attn = 0.0
        moe_disp = 0.0
        if cfg.is_moe and cfg.moe_group_size:
            moe_disp = (3.0 * 2 * cfg.top_k * cfg.capacity_factor
                        * cfg.moe_group_size * D * tokens * L)
        passes = 3.0 if shape.kind == "train" else 1.0
        recompute = 4.0 / 3.0 if (remat and shape.kind == "train") else 1.0
        flops = (mf + passes * attn + passes * moe_disp) * recompute
        if dp_replicated_attention:
            flops += (tp - 1) * passes * attn
        # HBM: params (fwd + bwd reads bf16, adam rw f32), activations
        # (per layer ~6 D-wide + 4 F-wide materializations, x2 with remat
        # re-reads), logits
        p_traffic = P * (2 * passes + (16 if shape.kind == "train" else 0))
        act = tokens * L * (6 * D + 4 * (F or 2 * D)) * 2 * \
            (2 if shape.kind == "train" else 1)
        logits = tokens * V * (6 if shape.kind == "train" else 2)
        kv_write = tokens * L * Kh * hd * 2 * 2 \
            if shape.kind == "prefill" else 0
        hbm = p_traffic + act + logits + kv_write
        # collectives: TP 2 all-reduce/layer each direction (tokens x D),
        # DP grad sync ~2 x P bf16 (reduce-scatter + all-gather)
        coll = 0.0
        if tp > 1:
            coll += 2 * passes * L * tokens * D * 2
        if shape.kind == "train":
            coll += 4.0 * P
    else:  # decode: one token per sequence
        tokens = B
        W_eff = min(S, cfg.sliding_window or S)
        p_traffic = P * 2                        # weights read once/step
        kv = 0.0
        if not cfg.attention_free:
            n_attn = (L if cfg.family not in ("hybrid",)
                      else L // cfg.hybrid_attn_every)
            if cfg.family == "ssm" and cfg.slstm_every:
                n_attn = 0
            kv = n_attn * B * W_eff * Kh * hd * 2 * 2
        state = 0.0
        if cfg.family in ("ssm", "hybrid") or cfg.slstm_every:
            from ..models.ssm import ssm_dims
            if cfg.slstm_every:
                d_in = 2 * D
                state = L * B * (d_in // cfg.n_heads) * d_in * 4 * 2
            else:
                d_inner, nh = ssm_dims(D, cfg.ssm_expand, cfg.ssm_headdim)
                state = L * B * nh * cfg.ssm_headdim * cfg.ssm_state * 4 * 2
        if cfg.is_encdec:
            kv += L * B * cfg.encoder_seq * Kh * hd * 2 * 2
        hbm = p_traffic + kv + state
        flops = mf + 2 * (kv / 2)                # ~1 MAC per cache byte/2
        coll = 2 * L * B * D * 2 * (1 if tp > 1 else 0)

    t_c = flops / (chips * hw["peak_flops_bf16"])
    t_m = hbm / (chips * hw["hbm_bw"])
    t_x = coll / (chips * hw["ici_bw"])
    step = max(t_c, t_m, t_x)
    return {
        "flops": flops, "hbm_bytes": hbm, "coll_bytes": coll,
        "t_compute": t_c, "t_memory": t_m, "t_collective": t_x,
        "bottleneck": max([("compute", t_c), ("memory", t_m),
                           ("collective", t_x)], key=lambda kv: kv[1])[0],
        "step_time": step,
        "mfu": mf / (chips * hw["peak_flops_bf16"] * step) if step else 0.0,
    }
