"""While-aware analyzer for optimized (post-SPMD) HLO text.

XLA's ``cost_analysis()`` counts while-loop bodies ONCE, which makes its
flops/bytes meaningless for scan-over-layers programs (a deliberate design
choice of this framework: scans keep the 512-device compile tractable).
This module re-derives the three roofline inputs from the module text,
multiplying through the loop nest using the ``known_trip_count`` backend
config XLA attaches to compiled scans:

* flops            — 2 * prod(dot output dims) * prod(contracted dims),
                     summed over every dot, x trip counts
* hbm bytes        — fusion-boundary traffic: for every materializing op,
                     output bytes + operand bytes (post-fusion HLO, so
                     fusion internals are free, as on a real backend)
* collective bytes — output-shape bytes per collective kind

All numbers are PER-DEVICE (the SPMD module is one device's program);
callers multiply by chip count for global totals.
"""
from __future__ import annotations

import json
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

# ops that don't materialize new HBM buffers / are bookkeeping
_SKIP_BYTES = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "while", "conditional", "call", "after-all", "partition-id",
    "replica-id", "iota",
}

_SHAPE_ATOM = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*"
    r"((?:\(.*?\))|(?:[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?))\s*"
    r"([\w\-]+)\(")   # tuple shapes may contain /*index=N*/ comments
# column-0 line '(ENTRY )%name (args...) -> shape {' — args may contain
# nested tuple parens, so key on the prefix only
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%([\w.\-]+)\s*\(")
_TRIP = re.compile(r'known_trip_count[":{]+n[":]+(\d+)')


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_ATOM.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(shape_str: str) -> List[int]:
    m = _SHAPE_ATOM.search(shape_str)
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",")]


@dataclass
class Instr:
    name: str
    shape: str
    opcode: str
    rest: str           # full line tail after opcode (operands + attrs)
    operands: List[str] = field(default_factory=list)


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: Dict[str, float] = field(default_factory=dict)

    def add(self, other: "Cost", mult: float = 1.0) -> None:
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        for k, v in other.coll.items():
            self.coll[k] = self.coll.get(k, 0.0) + v * mult

    @property
    def coll_bytes(self) -> float:
        return sum(self.coll.values())


class HloModule:
    def __init__(self, text: str):
        self.computations: Dict[str, List[Instr]] = {}
        self.entry: Optional[str] = None
        self._parse(text)
        self._memo: Dict[str, Cost] = {}

    # ------------------------------------------------------------- parsing
    def _parse(self, text: str) -> None:
        cur: Optional[str] = None
        for line in text.splitlines():
            if not line.startswith(" "):        # computation headers only
                hdr = _COMP_HDR.match(line)
                if hdr and line.rstrip().endswith("{") and "->" in line:
                    cur = hdr.group(1)
                    self.computations[cur] = []
                    if line.startswith("ENTRY"):
                        self.entry = cur
                    continue
            m = _INSTR.match(line)
            if m and cur is not None:
                name, shape, opcode = m.group(1), m.group(2), m.group(3)
                rest = line[m.end() - 1:]
                ins = Instr(name=name, shape=shape, opcode=opcode,
                            rest=rest)
                ins.operands = self._operand_names(rest)
                self.computations[cur].append(ins)
        if self.entry is None and self.computations:
            # entry is usually the last computation in the dump
            self.entry = list(self.computations)[-1]

    @staticmethod
    def _operand_names(rest: str) -> List[str]:
        """Names inside the first balanced (...) group."""
        depth = 0
        out = []
        buf = []
        for ch in rest:
            if ch == "(":
                depth += 1
                if depth == 1:
                    continue
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
            if depth >= 1:
                buf.append(ch)
        args = "".join(buf)
        for m in re.finditer(r"%([\w.\-]+)", args):
            out.append(m.group(1))
        return out

    # ------------------------------------------------------------ analysis
    def shape_of(self, comp: str, name: str) -> str:
        for ins in self.computations.get(comp, ()):
            if ins.name == name:
                return ins.shape
        return ""

    def cost(self, comp: Optional[str] = None) -> Cost:
        comp = comp if comp is not None else self.entry
        if comp in self._memo:
            return self._memo[comp]
        total = Cost()
        self._memo[comp] = total      # guards recursion
        for ins in self.computations.get(comp, ()):
            op = ins.opcode
            if op == "while":
                trip = 1
                t = _TRIP.search(ins.rest)
                if t:
                    trip = int(t.group(1))
                body = re.search(r"body=%?([\w.\-]+)", ins.rest)
                cond = re.search(r"condition=%?([\w.\-]+)", ins.rest)
                if body:
                    total.add(self.cost(body.group(1)), trip)
                if cond:
                    total.add(self.cost(cond.group(1)), trip + 1)
                continue
            if op == "conditional":
                branches = re.findall(
                    r"(?:true_computation|false_computation|"
                    r"branch_computations=\{[^}]*)=?%?([\w.\-]+)", ins.rest)
                costs = [self.cost(b) for b in branches
                         if b in self.computations]
                if costs:
                    mx = max(costs, key=lambda c: c.flops + c.bytes)
                    total.add(mx)
                continue
            if op == "fusion":
                called = re.search(r"calls=%?([\w.\-]+)", ins.rest)
                if called:
                    inner = self.cost(called.group(1))
                    total.flops += inner.flops      # dots inside fusions
                # boundary bytes
                total.bytes += _shape_bytes(ins.shape)
                for o in ins.operands:
                    total.bytes += _shape_bytes(self.shape_of(comp, o))
                continue
            if op == "dot":
                total.flops += self._dot_flops(comp, ins)
                total.bytes += _shape_bytes(ins.shape)
                for o in ins.operands:
                    total.bytes += _shape_bytes(self.shape_of(comp, o))
                continue
            if op in COLLECTIVES or any(
                    op == c + "-start" for c in COLLECTIVES):
                kind = op.replace("-start", "")
                b = _shape_bytes(ins.shape)
                total.coll[kind] = total.coll.get(kind, 0.0) + b
                total.bytes += b
                continue
            if op in _SKIP_BYTES or op.endswith("-done"):
                continue
            # generic materializing op (copy, convert, reduce, ...)
            total.bytes += _shape_bytes(ins.shape)
            for o in ins.operands:
                total.bytes += _shape_bytes(self.shape_of(comp, o))
        self._memo[comp] = total
        return total

    def _dot_flops(self, comp: str, ins: Instr) -> float:
        out_dims = _shape_dims(ins.shape)
        lhs_shape = self.shape_of(comp, ins.operands[0]) \
            if ins.operands else ""
        lhs_dims = _shape_dims(lhs_shape)
        cdims = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.rest)
        contracted = 1
        if cdims and cdims.group(1) and lhs_dims:
            for d in cdims.group(1).split(","):
                contracted *= lhs_dims[int(d)]
        n_out = 1
        for d in out_dims:
            n_out *= d
        return 2.0 * n_out * contracted


def analyze_text(text: str) -> Cost:
    return HloModule(text).cost()
