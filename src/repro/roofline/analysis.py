"""Three-term roofline from a compiled (AOT) artifact.

  compute term    = HLO_FLOPs / (chips x peak_FLOP/s)
  memory term     = HLO_bytes / (chips x HBM_bw)
  collective term = collective_bytes / (chips x link_bw)

``cost_analysis()`` provides flops/bytes; collective bytes are NOT in
cost_analysis, so we parse the optimized HLO text and sum operand sizes of
every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute op.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COLL_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute|"
    r"all-gather-start|all-reduce-start|collective-permute-start)\(",
    re.M)


def _shape_bytes(shape_str: str) -> int:
    """bytes of 'bf16[8,128]{1,0}' or tuple '(f32[2,2], u32[])'."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum of *output* operand sizes per collective kind (proxy for bytes
    moved; reduce-scatter/all-gather outputs reflect the data landed on
    each participant group)."""
    out: Dict[str, int] = {}
    for m in _COLL_RE.finditer(hlo_text):
        shape_str, kind = m.group(1), m.group(2)
        kind = kind.replace("-start", "")
        out[kind] = out.get(kind, 0) + _shape_bytes(shape_str)
    return out


@dataclass
class Roofline:
    flops: float
    hbm_bytes: float
    coll_bytes: float
    chips: int
    peak_flops: float
    hbm_bw: float
    ici_bw: float
    coll_detail: Dict[str, int] = field(default_factory=dict)
    out_bytes: float = 0.0

    @property
    def t_compute(self) -> float:
        return self.flops / (self.chips * self.peak_flops)

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / (self.chips * self.hbm_bw)

    @property
    def t_collective(self) -> float:
        # HLO shapes are global under SPMD: per-chip landed bytes ~ total/chips
        return self.coll_bytes / (self.chips * self.ici_bw)

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def step_time(self) -> float:
        """Perfect-overlap model: max of the three terms."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    def fraction_of_roofline(self, model_flops: float) -> float:
        """useful_FLOPs / (chips*peak*step_time): the score we report."""
        denom = self.chips * self.peak_flops * self.step_time
        return model_flops / denom if denom else 0.0

    def to_dict(self) -> Dict:
        return {
            "flops": self.flops, "hbm_bytes": self.hbm_bytes,
            "coll_bytes": self.coll_bytes, "chips": self.chips,
            "t_compute": self.t_compute, "t_memory": self.t_memory,
            "t_collective": self.t_collective,
            "bottleneck": self.bottleneck, "step_time": self.step_time,
            "coll_detail": self.coll_detail,
        }


def analyze_compiled(compiled, chips: int, hw: Dict,
                     hlo_text: Optional[str] = None) -> Roofline:
    """cost_analysis() and the partitioned HLO report PER-DEVICE numbers
    (the SPMD module is the program one device runs); we store GLOBAL
    totals (x chips) and divide by chips in the term formulas."""
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    flops = float(ca.get("flops", 0.0)) * chips
    byt = float(ca.get("bytes accessed", 0.0)) * chips
    text = hlo_text if hlo_text is not None else compiled.as_text()
    cd = {k: v * chips for k, v in collective_bytes(text).items()}
    return Roofline(
        flops=flops, hbm_bytes=byt, coll_bytes=float(sum(cd.values())),
        chips=chips, peak_flops=hw["peak_flops_bf16"], hbm_bw=hw["hbm_bw"],
        ici_bw=hw["ici_bw"], coll_detail=cd)


def roofline_terms(compiled, chips: int, hw: Dict) -> Dict:
    return analyze_compiled(compiled, chips, hw).to_dict()


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE); decode: D=batch
    tokens (one step), train: full batch x seq x 3 (fwd+bwd)."""
    n = cfg.param_count(active_only=True)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch          # decode: one token each
