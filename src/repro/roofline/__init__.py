from .analysis import (Roofline, analyze_compiled, collective_bytes,
                       roofline_terms)

__all__ = ["Roofline", "analyze_compiled", "collective_bytes",
           "roofline_terms"]
