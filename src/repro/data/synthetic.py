"""Deterministic synthetic LM data pipeline.

A real text pipeline is replaced (offline container) by a *learnable*
synthetic stream: order-k Markov token sequences from a seeded generator,
so the ~100M-param example run shows a genuinely decreasing loss (the model
can learn the transition structure; iid-uniform tokens would pin loss at
log V). Deterministic per (seed, step): restarting from a checkpoint
reproduces the exact stream — the pipeline state is just the step counter.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np


def _transition(vocab: int, seed: int, branch: int = 8) -> np.ndarray:
    """Sparse-ish Markov transition: each token has ``branch`` likely
    successors."""
    rng = np.random.default_rng(seed)
    nxt = rng.integers(0, vocab, size=(vocab, branch))
    return nxt


def make_batch(vocab: int, batch: int, seq: int, *, seed: int, step: int,
               extra: Optional[Dict] = None) -> Dict[str, np.ndarray]:
    """One (tokens, targets) batch; deterministic in (seed, step)."""
    nxt = _transition(vocab, seed)
    rng = np.random.default_rng((seed * 1_000_003 + step) % (2**63))
    toks = np.empty((batch, seq + 1), np.int32)
    toks[:, 0] = rng.integers(0, vocab, size=batch)
    choices = rng.integers(0, nxt.shape[1], size=(batch, seq))
    for t in range(seq):
        toks[:, t + 1] = nxt[toks[:, t], choices[:, t]]
    out = {"tokens": toks[:, :-1], "targets": toks[:, 1:]}
    if extra:
        for name, spec in extra.items():
            r = np.random.default_rng((seed * 7 + step) % (2**63))
            out[name] = r.normal(size=spec.shape).astype(np.float32)
    return out


@dataclass
class SyntheticLM:
    """Checkpointable iterator over synthetic batches."""

    vocab: int
    batch: int
    seq: int
    seed: int = 0
    step: int = 0

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        b = make_batch(self.vocab, self.batch, self.seq, seed=self.seed,
                       step=self.step)
        self.step += 1
        return b

    # -- checkpointing -----------------------------------------------------
    def state_dict(self) -> Dict:
        return {"seed": self.seed, "step": self.step}

    def load_state_dict(self, st: Dict) -> None:
        self.seed = int(st["seed"])
        self.step = int(st["step"])
