"""Train-step builder: loss + grad + AdamW update, with optional gradient
accumulation (microbatching) and remat, distributed via NamedShardings
derived from the sharding policy.

Gradient cross-replica reduction has two paths:

* baseline — XLA derives the reduction from the shardings (psum);
* device collective — when a ``collective`` *and* ``collective_devices``
  are passed, the step is compiled by the execution engine
  (``collective_exec``): a shard_map program over a real mesh axis that
  runs the epoch's schedule as ``lax.ppermute`` rounds with the fused
  Pallas bucket-combine local reduce. ``overlap="pipelined"`` makes the
  sync overlap the backward pass (reverse-topo readiness groups through
  the double-buffered executor, DESIGN.md §5); with ``microbatches > 1``
  the device path unrolls the grad-accumulation loop so microbatch k's
  bucket stream syncs while microbatch k+1's backward runs inside the
  same shard_map.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ModelConfig, ShapeConfig
from ..models.registry import ModelAPI
from ..optim import AdamW, OptState
from ..sharding import ShardingRules, param_specs, use_rules
from ..sharding.policies import batch_specs


@dataclass
class TrainStep:
    """A lowered/compilable train step plus its shardings. ``program``
    is set on the device-collective path (the engine's compiled
    GradSyncProgram); its ``jitted`` then also accepts an optional
    trailing per-worker alive mask."""

    fn: Callable                      # (params, opt, batch) -> (p, o, m)
    jitted: Any
    param_sh: Any
    opt_sh: Any
    batch_sh: Any
    program: Any = None

    def lower(self, param_spec, opt_spec, batch_spec):
        return self.jitted.lower(param_spec, opt_spec, batch_spec)


def _program_step(api: ModelAPI, opt: AdamW, collective,
                  devices: Sequence, *, remat: bool, stacked: bool,
                  donate: bool, overlap: str = "eager",
                  microbatches: int = 1) -> TrainStep:
    """Device-collective path: compile the schedule into a shard_map
    program (collective_exec) and adapt it to the TrainStep surface."""
    from ..collective_exec import build_gradsync_program
    prog = build_gradsync_program(api, opt, collective, devices=devices,
                                  stacked=stacked, remat=remat,
                                  donate=donate, overlap=overlap,
                                  microbatches=microbatches)

    def jitted(params, opt_state, batch, alive=None):
        new_p, new_o, pm = prog.step(params, opt_state, batch, alive)
        return new_p, new_o, prog.reduce_metrics(pm)

    return TrainStep(fn=jitted, jitted=jitted, param_sh=None, opt_sh=None,
                     batch_sh=None, program=prog)


def _pipeline_step(api: ModelAPI, opt: AdamW, collective,
                   devices: Sequence, *, n_stages: int, remat: bool,
                   stacked: bool, overlap: str = "eager",
                   microbatches: int = 1,
                   interleave: int = 1) -> TrainStep:
    """2-D path: the (interleaved) 1F1B stage pipeline on the stage
    axis interleaved with the epoch's collective schedule on the data
    axis (``pipeline_exec``), adapted to the TrainStep surface."""
    from ..pipeline_exec import build_pipeline_program
    prog = build_pipeline_program(api, opt, collective,
                                  n_stages=n_stages,
                                  interleave=interleave,
                                  devices=devices,
                                  microbatches=microbatches,
                                  stacked=stacked, remat=remat,
                                  overlap=overlap)

    def jitted(params, opt_state, batch, alive=None):
        new_p, new_o, pm = prog.step(params, opt_state, batch, alive)
        return new_p, new_o, prog.reduce_metrics(pm)

    return TrainStep(fn=jitted, jitted=jitted, param_sh=None, opt_sh=None,
                     batch_sh=None, program=prog)


def build_train_step(api: ModelAPI, opt: AdamW, *,
                     rules: Optional[ShardingRules] = None,
                     remat: bool = True,
                     microbatches: int = 1,
                     donate: bool = True,
                     collective=None,
                     collective_devices: Optional[Sequence] = None,
                     stacked_batch: bool = False,
                     overlap: str = "eager",
                     pipeline_stages: int = 1,
                     interleave: int = 1) -> TrainStep:
    """``collective``: the elastic epoch's PhaserCollective. It is part
    of the lowered step's *static identity* — re-building at an epoch
    boundary re-lowers for the new team. Without ``collective_devices``
    the schedule enters the step as static sync metadata in the metrics
    (team size, rounds, messages); with them, the step is the execution
    engine's compiled shard_map program and the schedule's ppermute
    rounds *are* the gradient reduction (``overlap="pipelined"`` makes
    that reduction overlap the backward pass; microbatching unrolls into
    per-microbatch bucket streams on this path).

    ``pipeline_stages > 1`` (device path only) compiles the 2-D
    (stage x data) pipeline program instead: the stacked blocks shard
    over the stage axis, microbatches flow through the wave-synchronous
    1F1B schedule — or its interleaved generalization when
    ``interleave > 1`` (v virtual stages per device, bubble fraction
    (S-1)/(vM+S-1)) — and the epoch's collective syncs each stage row
    over the data axis (``pipeline_exec``)."""
    cfg = api.cfg
    if collective is not None and collective_devices is not None:
        if pipeline_stages > 1 or interleave > 1:
            return _pipeline_step(api, opt, collective,
                                  collective_devices,
                                  n_stages=pipeline_stages, remat=remat,
                                  stacked=stacked_batch, overlap=overlap,
                                  microbatches=microbatches,
                                  interleave=interleave)
        return _program_step(api, opt, collective, collective_devices,
                             remat=remat, stacked=stacked_batch,
                             donate=donate, overlap=overlap,
                             microbatches=microbatches)
    sync_meta = None
    if collective is not None:
        st = collective.stats()
        sync_meta = {"team": collective.n,
                     "sync_rounds": st["rounds"],
                     "sync_messages": st["messages"]}

    def loss_fn(params, batch):
        with use_rules(rules):
            return api.loss_fn(params, batch, remat=remat)

    def step(params, opt_state: OptState, batch):
        if microbatches > 1:
            def mb(b):
                return jax.tree_util.tree_map(
                    lambda x: x.reshape(microbatches,
                                        x.shape[0] // microbatches,
                                        *x.shape[1:]), b)
            batches = mb(batch)

            def acc_fn(acc, b):
                (l, m), g = jax.value_and_grad(loss_fn,
                                               has_aux=True)(params, b)
                acc_g, acc_l = acc
                return (jax.tree_util.tree_map(jnp.add, acc_g, g),
                        acc_l + l), None

            zero = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), _ = jax.lax.scan(
                acc_fn, (zero, jnp.zeros((), jnp.float32)), batches)
            grads = jax.tree_util.tree_map(
                lambda g: g / microbatches, grads)
            loss = loss / microbatches
            metrics = {"loss": loss}
        else:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        new_params, new_opt, om = opt.update(grads, opt_state, params)
        out = {**metrics, **om}
        if sync_meta is not None:
            out.update({k: jnp.asarray(v, jnp.float32)
                        for k, v in sync_meta.items()})
        return new_params, new_opt, out

    param_sh = opt_sh = batch_sh = None
    if rules is not None and rules.mesh is not None:
        pspec = api.param_spec()
        specs = param_specs(pspec, rules)
        named = lambda s: NamedSharding(rules.mesh, s)
        param_sh = jax.tree_util.tree_map(named, specs,
                                          is_leaf=lambda x: isinstance(x, P))
        opt_sh = OptState(step=named(P()), mu=param_sh, nu=param_sh)
        dummy_batch = api.input_specs(
            ShapeConfig("x", 8, 8, "train"))
        bspecs = batch_specs(rules, dummy_batch)
        batch_sh = jax.tree_util.tree_map(
            named, bspecs, is_leaf=lambda x: isinstance(x, P))
        jitted = jax.jit(step,
                         in_shardings=(param_sh, opt_sh, batch_sh),
                         out_shardings=(param_sh, opt_sh, None),
                         donate_argnums=(0, 1) if donate else ())
    else:
        jitted = jax.jit(step, donate_argnums=(0, 1) if donate else ())

    return TrainStep(fn=step, jitted=jitted, param_sh=param_sh,
                     opt_sh=opt_sh, batch_sh=batch_sh)


# ---------------------------------------------------------------------------
# Serve steps (prefill / decode) — same builder pattern
# ---------------------------------------------------------------------------
def build_prefill_step(api: ModelAPI, *,
                       rules: Optional[ShardingRules] = None):
    def step(params, batch):
        with use_rules(rules):
            return api.prefill_fn(params, batch)
    if rules is not None and rules.mesh is not None:
        pspec = api.param_spec()
        named = lambda s: NamedSharding(rules.mesh, s)
        param_sh = jax.tree_util.tree_map(
            named, param_specs(pspec, rules),
            is_leaf=lambda x: isinstance(x, P))
        return jax.jit(step, in_shardings=(param_sh, None)), param_sh
    return jax.jit(step), None


def build_decode_step(api: ModelAPI, *,
                      rules: Optional[ShardingRules] = None,
                      batch: int = 1, window: int = 2048,
                      split_k: bool = False):
    from ..sharding.policies import decode_state_specs

    def step(params, state, b):
        with use_rules(rules):
            return api.decode_fn(params, state, b)

    if rules is not None and rules.mesh is not None:
        from ..sharding.policies import axis_size
        mesh = rules.mesh
        named = lambda s: NamedSharding(mesh, s)
        param_sh = jax.tree_util.tree_map(
            named, param_specs(api.param_spec(), rules),
            is_leaf=lambda x: isinstance(x, P))
        st_spec = api.decode_state_spec(batch, window)
        st_sh = jax.tree_util.tree_map(
            named, decode_state_specs(rules, api.cfg, st_spec, mesh,
                                      batch=batch, split_k=split_k),
            is_leaf=lambda x: isinstance(x, P))
        dp = rules.logical["batch"]
        bspec = dp if batch % axis_size(mesh, dp) == 0 else None
        b_sh = {"token": named(P(bspec)), "t": named(P(bspec))}
        jitted = jax.jit(step, in_shardings=(param_sh, st_sh, b_sh),
                         out_shardings=(None, st_sh),
                         donate_argnums=(1,))
        return jitted, (param_sh, st_sh, b_sh)
    return jax.jit(step, donate_argnums=(1,)), None
