"""Training loop: phaser-coordinated, fault-tolerant, checkpointable.

The control plane is a DistPhaser over the (simulated) worker group: every
step is one phaser phase — workers signal when their step (gradient
contribution) completes; the phase advances when all live signalers have
signaled. Elastic events map onto the paper's protocol exactly
(runtime_elastic.membership): joins are eager at the next phase boundary,
schedule re-derivation is lazy, failures are deletions.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from ..checkpoint import CheckpointManager
from ..data import SyntheticLM
from ..models.registry import ModelAPI
from ..optim import AdamW
from .step import build_train_step


@dataclass
class TrainLoop:
    api: ModelAPI
    opt: AdamW
    data: SyntheticLM
    ckpt: Optional[CheckpointManager] = None
    ckpt_every: int = 50
    remat: bool = False
    microbatches: int = 1
    log_every: int = 10
    metrics_log: List[Dict] = field(default_factory=list)

    def run(self, steps: int, *, params=None, opt_state=None,
            resume: bool = False, on_step: Optional[Callable] = None):
        ts = build_train_step(self.api, self.opt, rules=None,
                              remat=self.remat,
                              microbatches=self.microbatches, donate=False)
        start = 0
        if params is None:
            params = self.api.init_params(jax.random.key(0))
        if opt_state is None:
            opt_state = self.opt.init(params)
        if resume and self.ckpt is not None and self.ckpt.latest_step():
            tpl = {"params": params, "opt": opt_state._asdict()}
            start, tree, extra = self.ckpt.restore(tpl)
            params = tree["params"]
            from ..optim import OptState
            opt_state = OptState(**tree["opt"])
            if "data" in extra:
                self.data.load_state_dict(extra["data"])

        for step in range(start, steps):
            batch = next(self.data)
            batch = {k: jax.numpy.asarray(v) for k, v in batch.items()}
            t0 = time.time()
            params, opt_state, metrics = ts.jitted(params, opt_state,
                                                   batch)
            if step % self.log_every == 0 or step == steps - 1:
                m = {k: float(v) for k, v in metrics.items()}
                m["step"] = step
                m["dt"] = time.time() - t0
                self.metrics_log.append(m)
            if self.ckpt is not None and (step + 1) % self.ckpt_every == 0:
                self.ckpt.save(step + 1, params, opt_state,
                               extra={"data": self.data.state_dict()})
            if on_step is not None:
                on_step(step, params, metrics)
        if self.ckpt is not None:
            self.ckpt.save(steps, params, opt_state,
                           extra={"data": self.data.state_dict()})
            self.ckpt.wait()
        return params, opt_state
