"""Training loop: phaser-coordinated, fault-tolerant, checkpointable.

The control plane is a DistPhaser over the (simulated) worker group: every
step is one phaser phase — workers signal when their step (gradient
contribution) completes; the phase advances when all live signalers have
signaled. Elastic events map onto the paper's protocol exactly
(runtime_elastic.elastic_phaser): joins are eager, schedule re-derivation
lands lazily as a new epoch at the next phase boundary, failures are
deletions. When an ``ElasticPhaserRuntime`` is attached, the loop
re-lowers its compiled step at every epoch boundary (the schedule is part
of the step's static identity) and saves a checkpoint first, so a crash
mid-re-lower resumes into a consistent (params, epoch) pair.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from ..checkpoint import CheckpointManager
from ..data import SyntheticLM
from ..models.registry import ModelAPI
from ..optim import AdamW
from ..runtime_elastic.elastic_phaser import ElasticPhaserRuntime
from .step import build_train_step


@dataclass
class TrainLoop:
    api: ModelAPI
    opt: AdamW
    data: SyntheticLM
    ckpt: Optional[CheckpointManager] = None
    ckpt_every: int = 50
    remat: bool = False
    microbatches: int = 1
    log_every: int = 10
    metrics_log: List[Dict] = field(default_factory=list)
    # --- elastic control plane (optional) --------------------------------
    runtime: Optional[ElasticPhaserRuntime] = None
    # step -> list of ("join", None) | ("leave", wid|None) | ("fail", wid|None)
    elastic_events: Dict[int, List] = field(default_factory=dict)
    epoch_log: List[Dict] = field(default_factory=list)

    def _apply_elastic_events(self, step: int) -> None:
        for kind, arg in self.elastic_events.get(step, []):
            if kind == "join":
                self.runtime.request_join(arg, step=step)
                continue
            live = self.runtime.live
            if arg is None:
                if not live:
                    raise ValueError(f"elastic event {kind}@{step}: no "
                                     "live workers left to remove")
                wid = max(live)
            elif arg not in live:
                raise ValueError(f"elastic event {kind}:{arg}@{step}: "
                                 f"worker {arg} is not live "
                                 f"(live={sorted(live)})")
            else:
                wid = arg
            self.runtime.request_leave(wid, fail=(kind == "fail"),
                                       step=step)

    def _replay_elastic_events(self, upto: int) -> None:
        """Resume path: the runtime is reconstructed by replaying the
        churn schedule through the real protocol up to the restored
        step, so the live set and epoch index match the pre-crash run
        (phase counters restart; they are not part of the checkpoint
        contract). Only a fresh runtime is replayed — a pre-churned one
        passed in by the caller is taken as already positioned."""
        if self.runtime.events:
            return
        for s in sorted(k for k in self.elastic_events if k < upto):
            self._apply_elastic_events(s)
            self.runtime.advance(step=s)

    def _build_step(self):
        pc = (self.runtime.epoch.collective
              if self.runtime is not None else None)
        return build_train_step(self.api, self.opt, rules=None,
                                remat=self.remat,
                                microbatches=self.microbatches,
                                donate=False, collective=pc)

    def run(self, steps: int, *, params=None, opt_state=None,
            resume: bool = False, on_step: Optional[Callable] = None):
        ts = self._build_step()
        start = 0
        if params is None:
            params = self.api.init_params(jax.random.key(0))
        if opt_state is None:
            opt_state = self.opt.init(params)
        if resume and self.ckpt is not None and self.ckpt.latest_step():
            tpl = {"params": params, "opt": opt_state._asdict()}
            start, tree, extra = self.ckpt.restore(tpl)
            params = tree["params"]
            from ..optim import OptState
            opt_state = OptState(**tree["opt"])
            if "data" in extra:
                self.data.load_state_dict(extra["data"])
            if self.runtime is not None:
                self._replay_elastic_events(start)
                ts = self._build_step()     # re-lower for the epoch

        for step in range(start, steps):
            if self.runtime is not None:
                self._apply_elastic_events(step)
            batch = next(self.data)
            batch = {k: jax.numpy.asarray(v) for k, v in batch.items()}
            t0 = time.time()
            params, opt_state, metrics = ts.jitted(params, opt_state,
                                                   batch)
            if self.runtime is not None:
                # the step is one phaser phase; churn requested above
                # lands as a new epoch exactly at this boundary
                before = self.runtime.epoch.index
                released = self.runtime.advance(step=step)
                ep = self.runtime.epoch
                if ep.index != before:
                    # checkpoint-consistent swap: persist, then re-lower
                    if self.ckpt is not None:
                        self.ckpt.save(step + 1, params, opt_state,
                                       extra={"data":
                                              self.data.state_dict()})
                    ts = self._build_step()
                    self.runtime.verify_epoch()
                    self.epoch_log.append({
                        "step": step, "phase": released,
                        "epoch": ep.index, "live": list(ep.live),
                        "kind": ep.kind, **ep.stats()})
            if step % self.log_every == 0 or step == steps - 1:
                m = {k: float(v) for k, v in metrics.items()}
                m["step"] = step
                m["dt"] = time.time() - t0
                if self.runtime is not None:
                    m["epoch"] = self.runtime.epoch.index
                    m["live"] = len(self.runtime.live)
                self.metrics_log.append(m)
            if self.ckpt is not None and (step + 1) % self.ckpt_every == 0:
                self.ckpt.save(step + 1, params, opt_state,
                               extra={"data": self.data.state_dict()})
            if on_step is not None:
                on_step(step, params, metrics)
        if self.ckpt is not None:
            self.ckpt.save(steps, params, opt_state,
                           extra={"data": self.data.state_dict()})
            self.ckpt.wait()
        return params, opt_state
