"""Training loop: phaser-coordinated, fault-tolerant, checkpointable.

The control plane is a DistPhaser over the (simulated) worker group: every
step is one phaser phase — workers signal when their step (gradient
contribution) completes; the phase advances when all live signalers have
signaled. Elastic events map onto the paper's protocol exactly
(runtime_elastic.elastic_phaser): joins are eager, schedule re-derivation
lands lazily as a new epoch at the next phase boundary, failures are
deletions. When an ``ElasticPhaserRuntime`` is attached, the loop
re-lowers its compiled step at every epoch boundary (the schedule is part
of the step's static identity) and saves a checkpoint first, so a crash
mid-re-lower resumes into a consistent (params, epoch) pair.

With multiple devices available (``device_collective`` auto/True), the
per-epoch step is the execution engine's compiled shard_map program: the
global batch is sharded over the epoch's mesh axis and gradients sync
through the schedule's ppermute rounds on device. Programs come from an
epoch-aware cache keyed by (member_set, kind) plus the overlap config
(``overlap_sync`` compiles the pipelined programs of DESIGN.md §5 —
reverse-topo bucket groups synced while the backward runs, microbatch
streams interleaved), so a boundary that revisits a team swaps back to
an already-compiled executable. Every checkpoint carries the live
program-cache key, so a resume pre-compiles the exact epoch program
before step 1 instead of paying the first-step compile after restore.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint import CheckpointManager
from ..data import SyntheticLM
from ..models.registry import ModelAPI
from ..obs import timeline as obs_timeline
from ..obs.metrics import MetricsRegistry
from ..optim import AdamW
from ..runtime_elastic.elastic_phaser import ElasticPhaserRuntime
from ..utils import to_device_copy
from .step import build_train_step


@dataclass
class TrainLoop:
    api: ModelAPI
    opt: AdamW
    data: SyntheticLM
    ckpt: Optional[CheckpointManager] = None
    ckpt_every: int = 50
    remat: bool = False
    microbatches: int = 1
    log_every: int = 10
    metrics_log: List[Dict] = field(default_factory=list)
    # --- elastic control plane (optional) --------------------------------
    runtime: Optional[ElasticPhaserRuntime] = None
    # step -> list of ("join", None) | ("leave", wid|None) | ("fail", wid|None)
    elastic_events: Dict[int, List] = field(default_factory=dict)
    epoch_log: List[Dict] = field(default_factory=list)
    # device-collective data plane: None = auto (on when >1 device and the
    # batch divides the team), True = required, False = host/XLA path
    device_collective: Optional[bool] = None
    # overlapped gradient sync (device path): pipeline bucket-group
    # rounds against the backward pass / microbatch streams (DESIGN.md §5)
    overlap_sync: bool = False
    # pipeline parallelism (device path): shard the stacked blocks over
    # a stage axis and run the 1F1B wave schedule on a 2-D (stage, data)
    # mesh; ``microbatches`` is the pipeline depth M (DESIGN.md §6)
    pipeline_stages: int = 1
    # interleaved virtual stages: each device owns ``interleave``
    # non-contiguous model chunks and runs the interleaved 1F1B order —
    # bubble fraction (S-1)/(vM+S-1) instead of (S-1)/(M+S-1); needs
    # microbatches % pipeline_stages == 0 (DESIGN.md §6)
    interleave: int = 1
    # obs plane (optional): an active ``timeline`` receives wall-clock
    # step/relower spans plus the logical schedule grids the executors
    # emit at trace time; ``metrics`` shards step timings and cache hits
    timeline: Optional[obs_timeline.Timeline] = None
    metrics: Optional[MetricsRegistry] = None
    _progs: Any = field(default=None, init=False, repr=False)

    @property
    def _overlap_mode(self) -> str:
        return "pipelined" if self.overlap_sync else "eager"

    def _apply_elastic_events(self, step: int) -> None:
        for kind, arg in self.elastic_events.get(step, []):
            if kind == "join":
                self.runtime.request_join(arg, step=step)
                continue
            live = self.runtime.live
            if arg is None:
                if not live:
                    raise ValueError(f"elastic event {kind}@{step}: no "
                                     "live workers left to remove")
                wid = max(live)
            elif arg not in live:
                raise ValueError(f"elastic event {kind}:{arg}@{step}: "
                                 f"worker {arg} is not live "
                                 f"(live={sorted(live)})")
            else:
                wid = arg
            self.runtime.request_leave(wid, fail=(kind == "fail"),
                                       step=step)

    def _replay_elastic_events(self, upto: int) -> None:
        """Resume path: the runtime is reconstructed by replaying the
        churn schedule through the real protocol up to the restored
        step, so the live set and epoch index match the pre-crash run
        (phase counters restart; they are not part of the checkpoint
        contract). Only a fresh runtime is replayed — a pre-churned one
        passed in by the caller is taken as already positioned."""
        if self.runtime.events:
            return
        for s in sorted(k for k in self.elastic_events if k < upto):
            self._apply_elastic_events(s)
            self.runtime.advance(step=s)

    def _collective_devices(self, pc) -> Optional[List]:
        """Devices for the device-collective path, or None for the
        host/XLA path. Auto mode requires >1 device, enough of them for
        the team (x stages on the 2-D pipeline path), and a batch the
        team (and per-rank microbatching) divides."""
        if self.device_collective is False or pc is None:
            if self.pipeline_stages > 1 or self.interleave > 1:
                raise ValueError("pipeline_stages/interleave > 1 "
                                 "require the device-collective path")
            return None
        devs = jax.devices()
        need = pc.n * max(self.pipeline_stages, 1)
        ok = (len(devs) >= need and pc.n >= 1
              and self.data.batch % pc.n == 0
              and (self.data.batch // pc.n) % self.microbatches == 0)
        if (self.device_collective is True or self.pipeline_stages > 1
                or self.interleave > 1):
            assert ok, (f"device_collective requested but team={pc.n}, "
                        f"stages={self.pipeline_stages}, "
                        f"devices={len(devs)}, batch={self.data.batch}, "
                        f"microbatches={self.microbatches}")
            return devs
        return devs if ok and len(devs) > 1 else None

    def _ensure_progs(self):
        """The epoch-aware program cache (device-collective path); the
        overlap/microbatch config rides the cache key."""
        if self._progs is None:
            from ..collective_exec import ProgramCache
            self._progs = ProgramCache(
                lambda c: build_train_step(
                    self.api, self.opt, rules=None, remat=self.remat,
                    microbatches=self.microbatches, donate=False,
                    collective=c, collective_devices=jax.devices(),
                    overlap=self._overlap_mode,
                    pipeline_stages=self.pipeline_stages,
                    interleave=self.interleave),
                extra_key=(self._overlap_mode, self.microbatches,
                           self.pipeline_stages, self.interleave),
                metrics=self.metrics)
        return self._progs

    def _build_step(self):
        pc = (self.runtime.epoch.collective
              if self.runtime is not None else None)
        devs = self._collective_devices(pc)
        if devs is not None:
            return self._ensure_progs().get(pc)
        return build_train_step(self.api, self.opt, rules=None,
                                remat=self.remat,
                                microbatches=self.microbatches,
                                donate=False, collective=pc)

    # ------------------------------------------------- program-key ckpt
    def _program_key(self) -> Optional[Dict]:
        """Checkpointable identity of the current epoch's compiled
        program (member set, kind, seed/p, overlap config) — written
        into every checkpoint manifest so a resume can pre-compile the
        exact program before step 1."""
        if self.runtime is None or self._progs is None:
            return None
        key = self.runtime.epoch_key()
        if key is None:
            return None
        # single-process run: manifest schema matches the multi-host
        # agents, which record the surviving process set (runtime_dist)
        return {"process_set": [0], **key, "overlap": self._overlap_mode,
                "microbatches": self.microbatches,
                "pipeline_stages": self.pipeline_stages,
                "interleave": self.interleave}

    def _precompile_from_key(self, pk: Optional[Dict]) -> None:
        """Resume path: rebuild the checkpointed epoch's collective and
        compile (or cache-hit) its program before the first step."""
        if not pk or self.device_collective is False:
            return
        # config changed since the save (overlap mode, microbatching,
        # sync kind or seed): the replayed epoch would never cache-hit
        # this program, so skip rather than compile a dead executable
        if (pk.get("overlap") != self._overlap_mode
                or pk.get("microbatches") != self.microbatches
                or pk.get("pipeline_stages", 1) != self.pipeline_stages
                or pk.get("interleave", 1) != self.interleave
                or (self.runtime is not None
                    and (pk.get("kind") != self.runtime.kind
                         or pk.get("seed") != self.runtime.seed))):
            return
        from ..core.collective import PhaserCollective
        keys = tuple(pk["member_set"])
        pc = PhaserCollective(len(keys), pk.get("axis", "data"),
                              kind=pk["kind"], seed=pk["seed"],
                              p=pk["p"], keys=keys,
                              leaf_keys=tuple(pk.get("leaf_keys", ())))
        if self._collective_devices(pc) is not None:
            self._ensure_progs().get(pc)

    def _to_canonical(self, ts, params, opt_state):
        """Carried state -> canonical layer order (identity except for
        the interleaved pipeline program's device-major layout)."""
        prog = getattr(ts, "program", None)
        if prog is not None:
            return prog.readout_state(params, opt_state)
        return params, opt_state

    def _to_carried(self, ts, params, opt_state):
        """Canonical state -> the program's carried layout. For the
        interleaved pipeline this is the one permute paid at bind /
        restore; the layout depends only on (stages, interleave, rows
        per chunk), so epoch swaps under data-axis churn reuse the
        carried state without conversion."""
        prog = getattr(ts, "program", None)
        if prog is not None:
            return prog.bind_state(params, opt_state)
        return params, opt_state

    def run(self, steps: int, *, params=None, opt_state=None,
            resume: bool = False, on_step: Optional[Callable] = None):
        if self.timeline is not None:
            # active for the whole run: build-time/trace-time emitters
            # in the executors reach it via the module hook
            obs_timeline.activate(self.timeline)
        ts = self._build_step()
        start = 0
        if params is None:
            params = self.api.init_params(jax.random.key(0))
        if opt_state is None:
            opt_state = self.opt.init(params)
        if resume and self.ckpt is not None and self.ckpt.latest_step():
            # pre-compile the checkpointed epoch's program BEFORE the
            # params restore and event replay: resume reaches step 1
            # with the exact program already executable (cache hit at
            # the re-lower below)
            self._precompile_from_key(self.ckpt.program_key())
            tpl = {"params": params, "opt": opt_state._asdict()}
            start, tree, extra = self.ckpt.restore(tpl)
            params = tree["params"]
            from ..optim import OptState
            opt_state = OptState(**tree["opt"])
            if "data" in extra:
                self.data.load_state_dict(extra["data"])
            if self.runtime is not None:
                self._replay_elastic_events(start)
                ts = self._build_step()     # re-lower for the epoch

        # carried state: the program's own layout (device-major for the
        # interleaved pipeline) — converted once here, carried verbatim
        # through steps and epoch swaps, read out at save/return
        params, opt_state = self._to_carried(ts, params, opt_state)

        for step in range(start, steps):
            if self.runtime is not None:
                self._apply_elastic_events(step)
            batch = next(self.data)
            # snapshot into fresh device buffers: jnp.asarray on a host
            # buffer may alias it and read asynchronously (see utils)
            batch = {k: to_device_copy(v) for k, v in batch.items()}
            t0 = time.time()
            tp0 = (self.timeline.now() if self.timeline is not None
                   else 0.0)
            if ts.program is not None:
                # per-worker alive mask: a worker that left mid-epoch
                # contributes zeros; the program's masked mean re-scales
                ep = self.runtime.epoch
                alive = jnp.asarray([1.0 if w in self.runtime.live else 0.0
                                     for w in ep.live], jnp.float32)
                params, opt_state, metrics = ts.jitted(params, opt_state,
                                                       batch, alive)
            else:
                params, opt_state, metrics = ts.jitted(params, opt_state,
                                                       batch)
            if self.timeline is not None:
                self.timeline.complete("train.step", tp0,
                                       args={"step": step})
            if self.metrics is not None:
                self.metrics.observe("train.step_seconds",
                                     time.time() - t0)
            if self.runtime is not None:
                # the step is one phaser phase; churn requested above
                # lands as a new epoch exactly at this boundary
                before = self.runtime.epoch.index
                released = self.runtime.advance(step=step)
                ep = self.runtime.epoch
                if ep.index != before:
                    # checkpoint-consistent swap: persist, then re-lower
                    if self.ckpt is not None:
                        cp, co = self._to_canonical(ts, params, opt_state)
                        self.ckpt.save(step + 1, cp, co,
                                       extra={"data":
                                              self.data.state_dict()},
                                       program_key=self._program_key())
                    tb = (self.timeline.now()
                          if self.timeline is not None else 0.0)
                    ts = self._build_step()
                    if self.timeline is not None:
                        self.timeline.complete("epoch.relower", tb,
                                               args={"epoch": ep.index})
                    if self.metrics is not None:
                        self.metrics.inc("train.relower")
                    self.runtime.verify_epoch()
                    if self.pipeline_stages > 1 or self.interleave > 1:
                        # the stage axis's own proof: the (interleaved)
                        # 1F1B wave order against the real p2p actors
                        from ..pipeline_exec import (derive_interleaved,
                                                     verify_phase_order)
                        verify_phase_order(derive_interleaved(
                            self.pipeline_stages, self.microbatches,
                            self.interleave))
                    self.epoch_log.append({
                        "step": step, "phase": released,
                        "epoch": ep.index, "live": list(ep.live),
                        "kind": ep.kind, **ep.stats()})
            if step % self.log_every == 0 or step == steps - 1:
                m = {k: float(v) for k, v in metrics.items()}
                m["step"] = step
                m["dt"] = time.time() - t0
                if self.runtime is not None:
                    m["epoch"] = self.runtime.epoch.index
                    m["live"] = len(self.runtime.live)
                self.metrics_log.append(m)
            if self.ckpt is not None and (step + 1) % self.ckpt_every == 0:
                cp, co = self._to_canonical(ts, params, opt_state)
                self.ckpt.save(step + 1, cp, co,
                               extra={"data": self.data.state_dict()},
                               program_key=self._program_key())
            if on_step is not None:
                on_step(step, params, metrics)
        # read the carried state out to the canonical layer order — the
        # loop's return contract (and the final checkpoint) never see
        # the device-major placement
        params, opt_state = self._to_canonical(ts, params, opt_state)
        if self.ckpt is not None:
            self.ckpt.save(steps, params, opt_state,
                           extra={"data": self.data.state_dict()},
                           program_key=self._program_key())
            self.ckpt.wait()
        if self.timeline is not None:
            obs_timeline.deactivate()
        return params, opt_state
