from .step import TrainStep, build_train_step
from .loop import TrainLoop

__all__ = ["TrainStep", "build_train_step", "TrainLoop"]
