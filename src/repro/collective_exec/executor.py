"""Device-side execution of a compiled collective over the bucket buffer.

``execute_flat`` is the data plane of one gradient sync: it runs the
epoch's schedule as ``lax.ppermute`` rounds over the mesh axis, with the
local reduce of each round fused into one Pallas bucket-combine kernel
launch (``fused=True``) or plain masked jnp (``fused=False`` — the
reference the kernel is tested against). Segment-level kinds dispatch to
their dedicated executors (``halving_doubling``), and ``xla_psum`` stays
native.

``execute_flat_pipelined`` is the overlapped data plane (DESIGN.md §5):
it takes the layout's per-group sub-buffers and runs the schedule as a
**double-buffered software pipeline** over the readiness groups. The
rounds are skewed — at pipeline tick ``t`` group ``g`` executes round
``t - g`` — and within a tick every active group's ``ppermute`` is
issued *before* any group's combine runs, so group ``i``'s round is in
flight while group ``i+1``'s previous round is being combined. Each
group's chain depends only on that group's gradients, so when the
caller feeds buffers straight from ``BucketLayout.flatten_groups``, the
earliest-ready group's rounds can start while the backward pass is
still producing the later groups (per-element combine order is
identical to ``execute_flat``, so the reduced buffers are bitwise equal
to the eager path).
"""
from __future__ import annotations

from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
from jax import lax

from ..core.collective import (PhaserCollective, _dst_mask,
                               halving_doubling_allreduce,
                               schedule_allreduce)
from ..kernels.ops import bucket_combine_op
from ..obs import timeline as obs_timeline


def _make_combine(fused: bool, interpret: Optional[bool]):
    if not fused:
        return None

    def combine(acc, y, gate, op):
        return bucket_combine_op(acc, y, gate, op=op, interpret=interpret)
    return combine


def execute_flat(flat: jax.Array, pc: PhaserCollective, *,
                 fused: bool = True,
                 interpret: Optional[bool] = None) -> jax.Array:
    """All-reduce the (n_buckets, bucket_elems) buffer along
    ``pc.axis_name`` through the collective's compiled schedule. Must be
    called inside ``shard_map`` over that axis."""
    if pc.kind == "xla_psum":
        return lax.psum(flat, pc.axis_name)
    if pc.kind == "halving_doubling":
        return halving_doubling_allreduce(flat, pc.axis_name, pc.n)
    sched = pc.unified_schedule()
    tl = obs_timeline.current()
    if tl is not None:
        # trace-time: the schedule's round grid lands on the timeline
        # exactly once per lowering of this program
        tl.extend(obs_timeline.gradsync_round_events(sched))
    return schedule_allreduce(flat, pc.axis_name, sched,
                              combine=_make_combine(fused, interpret))


def execute_flat_pipelined(bufs: Sequence[jax.Array],
                           pc: PhaserCollective, *,
                           fused: bool = True,
                           interpret: Optional[bool] = None
                           ) -> List[jax.Array]:
    """All-reduce each readiness group's sub-buffer along
    ``pc.axis_name``, pipelining the schedule across groups.

    ``bufs[g]`` is group g's ``(g_buckets, bucket_elems)`` buffer
    (``BucketLayout.flatten_groups`` order: earliest-ready first).
    Returns the reduced buffers in the same order. Must be called inside
    ``shard_map`` over the axis.

    The kernel combine is launched per (group, round) with the group's
    own bucket count — the variable-group launch — so no concat/slice
    traffic is added between groups.
    """
    bufs = list(bufs)
    if pc.kind == "xla_psum":
        return [lax.psum(b, pc.axis_name) for b in bufs]
    if pc.kind == "halving_doubling":
        # segment-level kind: per-group independent chains (the groups
        # expose the overlap; the variant manages its own halving)
        return [halving_doubling_allreduce(b, pc.axis_name, pc.n)
                for b in bufs]
    sched = pc.unified_schedule()
    combine = _make_combine(fused, interpret)
    idx = lax.axis_index(pc.axis_name)
    gates = [jnp.asarray(_dst_mask(sched.n, pairs))[idx]
             for pairs in sched.rounds]
    R, G = sched.depth, len(bufs)
    tl = obs_timeline.current()
    if tl is not None:
        # overlapped groups skew by their readiness tick: group g's
        # round r executes at pipeline tick t = g + r
        for g in range(G):
            tl.extend(obs_timeline.gradsync_round_events(sched, group=g,
                                                         offset=g))
    for t in range(R + G - 1):
        active = [g for g in range(G) if 0 <= t - g < R]
        # double buffering: issue every active group's ppermute first …
        inflight = []
        for g in active:
            r = t - g
            y = lax.ppermute(bufs[g], pc.axis_name,
                             perm=list(sched.rounds[r]))
            inflight.append((g, r, y))
        # … then combine, so round t-g of group g flies while group
        # g+1's round combines
        for g, r, y in inflight:
            if combine is not None:
                bufs[g] = combine(bufs[g], y, gates[r], sched.op(r))
            elif sched.op(r) == "add":
                bufs[g] = bufs[g] + jnp.where(gates[r], y,
                                              jnp.zeros_like(y))
            else:
                bufs[g] = jnp.where(gates[r], y, bufs[g])
    return bufs
