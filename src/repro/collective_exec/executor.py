"""Device-side execution of a compiled collective over the bucket buffer.

``execute_flat`` is the data plane of one gradient sync: it runs the
epoch's schedule as ``lax.ppermute`` rounds over the mesh axis, with the
local reduce of each round fused into one Pallas bucket-combine kernel
launch (``fused=True``) or plain masked jnp (``fused=False`` — the
reference the kernel is tested against). Segment-level kinds dispatch to
their dedicated executors (``halving_doubling``), and ``xla_psum`` stays
native.
"""
from __future__ import annotations

from typing import Optional

import jax
from jax import lax

from ..core.collective import (PhaserCollective, halving_doubling_allreduce,
                               schedule_allreduce)
from ..kernels.ops import bucket_combine_op


def execute_flat(flat: jax.Array, pc: PhaserCollective, *,
                 fused: bool = True,
                 interpret: Optional[bool] = None) -> jax.Array:
    """All-reduce the (n_buckets, bucket_elems) buffer along
    ``pc.axis_name`` through the collective's compiled schedule. Must be
    called inside ``shard_map`` over that axis."""
    if pc.kind == "xla_psum":
        return lax.psum(flat, pc.axis_name)
    if pc.kind == "halving_doubling":
        return halving_doubling_allreduce(flat, pc.axis_name, pc.n)
    combine = None
    if fused:
        def combine(acc, y, gate, op):
            return bucket_combine_op(acc, y, gate, op=op,
                                     interpret=interpret)
    return schedule_allreduce(flat, pc.axis_name, pc.unified_schedule(),
                              combine=combine)
