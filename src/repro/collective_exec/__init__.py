"""Device-resident collective execution engine (DESIGN.md §4).

Compiles phaser-derived schedules into executable gradient-sync
programs: bucketed grad flattening (``buckets``), scheduled
``lax.ppermute`` rounds with a fused Pallas bucket-combine local reduce
(``executor``), ``shard_map`` train-step programs over a real mesh axis
(``program``), and the epoch-aware program cache that lets the elastic
runtime swap compiled programs at phase-advance boundaries (``cache``).
"""
from .buckets import BucketLayout, make_layout
from .cache import ProgramCache
from .executor import execute_flat
from .program import (GradSyncProgram, build_allreduce_program,
                      build_gradsync_program, mesh_for)

__all__ = ["BucketLayout", "make_layout", "ProgramCache", "execute_flat",
           "GradSyncProgram", "build_allreduce_program",
           "build_gradsync_program", "mesh_for"]
