"""Device-resident collective execution engine (DESIGN.md §4–§5).

Compiles phaser-derived schedules into executable gradient-sync
programs: bucketed grad flattening in reverse-topological (backprop
readiness) order with per-bucket readiness groups (``buckets``),
scheduled ``lax.ppermute`` rounds with a fused Pallas bucket-combine
local reduce — eager over the whole buffer or double-buffered per
readiness group (``executor``), ``shard_map`` train-step programs over
a real mesh axis with optional comm/compute overlap and microbatch
pipelining (``program``), and the epoch-aware program cache that lets
the elastic runtime swap compiled programs — eager and overlapped alike
— at phase-advance boundaries (``cache``).
"""
from .buckets import BucketLayout, make_layout
from .cache import ProgramCache
from .executor import execute_flat, execute_flat_pipelined
from .program import (OVERLAP_MODES, GradSyncProgram, HierSyncProgram,
                      build_allreduce_program, build_gradsync_program,
                      build_hier_gradsync_program, mesh_for)

__all__ = ["BucketLayout", "make_layout", "ProgramCache", "execute_flat",
           "execute_flat_pipelined", "OVERLAP_MODES", "GradSyncProgram",
           "HierSyncProgram", "build_allreduce_program",
           "build_gradsync_program", "build_hier_gradsync_program",
           "mesh_for"]
