"""Bucketed flattening of gradient pytrees for the execution engine.

The grad pytree is raveled leaf-by-leaf into one f32 vector, the *alive
flag* (1.0 for a contributing worker, 0.0 for a departed one) is
appended, and the vector is zero-padded up to a ``(n_buckets,
bucket_elems)`` buffer whose rows are lane-aligned (multiples of 128)
and VMEM-sized. One ``lax.ppermute`` round then moves the whole buffer
and one fused Pallas kernel launch combines it — instead of one op per
pytree leaf.

Because the alive flag rides the same all-reduce as the payload, the
reduced buffer's flag slot holds the live contributor count: the masked
mean (``sum(grads) / n_alive``) costs no second collective.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Tuple

import jax
import jax.numpy as jnp

from ..kernels.bucket_combine import MAX_BUCKET_BYTES

LANES = 128                        # TPU lane width: rows stay tile-aligned
DEFAULT_BUCKET_ELEMS = 1 << 16     # 256 KiB f32 rows


@dataclass(frozen=True)
class BucketLayout:
    """Static identity of the bucketed buffer: part of the compiled
    program's key (it is derived from the param spec, which only changes
    when the model does)."""

    treedef: Any
    shapes: Tuple[Tuple[int, ...], ...]
    dtypes: Tuple[Any, ...]
    sizes: Tuple[int, ...]
    payload: int                   # raveled grad elems; flag sits after
    n_buckets: int
    bucket_elems: int

    @property
    def total_elems(self) -> int:
        return self.n_buckets * self.bucket_elems

    def flatten(self, tree, alive) -> jax.Array:
        """tree -> (n_buckets, bucket_elems) f32, alive flag appended."""
        leaves = jax.tree_util.tree_leaves(tree)
        assert len(leaves) == len(self.sizes), \
            (len(leaves), len(self.sizes))
        parts = [l.astype(jnp.float32).reshape(-1) for l in leaves]
        parts.append(jnp.asarray(alive, jnp.float32).reshape(1))
        flat = jnp.concatenate(parts)
        pad = self.total_elems - flat.shape[0]
        assert pad >= 0, (flat.shape[0], self.total_elems)
        if pad:
            flat = jnp.concatenate([flat, jnp.zeros((pad,), jnp.float32)])
        return flat.reshape(self.n_buckets, self.bucket_elems)

    def unflatten(self, buf: jax.Array) -> Tuple[Any, jax.Array]:
        """(n_buckets, bucket_elems) -> (tree, contributor count)."""
        flat = buf.reshape(-1)
        leaves = []
        off = 0
        for shape, dtype, size in zip(self.shapes, self.dtypes,
                                      self.sizes):
            leaves.append(flat[off:off + size].reshape(shape)
                          .astype(dtype))
            off += size
        count = flat[self.payload]
        return jax.tree_util.tree_unflatten(self.treedef, leaves), count


def make_layout(tree, *, bucket_elems: int = None) -> BucketLayout:
    """Derive the bucket layout from a pytree of arrays or
    ShapeDtypeStructs (typically ``api.param_spec()``)."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    assert leaves, "empty gradient tree"
    shapes = tuple(tuple(l.shape) for l in leaves)
    dtypes = tuple(jnp.dtype(l.dtype) for l in leaves)
    sizes = tuple(int(math.prod(s)) for s in shapes)
    payload = sum(sizes)
    total = payload + 1                       # + alive flag
    if bucket_elems is None:
        bucket_elems = min(DEFAULT_BUCKET_ELEMS,
                           -(-total // LANES) * LANES)
    assert bucket_elems % LANES == 0, bucket_elems
    assert bucket_elems * 4 <= MAX_BUCKET_BYTES, bucket_elems
    n_buckets = -(-total // bucket_elems)
    return BucketLayout(treedef=treedef, shapes=shapes, dtypes=dtypes,
                        sizes=sizes, payload=payload, n_buckets=n_buckets,
                        bucket_elems=bucket_elems)
