"""Bucketed flattening of gradient pytrees for the execution engine.

The grad pytree is raveled leaf-by-leaf into an f32 vector, the *alive
flag* (1.0 for a contributing worker, 0.0 for a departed one) is
appended, and the vector is zero-padded up to a ``(n_buckets,
bucket_elems)`` buffer whose rows are lane-aligned (multiples of 128)
and VMEM-sized. One ``lax.ppermute`` round then moves the whole buffer
and one fused Pallas kernel launch combines it — instead of one op per
pytree leaf.

Because the alive flag rides the same all-reduce as the payload, the
reduced buffer's flag slot holds the live contributor count: the masked
mean (``sum(grads) / n_alive``) costs no second collective.

**Reverse-layer order + readiness groups** (DESIGN.md §5): leaves are
ordered by *reverse topological depth* of the grad pytree — output-side
parameters (lm_head, final_norm) first, stacked block parameters next,
input-side embeddings last — because backprop finalizes gradients in
exactly that order. Contiguous runs of leaves with the same readiness
class form **bucket groups**: group 0's buckets hold the gradients that
finalize earliest, so a pipelined executor can start syncing group 0
while the backward pass is still producing the later groups. Each group
is padded to a whole number of buckets independently, which keeps every
group's sub-buffer a standalone ``(g_buckets, bucket_elems)`` collective
operand with no dataflow dependency on the other groups' leaves.

**Per-layer scan-slice sub-groups** (``block_groups=K``): the backward
scan over the stacked blocks finalizes the stacked grad ROWS from the
last layer down, so the monolithic "blocks" group can split into K
row-range sub-groups of the scan axis — ordered last-rows-first, the
order the backward scan emits them. Each sub-group covers the same
stacked leaves restricted to its row slice (``group_rows``), padded to
whole buckets like any other group, which deepens the pipelined
executor's overlap past the 3 coarse classes. Row splitting applies
only when every stacked-blocks leaf shares one scan length; anything
else (and non-stacked class-1 leaves, e.g. a hybrid family's shared
attention, whose grads accumulate across the whole backward) keeps its
own unsplit group after the block sub-groups.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from ..kernels.bucket_combine import MAX_BUCKET_BYTES

LANES = 128                        # TPU lane width: rows stay tile-aligned
DEFAULT_BUCKET_ELEMS = 1 << 16     # 256 KiB f32 rows

# readiness classes, in the order backprop finalizes gradients:
#   0 = output side (loss head — grads ready first)
#   1 = interior blocks (stacked-layer leaves — ready after the backward
#       scan reaches layer 0)
#   2 = input side (embeddings — accumulated until the very end)
_OUTPUT_NAMES = ("lm_head", "final_norm", "head", "out_norm")
_INPUT_NAMES = ("embed", "patch_proj", "frame_proj")


def _path_names(path: Tuple) -> List[str]:
    return [str(getattr(p, "key", getattr(p, "idx", p))).lower()
            for p in path]


def _leaf_class(path: Tuple) -> int:
    for n in _path_names(path):
        if any(tag in n for tag in _OUTPUT_NAMES):
            return 0
        if any(tag in n for tag in _INPUT_NAMES):
            return 2
    return 1


def _rows_elems(size: int, shape: Tuple[int, ...],
                rows: Optional[Tuple[int, int]]) -> int:
    """Raveled elems a leaf contributes to a group: the whole leaf, or
    its [rlo, rhi) slice of the leading scan axis. The single owner of
    the row-slice accounting (layout derivation and flatten/unflatten
    must agree on it)."""
    if rows is None:
        return size
    rlo, rhi = rows
    return (rhi - rlo) * (size // shape[0])


@dataclass(frozen=True)
class BucketLayout:
    """Static identity of the bucketed buffer: part of the compiled
    program's key (it is derived from the param spec, which only changes
    when the model does).

    ``perm[j]`` is the index (into tree-flatten order) of the j-th leaf
    in buffer order; ``group_leaves`` are [lo, hi) ranges into that
    permuted order, one per readiness group (group 0 finalizes
    earliest); ``group_rows[g]`` restricts group g to a [rlo, rhi) slice
    of its stacked leaves' leading (scan) axis — ``None`` takes whole
    leaves, and row-split groups repeat the same leaf range with
    disjoint row slices (``block_groups``). ``group_buckets`` is each
    group's bucket count, and ``groups`` the derived [start, stop)
    *bucket* ranges. The alive flag occupies ``flag_index`` (flattened
    element index) at the tail of the last group — it is an input, so it
    never delays a group's readiness.
    """

    treedef: Any
    shapes: Tuple[Tuple[int, ...], ...]
    dtypes: Tuple[Any, ...]
    sizes: Tuple[int, ...]
    payload: int                   # raveled grad elems (without the flag)
    n_buckets: int
    bucket_elems: int
    perm: Tuple[int, ...] = ()
    group_leaves: Tuple[Tuple[int, int], ...] = ()
    group_rows: Tuple[Optional[Tuple[int, int]], ...] = ()
    group_buckets: Tuple[int, ...] = ()
    flag_index: int = -1

    def __post_init__(self):
        if not self.perm:
            object.__setattr__(self, "perm",
                               tuple(range(len(self.sizes))))
        if not self.group_leaves:
            object.__setattr__(self, "group_leaves",
                               ((0, len(self.sizes)),))
        if not self.group_rows:
            object.__setattr__(self, "group_rows",
                               (None,) * len(self.group_leaves))
        if not self.group_buckets:
            object.__setattr__(self, "group_buckets", (self.n_buckets,))
        if self.flag_index < 0:
            object.__setattr__(
                self, "flag_index",
                (self.n_buckets - self.group_buckets[-1])
                * self.bucket_elems + self._group_payload(-1) - 1)

    @property
    def total_elems(self) -> int:
        return self.n_buckets * self.bucket_elems

    @property
    def n_groups(self) -> int:
        return len(self.group_buckets)

    @property
    def groups(self) -> Tuple[Tuple[int, int], ...]:
        """Per-group [start, stop) bucket ranges, readiness order."""
        out, off = [], 0
        for nb in self.group_buckets:
            out.append((off, off + nb))
            off += nb
        return tuple(out)

    def _leaf_elems(self, i: int, rows: Optional[Tuple[int, int]]) -> int:
        return _rows_elems(self.sizes[i], self.shapes[i], rows)

    def _group_payload(self, g: int) -> int:
        """Raveled elems in group g, including the flag in the last."""
        if g == -1:
            g = len(self.group_leaves) - 1
        lo, hi = self.group_leaves[g]
        rows = self.group_rows[g]
        base = sum(self._leaf_elems(self.perm[j], rows)
                   for j in range(lo, hi))
        return base + (1 if g == len(self.group_leaves) - 1 else 0)

    # ----------------------------------------------------------- flatten
    def flatten_groups(self, tree, alive) -> List[jax.Array]:
        """tree -> per-group ``(g_buckets, bucket_elems)`` f32 buffers.

        Each group's buffer depends only on its own leaves — or, for a
        row-split group, only on its rows of the stacked leaves (plus
        the alive flag in the last group) — so a consumer can launch
        group 0's collective before the later groups' gradients exist.
        """
        leaves = jax.tree_util.tree_leaves(tree)
        assert len(leaves) == len(self.sizes), \
            (len(leaves), len(self.sizes))
        out = []
        for g, (lo, hi) in enumerate(self.group_leaves):
            rows = self.group_rows[g]
            parts = []
            for j in range(lo, hi):
                leaf = leaves[self.perm[j]]
                if rows is not None:
                    leaf = leaf[rows[0]:rows[1]]
                parts.append(leaf.astype(jnp.float32).reshape(-1))
            if g == self.n_groups - 1:
                parts.append(jnp.asarray(alive, jnp.float32).reshape(1))
            flat = (jnp.concatenate(parts) if parts
                    else jnp.zeros((0,), jnp.float32))
            pad = self.group_buckets[g] * self.bucket_elems - flat.shape[0]
            assert pad >= 0, (g, flat.shape[0])
            if pad:
                flat = jnp.concatenate(
                    [flat, jnp.zeros((pad,), jnp.float32)])
            out.append(flat.reshape(self.group_buckets[g],
                                    self.bucket_elems))
        return out

    def flatten(self, tree, alive) -> jax.Array:
        """tree -> (n_buckets, bucket_elems) f32, alive flag appended at
        the tail of the last readiness group."""
        return jnp.concatenate(self.flatten_groups(tree, alive), axis=0)

    # --------------------------------------------------------- unflatten
    def unflatten_groups(self, bufs: Sequence[jax.Array]
                         ) -> Tuple[Any, jax.Array]:
        """Per-group buffers -> (tree, contributor count)."""
        assert len(bufs) == self.n_groups, (len(bufs), self.n_groups)
        return self.unflatten(jnp.concatenate(list(bufs), axis=0))

    def unflatten(self, buf: jax.Array) -> Tuple[Any, jax.Array]:
        """(n_buckets, bucket_elems) -> (tree, contributor count)."""
        flat = buf.reshape(-1)
        leaves: List[Any] = [None] * len(self.sizes)
        pieces: dict = {}              # leaf idx -> [(rlo, rows array)]
        off = 0
        for g, (lo, hi) in enumerate(self.group_leaves):
            rows = self.group_rows[g]
            pos = off
            for j in range(lo, hi):
                i = self.perm[j]
                size = self._leaf_elems(i, rows)
                seg = flat[pos:pos + size]
                if rows is None:
                    leaves[i] = (seg.reshape(self.shapes[i])
                                 .astype(self.dtypes[i]))
                else:
                    pieces.setdefault(i, []).append(
                        (rows[0], seg.reshape(rows[1] - rows[0],
                                              *self.shapes[i][1:])))
                pos += size
            off += self.group_buckets[g] * self.bucket_elems
        for i, ps in pieces.items():
            stacked = jnp.concatenate(
                [p for _, p in sorted(ps, key=lambda t: t[0])], axis=0)
            leaves[i] = (stacked.reshape(self.shapes[i])
                         .astype(self.dtypes[i]))
        count = flat[self.flag_index]
        return jax.tree_util.tree_unflatten(self.treedef, leaves), count


def make_layout(tree, *, bucket_elems: int = None,
                order: str = "reverse_topo",
                block_groups: int = 1) -> BucketLayout:
    """Derive the bucket layout from a pytree of arrays or
    ShapeDtypeStructs (typically ``api.param_spec()``).

    ``order="reverse_topo"`` (default) sorts leaves by reverse
    topological depth — the order backprop finalizes their gradients —
    and records the readiness groups; ``order="tree"`` keeps the raw
    tree-flatten order in a single group (the pre-overlap layout).
    ``block_groups=K`` additionally splits the stacked-blocks group into
    K scan-row sub-groups, last rows first — the order the backward
    scan emits them — so the pipelined executor's overlap deepens past
    the 3 coarse readiness classes.
    """
    assert order in ("reverse_topo", "tree"), order
    assert block_groups >= 1, block_groups
    flat_with_paths, treedef = jax.tree_util.tree_flatten_with_path(tree)
    assert flat_with_paths, "empty gradient tree"
    paths = [p for p, _ in flat_with_paths]
    leaves = [l for _, l in flat_with_paths]
    shapes = tuple(tuple(l.shape) for l in leaves)
    dtypes = tuple(jnp.dtype(l.dtype) for l in leaves)
    sizes = tuple(int(math.prod(s)) for s in shapes)
    payload = sum(sizes)
    total = payload + 1                       # + alive flag
    if bucket_elems is None:
        bucket_elems = min(DEFAULT_BUCKET_ELEMS,
                           -(-total // LANES) * LANES)
    assert bucket_elems % LANES == 0, bucket_elems
    assert bucket_elems * 4 <= MAX_BUCKET_BYTES, bucket_elems

    if order == "reverse_topo":
        classes = [_leaf_class(p) for p in paths]
    else:
        classes = [1] * len(leaves)

    # stacked-blocks leaves: class 1, under a "blocks" subtree, with one
    # common scan length — the only leaves eligible for row splitting
    stacked = [classes[i] == 1 and "blocks" in _path_names(paths[i])
               and len(shapes[i]) >= 1 and shapes[i][0] > 0
               for i in range(len(leaves))]
    scan_lens = {shapes[i][0] for i in range(len(leaves)) if stacked[i]}
    scan_len = scan_lens.pop() if len(scan_lens) == 1 else 0
    n_row_groups = (min(block_groups, scan_len)
                    if order == "reverse_topo" and scan_len else 1)
    if n_row_groups == 1:
        stacked = [False] * len(leaves)

    if order == "reverse_topo":
        # within class 1, stacked-blocks leaves sort ahead of loose
        # class-1 leaves (whose grads accumulate across the whole
        # backward, like inputs) — a no-op unless rows are split
        sub = [0 if (classes[i] != 1 or stacked[i] or n_row_groups == 1)
               else 1 for i in range(len(leaves))]
        perm = tuple(sorted(range(len(leaves)),
                            key=lambda i: (classes[i], sub[i], i)))
    else:
        sub = [0] * len(leaves)
        perm = tuple(range(len(leaves)))

    # contiguous runs of one (readiness class, stackedness) -> groups;
    # the stacked-blocks run fans out into n_row_groups row slices,
    # ordered last-rows-first (the backward scan's emission order)
    group_leaves: List[Tuple[int, int]] = []
    group_rows: List[Optional[Tuple[int, int]]] = []
    lo = 0
    key_of = lambda i: (classes[i], sub[i], stacked[i])
    for j in range(1, len(perm) + 1):
        if j < len(perm) and key_of(perm[j]) == key_of(perm[lo]):
            continue
        if stacked[perm[lo]] and n_row_groups > 1:
            bounds = [round(k * scan_len / n_row_groups)
                      for k in range(n_row_groups + 1)]
            for k in range(n_row_groups - 1, -1, -1):
                group_leaves.append((lo, j))
                group_rows.append((bounds[k], bounds[k + 1]))
        else:
            group_leaves.append((lo, j))
            group_rows.append(None)
        lo = j

    group_buckets = []
    for g, (glo, ghi) in enumerate(group_leaves):
        elems = sum(_rows_elems(sizes[perm[j]], shapes[perm[j]],
                                group_rows[g])
                    for j in range(glo, ghi))
        if g == len(group_leaves) - 1:
            elems += 1                        # alive flag rides the tail
        group_buckets.append(max(1, -(-elems // bucket_elems)))
    # flag_index is derived in __post_init__ (tail of the last group) —
    # one owner for the flag-position invariant
    return BucketLayout(treedef=treedef, shapes=shapes, dtypes=dtypes,
                        sizes=sizes, payload=payload,
                        n_buckets=sum(group_buckets),
                        bucket_elems=bucket_elems, perm=perm,
                        group_leaves=tuple(group_leaves),
                        group_rows=tuple(group_rows),
                        group_buckets=tuple(group_buckets))
