"""Compiled device-resident programs: phaser schedules inside shard_map.

``build_gradsync_program`` compiles one membership epoch's gradient sync
into an executable ``shard_map`` train step over a real mesh axis:

  1. each mesh rank computes loss + grads on its own batch shard,
  2. the grad pytree is flattened into the bucketed buffer (alive flag
     appended — ``buckets.py``),
  3. the epoch's schedule runs as ``lax.ppermute`` rounds with the fused
     Pallas bucket-combine for the local reduce (``executor.py``),
  4. the buffer is unflattened, the masked mean is taken from the
     reduced alive count, and the optimizer update runs replicated.

Params and optimizer state are replicated (``P()``); batch and alive
mask are sharded over the data axis. ``check_rep=False`` because Pallas
calls carry no replication rule — the schedule itself guarantees every
rank ends with the same reduced buffer (tested against ``xla_psum``).

**Overlap modes** (DESIGN.md §5). ``overlap="pipelined"`` flattens the
grads per readiness group (``flatten_groups``) and runs the schedule
through ``execute_flat_pipelined``: each group's ``ppermute`` chain
depends only on that group's gradients, so the earliest-finalized
buckets (last layers, reverse-topo bucket 0) sync while the backward
pass is still producing the rest. With ``microbatches > 1`` the
grad-accumulation loop is unrolled and each microbatch's bucket stream
is issued as soon as its backward ends — microbatch ``k`` syncs while
microbatch ``k+1``'s backward runs, inside the same ``shard_map``. Both
modes execute the identical per-element combine sequence, so
``overlap="pipelined"`` is bitwise-equal to ``overlap="eager"``.

``build_allreduce_program`` is the raw data-plane program (no model):
it all-reduces a stacked per-rank value through the same bucket path —
what benchmarks and equivalence tests drive.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from ..core.collective import PhaserCollective
from .buckets import BucketLayout, make_layout
from .executor import execute_flat, execute_flat_pipelined

OVERLAP_MODES = ("eager", "pipelined")


def reduce_worker_metrics(pm: Dict[str, jax.Array],
                          meta: Dict[str, int]) -> Dict[str, Any]:
    """Per-worker (n,) metric rows -> scalars: masked mean for the
    pre-sync losses, the sum for the alive count, any rank's copy for
    post-sync values (replicated by construction), plus the program's
    static meta. Shared by every compiled program flavour so the
    reported metrics can never drift between the single-axis and
    pipeline paths."""
    n_alive = jnp.maximum(pm["alive"].sum(), 1.0)
    out = {}
    for k, v in pm.items():
        if k in ("loss", "aux"):
            out[k] = v.sum() / n_alive
        elif k == "alive":
            out[k] = v.sum()
        else:
            out[k] = v[0]
    out.update({k: jnp.asarray(v, jnp.float32) for k, v in meta.items()})
    return out


def mesh_for(pc: PhaserCollective,
             devices: Optional[Sequence] = None) -> Mesh:
    devices = list(devices) if devices is not None else jax.devices()
    assert len(devices) >= pc.n, \
        f"need {pc.n} devices for axis {pc.axis_name!r}, " \
        f"have {len(devices)}"
    return Mesh(np.array(devices[:pc.n]), (pc.axis_name,))


@dataclass
class GradSyncProgram:
    """One epoch's compiled train step. ``key`` is the program-cache
    identity: (member_set, kind, seed, p, overlap, microbatches)."""

    key: tuple
    pc: PhaserCollective
    mesh: Mesh
    layout: BucketLayout
    jitted: Callable          # (params, opt, batch, alive) -> (p, o, pm)
    stacked: bool
    meta: Dict[str, int] = field(default_factory=dict)

    @property
    def n(self) -> int:
        return self.pc.n

    def _replicated(self, tree):
        """Re-commit carried state onto this program's mesh (the epoch
        swap moves params between meshes of different sizes; jit refuses
        mixed committed device sets, so the swap is an explicit
        replicated device_put — a no-op within an epoch)."""
        sh = jax.sharding.NamedSharding(self.mesh, P())
        return jax.tree_util.tree_map(
            lambda x: x if getattr(x, "sharding", None) == sh
            else jax.device_put(x, sh), tree)

    def step(self, params, opt_state, batch, alive=None):
        """Run one synced step; ``alive`` defaults to the full team."""
        if alive is None:
            alive = jnp.ones((self.pc.n,), jnp.float32)
        params = self._replicated(params)
        opt_state = self._replicated(opt_state)
        return self.jitted(params, opt_state, batch, alive)

    # single-axis programs carry canonical state: the converters exist
    # so loops drive this and the device-major pipeline program alike
    def bind_state(self, params, opt_state):
        return params, opt_state

    def readout_state(self, params, opt_state):
        return params, opt_state

    def reduce_metrics(self, pm: Dict[str, jax.Array]) -> Dict[str, Any]:
        return reduce_worker_metrics(pm, self.meta)


def build_gradsync_program(api, opt, pc: PhaserCollective, *,
                           devices: Optional[Sequence] = None,
                           stacked: bool = False,
                           remat: bool = False,
                           fused: bool = True,
                           interpret: Optional[bool] = None,
                           donate: bool = False,
                           bucket_elems: Optional[int] = None,
                           overlap: str = "eager",
                           microbatches: int = 1,
                           block_groups: Optional[int] = None
                           ) -> GradSyncProgram:
    """Compile the epoch's schedule into a shard_map train step.

    ``stacked=True`` takes per-worker batches stacked on a leading team
    axis (leaves ``(n, B, S)``); ``stacked=False`` shards a global batch
    (leaves ``(B, S)``, ``B % n == 0``) over the data axis.

    ``overlap="pipelined"`` runs the sync per readiness group through
    the double-buffered executor; ``microbatches > 1`` unrolls the
    grad-accumulation loop with one bucket stream per microbatch (each
    microbatch's sync overlaps the next microbatch's backward);
    ``block_groups=K`` splits the stacked-blocks group into K scan-row
    sub-groups (last rows first — the backward scan's emission order) so
    the pipelined overlap deepens past the 3 coarse readiness classes.
    The overlap modes are bitwise-equal at fixed ``microbatches`` for
    any grouping: grouping only partitions the buffer, never the
    per-element combine sequence.
    """
    assert overlap in OVERLAP_MODES, overlap
    assert microbatches >= 1, microbatches
    mesh = mesh_for(pc, devices)
    layout = make_layout(api.param_spec(), bucket_elems=bucket_elems,
                         block_groups=block_groups or 1)
    axis = pc.axis_name

    def sync(grads, flag):
        """One bucket-stream all-reduce; returns per-group buffers."""
        if overlap == "pipelined":
            bufs = layout.flatten_groups(grads, flag)
            return execute_flat_pipelined(bufs, pc, fused=fused,
                                          interpret=interpret)
        flat = execute_flat(layout.flatten(grads, flag), pc,
                            fused=fused, interpret=interpret)
        return [flat]

    def unflatten(bufs):
        if overlap == "pipelined":
            return layout.unflatten_groups(bufs)
        return layout.unflatten(bufs[0])

    def worker(params, opt_state, batch, alive):
        if stacked:
            batch = jax.tree_util.tree_map(lambda x: x[0], batch)
        a = alive[0]

        def mb_grads(b):
            (_, metrics), grads = jax.value_and_grad(
                api.loss_fn, has_aux=True)(params, b, remat=remat)
            grads = jax.tree_util.tree_map(
                lambda g: g * a.astype(g.dtype), grads)
            return metrics, grads

        if microbatches == 1:
            metrics, grads = mb_grads(batch)
            synced = sync(grads, a)
        else:
            mbs = jax.tree_util.tree_map(
                lambda x: x.reshape(microbatches,
                                    x.shape[0] // microbatches,
                                    *x.shape[1:]), batch)
            synced = None
            loss = aux = jnp.zeros((), jnp.float32)
            # unrolled (not scan): microbatch k's collective chain has
            # no dependency on microbatch k+1's backward, so the two
            # overlap inside the compiled step. The flag rides each
            # stream at a/M — the reduced count stays n_alive.
            for k in range(microbatches):
                b = jax.tree_util.tree_map(lambda x: x[k], mbs)
                m, grads = mb_grads(b)
                loss = loss + m["loss"]
                aux = aux + m.get("aux", jnp.zeros(()))
                red = sync(grads, a / microbatches)
                synced = red if synced is None else \
                    [s + r for s, r in zip(synced, red)]
            metrics = {"loss": loss / microbatches,
                       "aux": aux / microbatches}
        grads, count = unflatten(synced)
        inv = 1.0 / jnp.maximum(count, 1.0)
        if microbatches > 1:
            inv = inv / microbatches
        grads = jax.tree_util.tree_map(
            lambda g: g * inv.astype(g.dtype), grads)
        new_p, new_o, om = opt.update(grads, opt_state, params)
        pm = {"loss": metrics["loss"] * a,
              "aux": metrics.get("aux", jnp.zeros(())) * a,
              "alive": a, **om}
        pm = {k: jnp.asarray(v, jnp.float32).reshape(1)
              for k, v in pm.items()}
        return new_p, new_o, pm

    sm = shard_map(worker, mesh=mesh,
                   in_specs=(P(), P(), P(axis), P(axis)),
                   out_specs=(P(), P(), P(axis)),
                   check_rep=False)
    jitted = jax.jit(sm, donate_argnums=(0, 1) if donate else ())
    st = pc.stats()
    meta = {"team": pc.n, "sync_rounds": st["rounds"],
            "sync_messages": st["messages"],
            "overlap": int(overlap == "pipelined"),
            "bucket_groups": layout.n_groups,
            "microbatches": microbatches}
    return GradSyncProgram(key=(pc.keys, pc.kind, pc.seed, pc.p,
                                overlap, microbatches),
                           pc=pc, mesh=mesh,
                           layout=layout, jitted=jitted, stacked=stacked,
                           meta=meta)


@dataclass
class HierSyncProgram:
    """Two-level gradient sync for the multi-host runtime (DESIGN.md
    §11). Level 0 reduces one process's M local device shards inside a
    ``shard_map`` (the local collective); level 1 runs the *process-
    level* schedule — derived from the same skip-list oracle, over the
    live process keys — as real transport messages between processes.
    Only the flat bucket buffer crosses the process boundary; the two
    jitted halves stay device-resident:

      ``local_grads``: (params, opt, batch, alive) -> (flat, pm) — per-
          device grads, flattened with the alive flag, locally reduced
          so every local device (hence the host copy) holds the
          process-partial sum;
      ``apply``: (params, opt, flat) -> (params, opt, pm) — unflatten
          the *globally* reduced buffer, masked-mean by the reduced
          alive count (= live processes x M), optimizer update.

    Identical reduced buffers on every process keep params replicated
    across hosts with zero parameter traffic. ``key`` is keyed by the
    process-level collective: the cache entry a surviving host
    re-commits at each churn epoch boundary."""

    key: tuple
    pc_proc: PhaserCollective     # process-level collective (epoch id)
    pc_local: PhaserCollective    # local M-device collective
    mesh: Mesh
    layout: BucketLayout
    local_grads: Callable
    apply: Callable
    meta: Dict[str, int] = field(default_factory=dict)

    @property
    def proc_schedule(self):
        """The round schedule the owning process executes over the
        transport (add rounds reduce, copy rounds hydrate)."""
        return self.pc_proc.unified_schedule()

    def _replicated(self, tree):
        sh = jax.sharding.NamedSharding(self.mesh, P())
        return jax.tree_util.tree_map(
            lambda x: x if getattr(x, "sharding", None) == sh
            else jax.device_put(x, sh), tree)

    def bind_state(self, params, opt_state):
        return params, opt_state

    def readout_state(self, params, opt_state):
        return params, opt_state

    def reduce_metrics(self, pm, extra=None):
        return reduce_worker_metrics(pm, {**self.meta, **(extra or {})})


def build_hier_gradsync_program(api, opt, pc_proc: PhaserCollective, *,
                                local_devices: Sequence,
                                local_kind: str = "phaser_scsl",
                                remat: bool = False,
                                fused: bool = True,
                                interpret: Optional[bool] = None,
                                bucket_elems: Optional[int] = None
                                ) -> HierSyncProgram:
    """Compile one churn epoch's hierarchical sync for one process.

    ``pc_proc`` spans the live *process* keys (the epoch identity);
    the local level is a fresh collective over ``range(M)`` for this
    process's ``local_devices`` — identical on every host, so the
    programs only differ by their slice of the batch. ``pc_proc.kind``
    must be a whole-buffer round schedule (``phaser_scsl`` or
    ``recursive_doubling``): the cross-process rounds are executed by
    the transport, not by XLA."""
    assert pc_proc.unified_schedule() is not None, \
        f"process-level kind {pc_proc.kind!r} is not a round schedule"
    m = len(local_devices)
    pc_local = PhaserCollective(m, pc_proc.axis_name, kind=local_kind,
                                seed=pc_proc.seed)
    mesh = mesh_for(pc_local, local_devices)
    layout = make_layout(api.param_spec(), bucket_elems=bucket_elems)
    axis = pc_local.axis_name

    def grads_worker(params, opt_state, batch, alive):
        batch = jax.tree_util.tree_map(lambda x: x[0], batch)
        a = alive[0]
        (_, metrics), grads = jax.value_and_grad(
            api.loss_fn, has_aux=True)(params, batch, remat=remat)
        grads = jax.tree_util.tree_map(
            lambda g: g * a.astype(g.dtype), grads)
        flat = execute_flat(layout.flatten(grads, a), pc_local,
                            fused=fused, interpret=interpret)
        pm = {"loss": metrics["loss"] * a, "alive": a}
        pm = {k: jnp.asarray(v, jnp.float32).reshape(1)
              for k, v in pm.items()}
        return flat[None], pm

    sm = jax.jit(shard_map(grads_worker, mesh=mesh,
                           in_specs=(P(), P(), P(axis), P(axis)),
                           out_specs=(P(axis), P(axis)),
                           check_rep=False))

    def local_grads(params, opt_state, batch, alive):
        stacked_flat, pm = sm(params, opt_state, batch, alive)
        # every local rank holds the same locally-reduced buffer
        return stacked_flat[0], pm

    def apply_worker(params, opt_state, flat):
        grads, count = layout.unflatten(flat)
        inv = 1.0 / jnp.maximum(count, 1.0)
        grads = jax.tree_util.tree_map(
            lambda g: g * inv.astype(g.dtype), grads)
        new_p, new_o, om = opt.update(grads, opt_state, params)
        om = {k: jnp.asarray(v, jnp.float32) for k, v in om.items()}
        return new_p, new_o, om

    st = pc_proc.stats()
    lst = pc_local.stats()
    meta = {"team": pc_proc.n * m, "processes": pc_proc.n,
            "local_devices": m,
            "sync_rounds": st["rounds"] + lst["rounds"],
            "sync_messages": st["messages"] * m + lst["messages"]}
    return HierSyncProgram(
        key=(pc_proc.keys, pc_proc.kind, pc_proc.seed, pc_proc.p,
             "hier", m, local_kind),
        pc_proc=pc_proc, pc_local=pc_local, mesh=mesh, layout=layout,
        local_grads=local_grads, apply=jax.jit(apply_worker),
        meta=meta)


def build_allreduce_program(pc: PhaserCollective, spec, *,
                            devices: Optional[Sequence] = None,
                            fused: bool = True,
                            interpret: Optional[bool] = None) -> Callable:
    """Compile a bare bucketed all-reduce: ``(n, *spec.shape)`` stacked
    per-rank values -> the same, every rank holding the reduced sum."""
    mesh = mesh_for(pc, devices)
    layout = make_layout({"x": spec})

    def worker(x):
        flat = layout.flatten({"x": x[0].astype(jnp.float32)},
                              jnp.float32(1.0))
        flat = execute_flat(flat, pc, fused=fused, interpret=interpret)
        tree, _ = layout.unflatten(flat)
        return tree["x"][None].astype(x.dtype)

    return jax.jit(shard_map(worker, mesh=mesh, in_specs=P(pc.axis_name),
                             out_specs=P(pc.axis_name), check_rep=False))
