"""Epoch-aware program cache: ``(member_set, kind)`` -> compiled program.

The elastic runtime swaps data-plane programs at phase-advance
boundaries (DESIGN.md §3): when a boundary lands a new epoch, the next
epoch's program is looked up here — compiled once per distinct
``(member_set, kind)`` and re-used when churn revisits a team (a worker
set that grew back, an A/B membership flip). The phaser's keys are never
recycled, so within one runtime the member set *is* the topology
identity: skip-list heights are a deterministic function of
``(seed, key)``, so equal key sets under the same seed derive equal
skip lists and therefore equal schedules. The cache key carries
``(seed, p)`` alongside ``(member_set, kind)`` to stay correct when one
cache serves collectives from differently-seeded runtimes, and an
``extra_key`` for builder-level configuration that changes the compiled
program without changing the collective — the overlap mode, bucket-group
config, and microbatch count (DESIGN.md §5): an eager and a pipelined
program over the same member set are distinct cache entries.

LRU-bounded: compiled shard_map executables hold device buffers; the
default capacity keeps the last 8 teams warm.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Any, Callable, Optional, Tuple

from ..core.collective import PhaserCollective


class ProgramCache:
    def __init__(self, builder: Callable[[PhaserCollective], Any], *,
                 capacity: Optional[int] = 8,
                 extra_key: Tuple = (),
                 metrics: Any = None):
        self._builder = builder
        self._programs: "OrderedDict[Tuple, Any]" = OrderedDict()
        self.capacity = capacity
        self.extra_key = tuple(extra_key)
        self.metrics = metrics   # obs.MetricsRegistry shard, optional
        self.hits = 0
        self.misses = 0

    @staticmethod
    def key_of(pc: PhaserCollective) -> Tuple:
        # leaf_keys: a demoted straggler changes the schedule without
        # changing the member set — it must be a distinct cache entry
        return (pc.keys, pc.kind, pc.seed, pc.p,
                tuple(getattr(pc, "leaf_keys", ()) or ()))

    def full_key(self, pc: PhaserCollective) -> Tuple:
        """Cache identity of this collective's program: the collective
        key plus the cache's static builder config (overlap mode,
        bucket groups, microbatches)."""
        return self.key_of(pc) + self.extra_key

    def get(self, pc: PhaserCollective) -> Any:
        """The compiled program for this collective's (member_set, kind),
        building it on first use."""
        key = self.full_key(pc)
        prog = self._programs.get(key)
        if prog is not None:
            self.hits += 1
            if self.metrics is not None:
                self.metrics.inc("program_cache.hits")
            self._programs.move_to_end(key)
            return prog
        self.misses += 1
        if self.metrics is not None:
            self.metrics.inc("program_cache.misses")
        prog = self._builder(pc)
        self._programs[key] = prog
        if self.capacity and len(self._programs) > self.capacity:
            self._programs.popitem(last=False)
        return prog

    def __contains__(self, pc: PhaserCollective) -> bool:
        return self.full_key(pc) in self._programs

    def __len__(self) -> int:
        return len(self._programs)

    def stats(self) -> dict:
        return {"entries": len(self._programs), "hits": self.hits,
                "misses": self.misses}
