"""Small cross-cutting helpers.

``to_device_copy`` exists because of a real flake (DESIGN.md §7):
``jnp.asarray(np_buf)``'s host-to-device transfer may *alias* the source
buffer and read it asynchronously after dispatch returns. Handing it a
buffer the caller mutates right afterwards (the next prefill token, an
in-place position bump, a reused staging array) races the pending
execution — flakily, since the window depends on dispatch latency. Every
dispatch site that feeds a host buffer it does not exclusively own into
a jitted call must snapshot through this helper.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def to_device_copy(buf, dtype=None) -> jnp.ndarray:
    """Snapshot a host buffer into a device array via a fresh, never
    mutated copy. Safe against the async host-to-device aliasing race;
    also normalizes non-contiguous views (np slices) before transfer."""
    return jnp.asarray(np.array(buf, dtype=dtype, copy=True))
