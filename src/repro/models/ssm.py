"""Mamba2 (SSD) block, TPU-adapted.

The GPU reference implements the selective scan with warp-level primitives;
the TPU-idiomatic formulation (DESIGN.md §2) is the *chunked SSD* form:
within a chunk the state update is a dense matmul (MXU-friendly), across
chunks a short sequential carry (lax.scan over chunks). The Pallas kernel
(kernels/mamba2_scan.py) implements the same chunking with explicit VMEM
tiles; this module is the pure-jnp layer used for training/prefill, plus an
O(1)-state decode step used for long-context serving.

State-space: h_t = a_t * h_{t-1} + b_t x_t^T (per head, state N, headdim P)
             y_t = C_t h_t + D x_t
with scalar-per-head decay a_t = exp(-softplus(A) * dt_t).
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..sharding import constrain
from .layers import dense_init


def ssm_dims(d_model: int, expand: int, headdim: int) -> Tuple[int, int]:
    d_inner = expand * d_model
    n_heads = d_inner // headdim
    return d_inner, n_heads


def ssm_init(key, d_model: int, *, state: int, conv: int, expand: int,
             headdim: int, layers: Optional[int], dtype) -> Dict:
    d_inner, nh = ssm_dims(d_model, expand, headdim)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    lead = () if layers is None else (layers,)
    # in_proj emits [z (gate), x, B, C, dt]
    d_proj = 2 * d_inner + 2 * state + nh
    return {
        "in_proj": dense_init(k1, d_model, d_proj, layers=layers,
                              dtype=dtype),
        "conv_w": (jax.random.normal(k2, (*lead, conv,
                                          d_inner + 2 * state),
                                     jnp.float32) * 0.1).astype(dtype),
        "A_log": jnp.zeros((*lead, nh), jnp.float32),
        "D": jnp.ones((*lead, nh), jnp.float32),
        "dt_bias": jnp.zeros((*lead, nh), jnp.float32),
        "out_proj": dense_init(k3, d_inner, d_model, layers=layers,
                               dtype=dtype),
        "norm_w": jnp.ones((*lead, d_inner), dtype),
    }


def _split_proj(p: Dict, u: jax.Array, d_inner: int, state: int, nh: int):
    zxbcdt = u @ p["in_proj"]
    z, xbc, dt = jnp.split(
        zxbcdt, [d_inner, 2 * d_inner + 2 * state], axis=-1)
    return z, xbc, dt


def _causal_conv(xbc: jax.Array, w: jax.Array) -> jax.Array:
    """Depthwise causal conv over (B,S,C) with taps (K,C)."""
    K = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(xbc)
    for i in range(K):
        out = out + pad[:, i:i + xbc.shape[1], :] * w[i]
    return jax.nn.silu(out)


def ssm_apply(p: Dict, u: jax.Array, *, state: int, conv: int, expand: int,
              headdim: int, chunk: int = 256) -> jax.Array:
    """Training/prefill forward. u: (B,S,D) -> (B,S,D)."""
    B, S, D = u.shape
    d_inner, nh = ssm_dims(D, expand, headdim)
    z, xbc, dt = _split_proj(p, u, d_inner, state, nh)
    xbc = _causal_conv(xbc, p["conv_w"])
    x, Bmat, Cmat = jnp.split(xbc, [d_inner, d_inner + state], axis=-1)
    x = x.reshape(B, S, nh, headdim)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"])                      # (B,S,nh)
    a = jnp.exp(-jnp.exp(p["A_log"]) * dt)                    # decay in (0,1)

    # ---- chunked SSD: ONE chunk at a time (sequential scan over chunks,
    # matching the Pallas kernel's sequential grid dim) so the quadratic
    # (c x c) intra-chunk tensors exist for a single chunk only ----
    nchunk = max(1, math.ceil(S / chunk))
    pad = nchunk * chunk - S
    def padc(t):
        return jnp.pad(t, [(0, 0), (0, pad)] + [(0, 0)] * (t.ndim - 2))
    # xs layout: (Nc, B, c, ...)
    xc = padc(x).reshape(B, nchunk, chunk, nh, headdim).transpose(
        1, 0, 2, 3, 4)
    Bc = padc(Bmat).reshape(B, nchunk, chunk, state).transpose(1, 0, 2, 3)
    Cc = padc(Cmat).reshape(B, nchunk, chunk, state).transpose(1, 0, 2, 3)
    ac = padc(a).reshape(B, nchunk, chunk, nh).transpose(1, 0, 2, 3)
    dtc = padc(dt).reshape(B, nchunk, chunk, nh).transpose(1, 0, 2, 3)

    idx = jnp.arange(chunk)
    causal = idx[:, None] >= idx[None, :]

    def body(h, xs):
        x_i, B_i, C_i, a_i, dt_i = xs                  # (B,c,...)
        x_i = x_i.astype(jnp.float32)
        B_i = B_i.astype(jnp.float32)
        C_i = C_i.astype(jnp.float32)
        la = jnp.cumsum(jnp.log(a_i + 1e-20), axis=1)  # (B,c,nh)
        seg = la[:, :, None, :] - la[:, None, :, :]    # (B,c,c,nh)
        # mask in log space BEFORE exp (0*inf => NaN grads otherwise)
        seg = jnp.where(causal[None, :, :, None], seg, -1e30)
        G = jnp.exp(seg)
        CB = jnp.einsum("bcs,bks->bck", C_i, B_i)      # (B,c,c)
        W = CB[..., None] * G                          # (B,c,c,nh)
        y_intra = jnp.einsum("bckh,bkhp->bchp", W, x_i * dt_i[..., None])
        # inter-chunk: contribution of the incoming state
        decay_from_start = jnp.exp(la)                 # (B,c,nh)
        y_inter = jnp.einsum("bcs,bhps,bch->bchp", C_i, h,
                             decay_from_start)
        # state update
        decay_to_end = jnp.exp(la[:, -1:, :] - la)     # (B,c,nh)
        S_c = jnp.einsum("bcs,bch,bchp->bhps", B_i, decay_to_end * dt_i,
                         x_i)
        h_new = h * jnp.exp(la[:, -1, :])[..., None, None] + S_c
        return h_new, y_intra + y_inter

    h0 = jnp.zeros((B, nh, headdim, state), jnp.float32)
    _, yc = jax.lax.scan(jax.checkpoint(body), h0,
                         (xc, Bc, Cc, ac, dtc))
    y = yc.transpose(1, 0, 2, 3, 4).reshape(
        B, nchunk * chunk, nh, headdim)[:, :S]
    y = y + x.astype(jnp.float32) * p["D"][..., None]
    y = y.reshape(B, S, d_inner)

    # gated RMSNorm then out-projection
    y = y * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(y * y, axis=-1, keepdims=True)
    y = (y * jax.lax.rsqrt(var + 1e-5)) * p["norm_w"].astype(jnp.float32)
    y = constrain(y.astype(u.dtype), "batch", None, "ff")
    return y @ p["out_proj"]


# ---------------------------------------------------------------------------
# O(1)-state decode
# ---------------------------------------------------------------------------
def ssm_state_spec(batch: int, d_model: int, *, state: int, conv: int,
                   expand: int, headdim: int, dtype) -> Dict:
    d_inner, nh = ssm_dims(d_model, expand, headdim)
    return {
        "h": jax.ShapeDtypeStruct((batch, nh, headdim, state), jnp.float32),
        "conv": jax.ShapeDtypeStruct((batch, conv - 1, d_inner + 2 * state),
                                     dtype),
    }


def ssm_init_state(batch: int, d_model: int, *, state: int, conv: int,
                   expand: int, headdim: int, dtype) -> Dict:
    d_inner, nh = ssm_dims(d_model, expand, headdim)
    return {
        "h": jnp.zeros((batch, nh, headdim, state), jnp.float32),
        "conv": jnp.zeros((batch, conv - 1, d_inner + 2 * state), dtype),
    }


def ssm_decode_step(p: Dict, u: jax.Array, st: Dict, *, state: int,
                    conv: int, expand: int, headdim: int
                    ) -> Tuple[jax.Array, Dict]:
    """u: (B,1,D); st: {"h": (B,nh,P,N), "conv": (B,K-1,C)}."""
    B, S, D = u.shape
    d_inner, nh = ssm_dims(D, expand, headdim)
    z, xbc, dt = _split_proj(p, u, d_inner, state, nh)
    window = jnp.concatenate([st["conv"], xbc], axis=1)       # (B,K,C)
    w = p["conv_w"]
    xbc_c = jax.nn.silu(jnp.sum(window * w, axis=1, keepdims=True))
    x, Bv, Cv = jnp.split(xbc_c, [d_inner, d_inner + state], axis=-1)
    x = x.reshape(B, nh, headdim)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"])[:, 0]                # (B,nh)
    a = jnp.exp(-jnp.exp(p["A_log"]) * dt)                    # (B,nh)
    h = st["h"] * a[..., None, None] + jnp.einsum(
        "bhp,bs,bh->bhps", x.astype(jnp.float32), Bv[:, 0].astype(jnp.float32), dt)
    y = jnp.einsum("bs,bhps->bhp", Cv[:, 0].astype(jnp.float32), h)
    y = y + x.astype(jnp.float32) * p["D"][..., None]
    y = y.reshape(B, 1, d_inner)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(y * y, axis=-1, keepdims=True)
    y = (y * jax.lax.rsqrt(var + 1e-5)) * p["norm_w"].astype(jnp.float32)
    out = y.astype(u.dtype) @ p["out_proj"]
    return out, {"h": h, "conv": window[:, 1:]}
