"""Encoder-decoder (Whisper-style) stack. The audio conv frontend is a STUB
per the assignment: ``input_specs()`` supplies precomputed frame embeddings
(B, enc_seq, d_model). Encoder: non-causal self-attention; decoder: causal
self-attention + cross-attention over the encoder output.

Positioning adaptation: Whisper uses sinusoidal (encoder) / learned
(decoder) absolute embeddings; we use RoPE on self-attention uniformly and
position-free cross-attention — structurally identical compute/memory, one
code path (noted in DESIGN.md §2).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..sharding import constrain
from . import attention as A
from .layers import (embed_apply, embed_init, mlp_apply, mlp_init, rmsnorm,
                     unembed_apply)

Params = Dict


def init_params(cfg: ModelConfig, key) -> Params:
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 8)
    Le, Ld = cfg.encoder_layers, cfg.n_layers

    def attn(k, n):
        return A.attn_init(k, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                           cfg.hd, layers=n, dtype=dt, qkv_bias=cfg.qkv_bias)

    return {
        "embed": embed_init(ks[0], cfg.vocab_size, cfg.d_model, dt),
        "enc_blocks": {
            "ln1": jnp.ones((Le, cfg.d_model), dt),
            "ln2": jnp.ones((Le, cfg.d_model), dt),
            "attn": attn(ks[1], Le),
            "mlp": mlp_init(ks[2], cfg.d_model, cfg.d_ff, layers=Le,
                            dtype=dt),
        },
        "enc_norm": jnp.ones((cfg.d_model,), dt),
        "dec_blocks": {
            "ln1": jnp.ones((Ld, cfg.d_model), dt),
            "lnx": jnp.ones((Ld, cfg.d_model), dt),
            "ln2": jnp.ones((Ld, cfg.d_model), dt),
            "attn": attn(ks[3], Ld),
            "xattn": attn(ks[4], Ld),
            "mlp": mlp_init(ks[5], cfg.d_model, cfg.d_ff, layers=Ld,
                            dtype=dt),
        },
        "final_norm": jnp.ones((cfg.d_model,), dt),
        "lm_head": embed_init(ks[6], cfg.vocab_size, cfg.d_model, dt),
    }


def encode(cfg: ModelConfig, params: Params, frames: jax.Array) -> jax.Array:
    """frames: (B, S_enc, D) precomputed embeddings -> (B, S_enc, D)."""
    B, S, D = frames.shape
    h = frames.astype(jnp.dtype(cfg.dtype))
    h = constrain(h, "batch", None, None)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None],
                                 (B, S))

    def body(h, pl):
        a = A.attention(pl["attn"], rmsnorm(h, pl["ln1"], cfg.norm_eps),
                        positions, n_heads=cfg.n_heads,
                        n_kv_heads=cfg.n_kv_heads, head_dim=cfg.hd,
                        rope_theta=cfg.rope_theta, causal=False)
        h = h + a
        h = h + mlp_apply(pl["mlp"], rmsnorm(h, pl["ln2"], cfg.norm_eps))
        return constrain(h, "batch", None, None), None

    h, _ = jax.lax.scan(body, h, params["enc_blocks"])
    return rmsnorm(h, params["enc_norm"], cfg.norm_eps)


def forward(cfg: ModelConfig, params: Params, tokens: jax.Array,
            frames: jax.Array, *, remat: bool = False,
            want_cache: bool = False):
    """Teacher-forced decoder over ``tokens`` given encoder ``frames``.
    Returns (logits, aux=0, caches|None)."""
    enc_out = encode(cfg, params, frames)
    B, S = tokens.shape
    h = embed_apply(params["embed"], tokens)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None],
                                 (B, S))

    def body(h, pl):
        hn = rmsnorm(h, pl["ln1"], cfg.norm_eps)
        a = A.attention(pl["attn"], hn, positions, n_heads=cfg.n_heads,
                        n_kv_heads=cfg.n_kv_heads, head_dim=cfg.hd,
                        rope_theta=cfg.rope_theta, causal=True)
        h = h + a
        kv = A.cross_kv(pl["xattn"], enc_out, n_kv_heads=cfg.n_kv_heads,
                        head_dim=cfg.hd)
        xa = A.cross_attention(pl["xattn"],
                               rmsnorm(h, pl["lnx"], cfg.norm_eps), kv,
                               n_heads=cfg.n_heads,
                               n_kv_heads=cfg.n_kv_heads, head_dim=cfg.hd)
        h = h + xa
        h = h + mlp_apply(pl["mlp"], rmsnorm(h, pl["ln2"], cfg.norm_eps))
        h = constrain(h, "batch", None, None)
        cache = None
        if want_cache:
            cache = {"cross_k": kv[0], "cross_v": kv[1]}
        return h, cache

    if remat:
        body = jax.checkpoint(body)
    h, caches = jax.lax.scan(body, h, params["dec_blocks"])
    h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
    logits = unembed_apply(params["lm_head"], h, transpose=True)
    return logits, jnp.zeros((), jnp.float32), caches


# ---------------------------------------------------------------------------
# Decode: self-attn KV cache + precomputed per-layer cross K/V
# ---------------------------------------------------------------------------
def decode_state_spec(cfg: ModelConfig, batch: int, window: int) -> Dict:
    dt = jnp.dtype(cfg.dtype)
    L = cfg.n_layers
    f = jax.ShapeDtypeStruct
    self_cache = jax.tree_util.tree_map(
        lambda s: f((L, *s.shape), s.dtype),
        A.cache_spec(batch, window, cfg.n_kv_heads, cfg.hd, dt))
    return {
        "layers": self_cache,
        "cross_k": f((L, batch, cfg.encoder_seq, cfg.n_kv_heads, cfg.hd),
                     dt),
        "cross_v": f((L, batch, cfg.encoder_seq, cfg.n_kv_heads, cfg.hd),
                     dt),
    }


def init_decode_state(cfg: ModelConfig, batch: int, window: int) -> Dict:
    spec = decode_state_spec(cfg, batch, window)
    return jax.tree_util.tree_map(
        lambda s: (jnp.full(s.shape, -1, s.dtype)
                   if s.dtype == jnp.int32 else jnp.zeros(s.shape, s.dtype)),
        spec)


def decode_step(cfg: ModelConfig, params: Params, state: Dict,
                token: jax.Array, t: jax.Array) -> Tuple[jax.Array, Dict]:
    h = embed_apply(params["embed"], token[:, None])

    xs = {"_p": params["dec_blocks"], "_state": state["layers"],
          "_ck": state["cross_k"], "_cv": state["cross_v"]}

    def body(h, x):
        pl = x["_p"]
        hn = rmsnorm(h, pl["ln1"], cfg.norm_eps)
        a, new_st = A.decode_attention(
            pl["attn"], hn, t, x["_state"], n_heads=cfg.n_heads,
            n_kv_heads=cfg.n_kv_heads, head_dim=cfg.hd,
            rope_theta=cfg.rope_theta)
        h = h + a
        xa = A.cross_attention(pl["xattn"],
                               rmsnorm(h, pl["lnx"], cfg.norm_eps),
                               (x["_ck"], x["_cv"]), n_heads=cfg.n_heads,
                               n_kv_heads=cfg.n_kv_heads, head_dim=cfg.hd)
        h = h + xa
        h = h + mlp_apply(pl["mlp"], rmsnorm(h, pl["ln2"], cfg.norm_eps))
        return h, new_st

    h, new_layer_states = jax.lax.scan(body, h, xs)
    h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
    logits = unembed_apply(params["lm_head"], h, transpose=True)[:, 0]
    return logits, {**state, "layers": new_layer_states}
