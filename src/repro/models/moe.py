"""Mixture-of-Experts feed-forward with capacity-based dense dispatch
(GShard/Switch style): top-k routing, per-expert capacity, one-hot dispatch/
combine einsums. Expert weights carry a leading expert dim that the sharding
rules map to the model axis (expert parallelism); the dispatch einsums are
what GSPMD turns into the all-to-alls.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..sharding import constrain
from .layers import dense_init


def moe_init(key, d_model: int, d_ff: int, n_experts: int, *,
             layers: Optional[int], dtype) -> Dict:
    kr, kg, ku, kd = jax.random.split(key, 4)

    def exp_w(k, din, dout):
        """(L?, E, din, dout) expert-stacked weights."""
        w = dense_init(k, din, dout * n_experts, layers=layers, dtype=dtype)
        if layers is None:
            return w.reshape(din, n_experts, dout).transpose(1, 0, 2)
        return w.reshape(layers, din, n_experts, dout).transpose(0, 2, 1, 3)

    return {
        "router": dense_init(kr, d_model, n_experts, layers=layers,
                             dtype=jnp.float32, scale=0.02),
        "gate": exp_w(kg, d_model, d_ff),     # (L?, E, D, F)
        "up": exp_w(ku, d_model, d_ff),       # (L?, E, D, F)
        "down": exp_w(kd, d_ff, d_model),     # (L?, E, F, D)
    }


def moe_apply(p: Dict, x: jax.Array, *, top_k: int,
              capacity_factor: float,
              group_size: int = 4096) -> Tuple[jax.Array, jax.Array]:
    """x: (B,S,D) -> (out, aux_loss).

    GROUPED dense dispatch (GShard/Switch): tokens are split into groups
    of ``group_size`` and each group dispatches within its own capacity.
    The one-hot dispatch matmul costs k·cf·Tg·D per token (vs k·cf·T·D
    ungrouped — O(T²·D) over the whole batch, which at a 1M-token global
    batch dwarfs the expert FLOPs ~250x; hillclimb A in EXPERIMENTS.md
    §Perf measures exactly this). ``group_size=0`` reproduces the
    ungrouped baseline."""
    B, S, D = x.shape
    E = p["router"].shape[-1]
    T = B * S
    Tg = T if not group_size else min(group_size, T)
    # pad T to a multiple of the group size
    G = (T + Tg - 1) // Tg
    pad = G * Tg - T
    xt = x.reshape(T, D)
    if pad:
        xt = jnp.concatenate([xt, jnp.zeros((pad, D), xt.dtype)])
    xg = xt.reshape(G, Tg, D)
    logits = (xg.astype(jnp.float32) @ p["router"])          # (G,Tg,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, top_k)        # (G,Tg,K)
    gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)

    C = int(max(1, capacity_factor * Tg * top_k / E))
    C = min(C, Tg)
    # position of each (token, k) within its expert's per-group queue
    onehot = jax.nn.one_hot(gate_idx, E, dtype=jnp.int32)    # (G,Tg,K,E)
    flat = onehot.reshape(G, Tg * top_k, E)
    pos_in_exp = (jnp.cumsum(flat, axis=1) - flat).reshape(
        G, Tg, top_k, E)
    pos = jnp.sum(pos_in_exp * onehot, axis=-1)              # (G,Tg,K)
    keep = pos < C
    oh = onehot.astype(jnp.float32) * keep[..., None]
    posoh = jax.nn.one_hot(pos, C, dtype=jnp.float32)
    disp = jnp.einsum("gtke,gtkc->gtec", oh, posoh)
    comb = jnp.einsum("gtke,gtkc,gtk->gtec", oh, posoh,
                      gate_vals.astype(jnp.float32))
    xin = jnp.einsum("gtec,gtd->gecd", disp.astype(x.dtype), xg)
    # groups shard over the dp axes (token-parallel), experts over the EP
    # axis: all-None constraints would force replication of the dispatch
    # tensors across the mesh (hillclimb A, iteration 2)
    xin = constrain(xin, "batch", "experts", None, None)     # (G,E,C,D)
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xin, p["gate"])) \
        * jnp.einsum("gecd,edf->gecf", xin, p["up"])
    h = constrain(h, "batch", "experts", None, "expert_ff")
    out = jnp.einsum("gecf,efd->gecd", h, p["down"])         # (G,E,C,D)
    y = jnp.einsum("gtec,gecd->gtd", comb.astype(x.dtype), out)
    y = y.reshape(G * Tg, D)[:T]
    # load-balancing auxiliary loss (Switch): E * sum(f_e * P_e)
    me = jnp.mean(probs, axis=(0, 1))
    ce = jnp.mean(jax.nn.one_hot(gate_idx[..., 0], E, dtype=jnp.float32),
                  axis=(0, 1))
    aux = E * jnp.sum(me * ce)
    return y.reshape(B, S, D), aux
