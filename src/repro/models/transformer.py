"""Decoder stacks for all decoder-only families (dense / moe / ssm /
hybrid / xlstm / vlm backbone).

Every stack is a single ``lax.scan`` over stacked per-layer parameters:
compact HLO (the 512-device dry-run compiles layer-count-independently),
natural remat boundary, natural FSDP all-gather granularity. Hybrid
(zamba2-style) applies one *shared* attention block every k layers via
``lax.cond`` inside the scan, with per-application KV caches carried as a
stacked buffer indexed by an application counter.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..sharding import constrain
from . import attention as A
from . import moe as MOE
from . import ssm as SSM
from . import xlstm as XL
from .layers import (apply_rope, embed_apply, embed_init, mlp_apply,
                     mlp_init, rmsnorm, unembed_apply)

Params = Dict


def _dt(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------
def init_params(cfg: ModelConfig, key) -> Params:
    dt = _dt(cfg)
    L = cfg.n_layers
    ks = jax.random.split(key, 8)
    p: Params = {"embed": embed_init(ks[0], cfg.vocab_size, cfg.d_model, dt),
                 "final_norm": jnp.ones((cfg.d_model,), dt)}
    if not cfg.tie_embeddings:
        p["lm_head"] = embed_init(ks[1], cfg.vocab_size, cfg.d_model, dt)

    blocks: Params = {}
    if cfg.family in ("dense", "vlm", "moe"):
        blocks["ln1"] = jnp.ones((L, cfg.d_model), dt)
        blocks["ln2"] = jnp.ones((L, cfg.d_model), dt)
        blocks["attn"] = A.attn_init(
            ks[2], cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd,
            layers=L, dtype=dt, qkv_bias=cfg.qkv_bias)
        if cfg.family == "moe":
            blocks["moe"] = MOE.moe_init(ks[3], cfg.d_model, cfg.d_ff,
                                         cfg.n_experts, layers=L, dtype=dt)
        else:
            blocks["mlp"] = mlp_init(ks[3], cfg.d_model, cfg.d_ff,
                                     layers=L, dtype=dt)
    elif cfg.family == "ssm" and cfg.slstm_every:
        # xLSTM as a GROUP scan: G groups of (k-1 mLSTM + 1 sLSTM).
        # No lax.cond: exact cost attribution in the HLO loop nest.
        k = cfg.slstm_every
        assert L % k == 0, f"xlstm: {L} layers not divisible by group {k}"
        G = L // k
        def regroup(tree, inner):
            return jax.tree_util.tree_map(
                lambda x: x.reshape(G, inner, *x.shape[1:]), tree)
        blocks["m_ln"] = jnp.ones((G, k - 1, cfg.d_model), dt)
        blocks["s_ln"] = jnp.ones((G, cfg.d_model), dt)
        blocks["mlstm"] = regroup(
            XL.mlstm_init(ks[2], cfg.d_model, n_heads=cfg.n_heads,
                          layers=G * (k - 1), dtype=dt), k - 1)
        blocks["slstm"] = XL.slstm_init(ks[3], cfg.d_model,
                                        n_heads=cfg.n_heads, layers=G,
                                        dtype=dt)
    elif cfg.family == "hybrid":
        # zamba2-style GROUP scan: G groups of (k Mamba2 + shared attn)
        k = cfg.hybrid_attn_every
        assert k and L % k == 0, \
            f"hybrid: {L} layers not divisible by period {k}"
        G = L // k
        ssm_p = SSM.ssm_init(
            ks[2], cfg.d_model, state=cfg.ssm_state, conv=cfg.ssm_conv,
            expand=cfg.ssm_expand, headdim=cfg.ssm_headdim, layers=L,
            dtype=dt)
        blocks["ssm"] = jax.tree_util.tree_map(
            lambda x: x.reshape(G, k, *x.shape[1:]), ssm_p)
        blocks["ln1"] = jnp.ones((G, k, cfg.d_model), dt)
    elif cfg.family == "ssm":
        blocks["ln1"] = jnp.ones((L, cfg.d_model), dt)
        blocks["ssm"] = SSM.ssm_init(
            ks[2], cfg.d_model, state=cfg.ssm_state, conv=cfg.ssm_conv,
            expand=cfg.ssm_expand, headdim=cfg.ssm_headdim, layers=L,
            dtype=dt)
    else:
        raise ValueError(cfg.family)
    p["blocks"] = blocks

    if cfg.family == "hybrid" and cfg.hybrid_attn_every:
        p["shared"] = {
            "ln1": jnp.ones((cfg.d_model,), dt),
            "ln2": jnp.ones((cfg.d_model,), dt),
            "attn": A.attn_init(ks[4], cfg.d_model, cfg.n_heads,
                                cfg.n_kv_heads, cfg.hd, layers=None,
                                dtype=dt, qkv_bias=cfg.qkv_bias),
            "mlp": mlp_init(ks[5], cfg.d_model, cfg.d_ff, layers=None,
                            dtype=dt),
        }
    return p


def n_shared_apps(cfg: ModelConfig) -> int:
    """Hybrid: shared attention applications = group count."""
    if cfg.family != "hybrid" or not cfg.hybrid_attn_every:
        return 0
    return cfg.n_layers // cfg.hybrid_attn_every


def _full_kv(cfg: ModelConfig, attn_p: Dict, positions: jax.Array,
             xn: jax.Array) -> Dict:
    """K/V (post-rope) of the full sequence: prefill -> decode handoff."""
    B, S, _ = xn.shape
    k = xn @ attn_p["wk"]
    v = xn @ attn_p["wv"]
    if "bk" in attn_p:
        k = k + attn_p["bk"]
        v = v + attn_p["bv"]
    k = k.reshape(B, S, cfg.n_kv_heads, cfg.hd)
    v = v.reshape(B, S, cfg.n_kv_heads, cfg.hd)
    k = apply_rope(k, positions, cfg.rope_theta)
    return {"k": k, "v": v}


# ---------------------------------------------------------------------------
# Training / prefill forward
# ---------------------------------------------------------------------------
def _stack_body(cfg: ModelConfig, shared: Optional[Dict],
                positions: jax.Array, want_cache: bool):
    """The per-layer scan body shared by the full forward and the
    pipeline stage forward (``forward_stage``): one stacked-blocks slice
    element -> new carry (+ optional KV cache)."""

    def ssm_block(pl, h):
        hn = rmsnorm(h, pl["ln1"], cfg.norm_eps)
        y = SSM.ssm_apply(pl["ssm"], hn, state=cfg.ssm_state,
                          conv=cfg.ssm_conv, expand=cfg.ssm_expand,
                          headdim=cfg.ssm_headdim)
        return h + y

    def shared_block(h):
        hn1 = rmsnorm(h, shared["ln1"], cfg.norm_eps)
        a = A.attention(shared["attn"], hn1, positions,
                        n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
                        head_dim=cfg.hd, rope_theta=cfg.rope_theta,
                        causal=True, sliding_window=cfg.sliding_window)
        h = h + a
        m = mlp_apply(shared["mlp"],
                      rmsnorm(h, shared["ln2"], cfg.norm_eps))
        return h + m, hn1

    def body(carry, pl):
        h = carry["h"]
        aux = carry["aux"]
        cache = None
        if cfg.family in ("dense", "vlm", "moe"):
            hn = rmsnorm(h, pl["ln1"], cfg.norm_eps)
            a = A.attention(pl["attn"], hn, positions,
                            n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
                            head_dim=cfg.hd, rope_theta=cfg.rope_theta,
                            causal=True, sliding_window=cfg.sliding_window)
            h = h + a
            h = constrain(h, "batch", None, None)
            hn2 = rmsnorm(h, pl["ln2"], cfg.norm_eps)
            if cfg.family == "moe":
                y, a_loss = MOE.moe_apply(
                    pl["moe"], hn2, top_k=cfg.top_k,
                    capacity_factor=cfg.capacity_factor,
                    group_size=cfg.moe_group_size)
                aux = aux + a_loss
            else:
                y = mlp_apply(pl["mlp"], hn2)
            h = h + y
            h = constrain(h, "batch", None, None)
            if want_cache:
                cache = _full_kv(cfg, pl["attn"], positions, hn)
        elif cfg.slstm_every:                               # xLSTM group
            def m_body(hh, pm):
                hn = rmsnorm(hh, pm["m_ln"], cfg.norm_eps)
                return hh + XL.mlstm_apply(pm["mlstm"], hn,
                                           n_heads=cfg.n_heads), None
            h, _ = jax.lax.scan(m_body, h, {"m_ln": pl["m_ln"],
                                            "mlstm": pl["mlstm"]})
            hn = rmsnorm(h, pl["s_ln"], cfg.norm_eps)
            h = h + XL.slstm_apply(pl["slstm"], hn, n_heads=cfg.n_heads)
        elif cfg.family == "hybrid":                        # zamba2 group
            def s_body(hh, pm):
                return ssm_block(pm, hh), None
            h, _ = jax.lax.scan(s_body, h, {"ln1": pl["ln1"],
                                            "ssm": pl["ssm"]})
            h, hn1 = shared_block(h)
            if want_cache:
                cache = _full_kv(cfg, shared["attn"], positions, hn1)
        else:                                               # plain ssm
            h = ssm_block(pl, h)
        return {"h": h, "aux": aux}, cache

    return body


def forward(cfg: ModelConfig, params: Params, tokens: jax.Array,
            *, patches: Optional[jax.Array] = None, remat: bool = False,
            want_cache: bool = False):
    """Full-sequence forward. tokens: (B, S_txt). For vlm, ``patches``
    (B, n_vis, D) are prepended (stub frontend per assignment). Returns
    (logits, aux_loss, caches|None)."""
    dt = _dt(cfg)
    h = embed_apply(params["embed"], tokens)
    if cfg.family == "vlm":
        assert patches is not None
        h = jnp.concatenate([patches.astype(dt), h], axis=1)
    B, S, D = h.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None],
                                 (B, S))
    h = constrain(h, "batch", None, None)
    body = _stack_body(cfg, params.get("shared"), positions, want_cache)

    if remat:
        body = jax.checkpoint(body)

    carry0 = {"h": h, "aux": jnp.zeros((), jnp.float32)}
    carry, caches = jax.lax.scan(body, carry0, params["blocks"])
    hout = rmsnorm(carry["h"], params["final_norm"], cfg.norm_eps)
    logits = unembed_apply(
        params["embed"] if cfg.tie_embeddings else params["lm_head"],
        hout, transpose=True)
    out_caches = None
    if want_cache:
        out_caches = {"layers": caches}
    return logits, carry["aux"], out_caches


# ---------------------------------------------------------------------------
# Pipeline-stage decomposition (pipeline_exec): embed | block slice | head
# ---------------------------------------------------------------------------
def embed_tokens(cfg: ModelConfig, params: Params,
                 tokens: jax.Array) -> jax.Array:
    """The input-side pipeline stage: tokens (B, S) -> h (B, S, D)."""
    assert cfg.family != "vlm" and not cfg.is_encdec, cfg.family
    return embed_apply(params["embed"], tokens)


def forward_stage(cfg: ModelConfig, blocks: Params, h: jax.Array, *,
                  shared: Optional[Params] = None,
                  remat: bool = False) -> Tuple[jax.Array, jax.Array]:
    """Scan a contiguous SLICE of the stacked blocks over an incoming
    activation — one pipeline stage's compute. Identical math to the
    same slice inside ``forward``'s scan (the stage map partitions the
    scan axis, and the body is literally shared), so chaining the S
    stage slices reproduces the full forward exactly. Returns
    (h, aux_slice); aux contributions are per-slice and summed across
    stages by the caller (linearity of the load-balancing loss)."""
    B, S, D = h.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None],
                                 (B, S))
    body = _stack_body(cfg, shared, positions, want_cache=False)
    if remat:
        body = jax.checkpoint(body)
    carry0 = {"h": h, "aux": jnp.zeros((), jnp.float32)}
    carry, _ = jax.lax.scan(body, carry0, blocks)
    return carry["h"], carry["aux"]


def head_logits(cfg: ModelConfig, params: Params,
                h: jax.Array) -> jax.Array:
    """The output-side pipeline stage: final norm + (tied) unembedding."""
    hout = rmsnorm(h, params["final_norm"], cfg.norm_eps)
    return unembed_apply(
        params["embed"] if cfg.tie_embeddings else params["lm_head"],
        hout, transpose=True)


# ---------------------------------------------------------------------------
# Decode (one token; stacked caches/states scanned with the layers)
# ---------------------------------------------------------------------------
def decode_state_spec(cfg: ModelConfig, batch: int, window: int) -> Dict:
    """ShapeDtypeStruct tree of the decode state."""
    dt = _dt(cfg)
    L = cfg.n_layers
    f = jax.ShapeDtypeStruct

    def stack(spec, n=L):
        return jax.tree_util.tree_map(
            lambda s: f((n, *s.shape), s.dtype), spec)

    if cfg.family in ("dense", "vlm", "moe"):
        W = min(window, cfg.sliding_window) if cfg.sliding_window else window
        return {"layers": stack(A.cache_spec(batch, W, cfg.n_kv_heads,
                                             cfg.hd, dt))}
    if cfg.slstm_every:             # xLSTM groups: (G, k-1, ...) + (G, ...)
        k = cfg.slstm_every
        G = L // k
        def stack2(spec):
            return jax.tree_util.tree_map(
                lambda s: f((G, k - 1, *s.shape), s.dtype), spec)
        return {"layers": {
            "mlstm": stack2(XL.mlstm_state_spec(batch, cfg.d_model,
                                                n_heads=cfg.n_heads,
                                                dtype=dt)),
            "slstm": stack(XL.slstm_state_spec(batch, cfg.d_model,
                                               n_heads=cfg.n_heads), n=G)}}
    if cfg.family == "ssm":
        return {"layers": stack(SSM.ssm_state_spec(
            batch, cfg.d_model, state=cfg.ssm_state, conv=cfg.ssm_conv,
            expand=cfg.ssm_expand, headdim=cfg.ssm_headdim, dtype=dt))}
    if cfg.family == "hybrid":
        W = min(window, cfg.sliding_window) if cfg.sliding_window else window
        k = cfg.hybrid_attn_every
        G = L // k
        def stack2(spec):
            return jax.tree_util.tree_map(
                lambda s: f((G, k, *s.shape), s.dtype), spec)
        return {"layers": {
            "ssm": stack2(SSM.ssm_state_spec(
                batch, cfg.d_model, state=cfg.ssm_state, conv=cfg.ssm_conv,
                expand=cfg.ssm_expand, headdim=cfg.ssm_headdim, dtype=dt)),
            "shared": stack(A.cache_spec(batch, W, cfg.n_kv_heads,
                                         cfg.hd, dt), n=G)}}
    raise ValueError(cfg.family)


def init_decode_state(cfg: ModelConfig, batch: int, window: int) -> Dict:
    spec = decode_state_spec(cfg, batch, window)
    return jax.tree_util.tree_map(
        lambda s: (jnp.full(s.shape, -1, s.dtype)
                   if s.dtype == jnp.int32 else jnp.zeros(s.shape, s.dtype)),
        spec)


def decode_step(cfg: ModelConfig, params: Params, state: Dict,
                token: jax.Array, t: jax.Array
                ) -> Tuple[jax.Array, Dict]:
    """One new token. token: (B,) int32; t: (B,) absolute positions."""
    h = embed_apply(params["embed"], token[:, None])           # (B,1,D)
    shared = params.get("shared")

    def ssm_decode(pl, h, st):
        hn = rmsnorm(h, pl["ln1"], cfg.norm_eps)
        y, ns = SSM.ssm_decode_step(
            pl["ssm"], hn, st, state=cfg.ssm_state, conv=cfg.ssm_conv,
            expand=cfg.ssm_expand, headdim=cfg.ssm_headdim)
        return h + y, ns

    def body(h, x):
        pl = x["_p"]
        st = x["_state"]
        if cfg.family in ("dense", "vlm", "moe"):
            hn = rmsnorm(h, pl["ln1"], cfg.norm_eps)
            a, new_st = A.decode_attention(
                pl["attn"], hn, t, st, n_heads=cfg.n_heads,
                n_kv_heads=cfg.n_kv_heads, head_dim=cfg.hd,
                rope_theta=cfg.rope_theta,
                sliding_window=cfg.sliding_window)
            h = h + a
            hn2 = rmsnorm(h, pl["ln2"], cfg.norm_eps)
            if cfg.family == "moe":
                y, _ = MOE.moe_apply(pl["moe"], hn2, top_k=cfg.top_k,
                                     capacity_factor=cfg.capacity_factor,
                                     group_size=cfg.moe_group_size)
            else:
                y = mlp_apply(pl["mlp"], hn2)
            return h + y, new_st

        if cfg.slstm_every:                                 # xLSTM group
            def m_body(hh, xm):
                hn = rmsnorm(hh, xm["m_ln"], cfg.norm_eps)
                y, ns = XL.mlstm_decode_step(xm["mlstm"], hn, xm["st"],
                                             n_heads=cfg.n_heads)
                return hh + y, ns
            h, new_m = jax.lax.scan(
                m_body, h, {"m_ln": pl["m_ln"], "mlstm": pl["mlstm"],
                            "st": st["mlstm"]})
            hn = rmsnorm(h, pl["s_ln"], cfg.norm_eps)
            y, new_s = XL.slstm_decode_step(pl["slstm"], hn, st["slstm"],
                                            n_heads=cfg.n_heads)
            return h + y, {"mlstm": new_m, "slstm": new_s}

        if cfg.family == "hybrid":                          # zamba2 group
            def s_body(hh, xm):
                return ssm_decode({"ln1": xm["ln1"], "ssm": xm["ssm"]},
                                  hh, xm["st"])
            h, new_ssm = jax.lax.scan(
                s_body, h, {"ln1": pl["ln1"], "ssm": pl["ssm"],
                            "st": st["ssm"]})
            a_out, new_kv = A.decode_attention(
                shared["attn"], rmsnorm(h, shared["ln1"], cfg.norm_eps),
                t, st["shared"], n_heads=cfg.n_heads,
                n_kv_heads=cfg.n_kv_heads, head_dim=cfg.hd,
                rope_theta=cfg.rope_theta,
                sliding_window=cfg.sliding_window)
            h = h + a_out
            m = mlp_apply(shared["mlp"],
                          rmsnorm(h, shared["ln2"], cfg.norm_eps))
            return h + m, {"ssm": new_ssm, "shared": new_kv}

        return ssm_decode(pl, h, st)                        # plain ssm

    xs = {"_p": params["blocks"], "_state": state["layers"]}
    h, new_layer_states = jax.lax.scan(body, h, xs)
    hout = rmsnorm(h, params["final_norm"], cfg.norm_eps)
    logits = unembed_apply(
        params["embed"] if cfg.tie_embeddings else params["lm_head"],
        hout, transpose=True)[:, 0]
    return logits, {"layers": new_layer_states}
