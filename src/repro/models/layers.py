"""Shared model primitives: norms, rotary embeddings, SwiGLU MLP, linear
init. Parameters are plain nested dicts of jnp arrays; per-layer parameters
are created *stacked* along a leading layer dim so the decoder stack is a
single ``lax.scan`` (compact HLO, natural remat/FSDP granularity)."""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..sharding import constrain


def dense_init(key, in_dim: int, out_dim: int, *, layers: Optional[int],
               dtype, scale: Optional[float] = None) -> jax.Array:
    """(L?, in, out) truncated-normal fan-in init."""
    scale = scale if scale is not None else 1.0 / math.sqrt(in_dim)
    shape = (in_dim, out_dim) if layers is None else (layers, in_dim, out_dim)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * scale).astype(dtype)


def zeros_init(shape, dtype) -> jax.Array:
    return jnp.zeros(shape, dtype)


def ones_init(shape, dtype) -> jax.Array:
    return jnp.ones(shape, dtype)


def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps)
    return (out * w.astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2,
                                       dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array,
               theta: float) -> jax.Array:
    """x: (B, S, H, hd); positions: (B, S) int32."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B,S,hd/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin,
                           x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------
def mlp_init(key, d_model: int, d_ff: int, *, layers: Optional[int],
             dtype) -> Dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "gate": dense_init(k1, d_model, d_ff, layers=layers, dtype=dtype),
        "up": dense_init(k2, d_model, d_ff, layers=layers, dtype=dtype),
        "down": dense_init(k3, d_ff, d_model, layers=layers, dtype=dtype),
    }


def mlp_apply(p: Dict, x: jax.Array) -> jax.Array:
    h = jax.nn.silu(x @ p["gate"]) * (x @ p["up"])
    h = constrain(h, "batch", None, "ff")
    return h @ p["down"]


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------
def embed_init(key, vocab: int, d_model: int, dtype) -> jax.Array:
    return (jax.random.normal(key, (vocab, d_model), jnp.float32)
            * 0.02).astype(dtype)


def embed_apply(emb: jax.Array, tokens: jax.Array) -> jax.Array:
    out = jnp.take(emb, tokens, axis=0)
    return constrain(out, "batch", None, None)


def unembed_apply(emb_or_head: jax.Array, x: jax.Array,
                  transpose: bool) -> jax.Array:
    w = emb_or_head.T if transpose else emb_or_head
    logits = x @ w
    return constrain(logits, "batch", None, "vocab")
