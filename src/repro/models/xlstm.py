"""xLSTM blocks: mLSTM (matrix-memory, chunkwise-parallel) and sLSTM
(scalar-memory, sequential) — arXiv:2405.04517, TPU-adapted.

mLSTM is linear-attention-like: C_t = f_t C_{t-1} + i_t v_t k_t^T with
exponential gating stabilized in log space (m_t running max). The chunkwise
form (intra-chunk dense matmuls + inter-chunk carry) matches the Mamba2 SSD
structure and is MXU-friendly; the GPU reference's warp-parallel scan does
not transfer (DESIGN.md §7).

sLSTM has a true sequential recurrence (hidden-to-hidden); it is evaluated
with lax.scan over time — the paper's design point (used in 1-in-k layers).
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import dense_init


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------
def mlstm_init(key, d_model: int, *, n_heads: int, layers: Optional[int],
               dtype, proj_factor: float = 2.0) -> Dict:
    d_in = int(proj_factor * d_model)
    hd = d_in // n_heads
    ks = jax.random.split(key, 7)
    lead = () if layers is None else (layers,)
    return {
        "up": dense_init(ks[0], d_model, 2 * d_in, layers=layers,
                         dtype=dtype),
        "wq": dense_init(ks[1], d_in, d_in, layers=layers, dtype=dtype),
        "wk": dense_init(ks[2], d_in, d_in, layers=layers, dtype=dtype),
        "wv": dense_init(ks[3], d_in, d_in, layers=layers, dtype=dtype),
        "wi": dense_init(ks[4], d_in, n_heads, layers=layers,
                         dtype=jnp.float32, scale=0.02),
        "wf": dense_init(ks[5], d_in, n_heads, layers=layers,
                         dtype=jnp.float32, scale=0.02),
        "fb": jnp.full((*lead, n_heads), 3.0, jnp.float32),
        "norm_w": jnp.ones((*lead, d_in), dtype),
        "down": dense_init(ks[6], d_in, d_model, layers=layers, dtype=dtype),
    }


def mlstm_apply(p: Dict, u: jax.Array, *, n_heads: int,
                chunk: int = 256) -> jax.Array:
    """Chunkwise-parallel mLSTM. u: (B,S,D)."""
    B, S, D = u.shape
    d_in = p["wq"].shape[-1]
    hd = d_in // n_heads
    h, z = jnp.split(u @ p["up"], 2, axis=-1)                  # (B,S,d_in)
    q = (h @ p["wq"]).reshape(B, S, n_heads, hd)
    k = (h @ p["wk"]).reshape(B, S, n_heads, hd) / math.sqrt(hd)
    v = (h @ p["wv"]).reshape(B, S, n_heads, hd)
    logi = (h.astype(jnp.float32) @ p["wi"])                   # (B,S,nh)
    logf = jax.nn.log_sigmoid(h.astype(jnp.float32) @ p["wf"] + p["fb"])

    # chunkwise-parallel, ONE chunk at a time (sequential scan over
    # chunks = the Pallas kernel's sequential grid dim); (c x c) tensors
    # exist for a single chunk only
    nchunk = max(1, math.ceil(S / chunk))
    pad = nchunk * chunk - S
    def padc(t):
        return jnp.pad(t, [(0, 0), (0, pad)] + [(0, 0)] * (t.ndim - 2))
    qc = padc(q).reshape(B, nchunk, chunk, n_heads, hd).transpose(
        1, 0, 2, 3, 4)
    kc = padc(k).reshape(B, nchunk, chunk, n_heads, hd).transpose(
        1, 0, 2, 3, 4)
    vc = padc(v).reshape(B, nchunk, chunk, n_heads, hd).transpose(
        1, 0, 2, 3, 4)
    ic = padc(logi).reshape(B, nchunk, chunk, n_heads).transpose(1, 0, 2, 3)
    fc = padc(logf).reshape(B, nchunk, chunk, n_heads).transpose(1, 0, 2, 3)

    idx = jnp.arange(chunk)
    causal = (idx[:, None] >= idx[None, :])[None, :, :, None]

    def body(carry, xs):
        C_prev, n_prev = carry                          # (B,nh,k,p),(B,nh,k)
        q_i, k_i, v_i, i_i, f_i = xs
        q_i = q_i.astype(jnp.float32)
        k_i = k_i.astype(jnp.float32)
        v_i = v_i.astype(jnp.float32)
        lf = jnp.cumsum(f_i, axis=1)                    # (B,c,nh)
        seg = lf[:, :, None, :] - lf[:, None, :, :]     # (B,c,c,nh)
        logD = jnp.where(causal, seg + i_i[:, None, :, :], -1e30)
        m_intra = jnp.max(logD, axis=2)                 # (B,c,nh)
        m = jnp.maximum(m_intra, lf)                    # stabilizer
        Dmat = jnp.exp(logD - m[:, :, None, :])
        QK = jnp.einsum("bthk,bshk->btsh", q_i, k_i)    # (B,t,s,nh)
        W = QK * Dmat                                   # (B,t,s,nh)
        y_intra = jnp.einsum("btsh,bshp->bthp", W, v_i)
        den_intra = jnp.sum(W, axis=2)                  # (B,t,nh)
        w_init = jnp.exp(lf - m)                        # (B,t,nh)
        y_inter = jnp.einsum("bthk,bhkp->bthp",
                             q_i * w_init[..., None], C_prev)
        den_inter = jnp.einsum("bthk,bhk->bth",
                               q_i * w_init[..., None], n_prev)
        num = y_intra + y_inter
        den = den_intra + den_inter
        den = jnp.maximum(jnp.abs(den), jnp.exp(-m))[..., None]
        y_c = num / den                                 # (B,c,nh,hd)
        # carry update
        decay_to_end = jnp.exp(lf[:, -1:, :] - lf + i_i)
        C_new = (jnp.exp(lf[:, -1, :])[..., None, None] * C_prev
                 + jnp.einsum("bch,bchk,bchp->bhkp", decay_to_end, k_i,
                              v_i))
        n_new = (jnp.exp(lf[:, -1, :])[..., None] * n_prev
                 + jnp.einsum("bch,bchk->bhk", decay_to_end, k_i))
        return (C_new, n_new), y_c

    C0 = jnp.zeros((B, n_heads, hd, hd), jnp.float32)
    n0 = jnp.zeros((B, n_heads, hd), jnp.float32)
    _, yc = jax.lax.scan(jax.checkpoint(body), (C0, n0),
                         (qc, kc, vc, ic, fc))
    y = yc.transpose(1, 0, 2, 3, 4).reshape(
        B, nchunk * chunk, n_heads, hd)[:, :S]
    y = y.reshape(B, S, d_in)
    var = jnp.mean(y * y, axis=-1, keepdims=True)
    y = y * jax.lax.rsqrt(var + 1e-5) * p["norm_w"].astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(u.dtype)
    return y @ p["down"]


def mlstm_state_spec(batch: int, d_model: int, *, n_heads: int, dtype,
                     proj_factor: float = 2.0) -> Dict:
    d_in = int(proj_factor * d_model)
    hd = d_in // n_heads
    f = jax.ShapeDtypeStruct
    return {"C": f((batch, n_heads, hd, hd), jnp.float32),
            "n": f((batch, n_heads, hd), jnp.float32),
            "m": f((batch, n_heads), jnp.float32)}


def mlstm_init_state(batch: int, d_model: int, *, n_heads: int, dtype,
                     proj_factor: float = 2.0) -> Dict:
    d_in = int(proj_factor * d_model)
    hd = d_in // n_heads
    return {"C": jnp.zeros((batch, n_heads, hd, hd), jnp.float32),
            "n": jnp.zeros((batch, n_heads, hd), jnp.float32),
            "m": jnp.full((batch, n_heads), -1e30, jnp.float32)}


def mlstm_decode_step(p: Dict, u: jax.Array, st: Dict, *,
                      n_heads: int) -> Tuple[jax.Array, Dict]:
    """One-token recurrent step (O(1) state). u: (B,1,D)."""
    B, S, D = u.shape
    d_in = p["wq"].shape[-1]
    hd = d_in // n_heads
    h, z = jnp.split(u @ p["up"], 2, axis=-1)
    q = (h @ p["wq"]).reshape(B, n_heads, hd).astype(jnp.float32)
    k = ((h @ p["wk"]).reshape(B, n_heads, hd)
         / math.sqrt(hd)).astype(jnp.float32)
    v = (h @ p["wv"]).reshape(B, n_heads, hd).astype(jnp.float32)
    logi = (h.astype(jnp.float32) @ p["wi"])[:, 0]             # (B,nh)
    logf = jax.nn.log_sigmoid(
        h.astype(jnp.float32) @ p["wf"] + p["fb"])[:, 0]
    m_new = jnp.maximum(logf + st["m"], logi)
    C = (jnp.exp(logf + st["m"] - m_new)[..., None, None] * st["C"]
         + jnp.exp(logi - m_new)[..., None, None]
         * jnp.einsum("bhk,bhp->bhkp", k, v))
    n = (jnp.exp(logf + st["m"] - m_new)[..., None] * st["n"]
         + jnp.exp(logi - m_new)[..., None] * k)
    num = jnp.einsum("bhk,bhkp->bhp", q, C)
    den = jnp.abs(jnp.einsum("bhk,bhk->bh", q, n))
    den = jnp.maximum(den, jnp.exp(-m_new))[..., None]
    y = (num / den).reshape(B, 1, d_in)
    var = jnp.mean(y * y, axis=-1, keepdims=True)
    y = y * jax.lax.rsqrt(var + 1e-5) * p["norm_w"].astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(u.dtype)
    return y @ p["down"], {"C": C, "n": n, "m": m_new}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------
def slstm_init(key, d_model: int, *, n_heads: int, layers: Optional[int],
               dtype) -> Dict:
    hd = d_model // n_heads
    ks = jax.random.split(key, 3)
    lead = () if layers is None else (layers,)
    # 4 gates (i, f, z, o), input + recurrent (block-diagonal per head)
    return {
        "wx": dense_init(ks[0], d_model, 4 * d_model, layers=layers,
                         dtype=dtype),
        "wr": (jax.random.normal(ks[1], (*lead, n_heads, hd, 4 * hd),
                                 jnp.float32)
               / math.sqrt(hd)).astype(dtype),
        "b": jnp.zeros((*lead, 4 * d_model), jnp.float32),
        "norm_w": jnp.ones((*lead, d_model), dtype),
        "down": dense_init(ks[2], d_model, d_model, layers=layers,
                           dtype=dtype),
    }


def slstm_apply(p: Dict, u: jax.Array, *, n_heads: int) -> jax.Array:
    """Sequential sLSTM over time (lax.scan). u: (B,S,D)."""
    B, S, D = u.shape
    hd = D // n_heads
    gx = (u @ p["wx"] + p["b"].astype(u.dtype))                # (B,S,4D)
    gx = gx.reshape(B, S, n_heads, 4 * hd).astype(jnp.float32)

    def step(carry, g_t):
        c, n, m, h = carry
        rec = jnp.einsum("bhd,hdg->bhg", h, p["wr"].astype(jnp.float32))
        g = g_t + rec                                          # (B,nh,4hd)
        gi, gf, gz, go = jnp.split(g, 4, axis=-1)
        logf = jax.nn.log_sigmoid(gf)
        m_new = jnp.maximum(logf + m, gi)
        i = jnp.exp(gi - m_new)
        f = jnp.exp(logf + m - m_new)
        c_new = f * c + i * jnp.tanh(gz)
        n_new = f * n + i
        h_new = jax.nn.sigmoid(go) * c_new / jnp.maximum(n_new, 1e-6)
        return (c_new, n_new, m_new, h_new), h_new

    zeros = jnp.zeros((B, n_heads, hd), jnp.float32)
    m0 = jnp.full((B, n_heads, hd), -1e30, jnp.float32)
    _, hs = jax.lax.scan(step, (zeros, zeros, m0, zeros),
                         gx.transpose(1, 0, 2, 3))
    y = hs.transpose(1, 0, 2, 3).reshape(B, S, D)
    var = jnp.mean(y * y, axis=-1, keepdims=True)
    y = y * jax.lax.rsqrt(var + 1e-5) * p["norm_w"].astype(jnp.float32)
    return y.astype(u.dtype) @ p["down"]


def slstm_state_spec(batch: int, d_model: int, *, n_heads: int) -> Dict:
    hd = d_model // n_heads
    f = jax.ShapeDtypeStruct
    return {"c": f((batch, n_heads, hd), jnp.float32),
            "n": f((batch, n_heads, hd), jnp.float32),
            "m": f((batch, n_heads, hd), jnp.float32),
            "h": f((batch, n_heads, hd), jnp.float32)}


def slstm_init_state(batch: int, d_model: int, *, n_heads: int) -> Dict:
    hd = d_model // n_heads
    z = jnp.zeros((batch, n_heads, hd), jnp.float32)
    return {"c": z, "n": z, "m": jnp.full_like(z, -1e30), "h": z}


def slstm_decode_step(p: Dict, u: jax.Array, st: Dict, *,
                      n_heads: int) -> Tuple[jax.Array, Dict]:
    B, S, D = u.shape
    hd = D // n_heads
    g_t = ((u @ p["wx"] + p["b"].astype(u.dtype))
           .reshape(B, n_heads, 4 * hd).astype(jnp.float32))
    rec = jnp.einsum("bhd,hdg->bhg", st["h"],
                     p["wr"].astype(jnp.float32))
    g = g_t + rec
    gi, gf, gz, go = jnp.split(g, 4, axis=-1)
    logf = jax.nn.log_sigmoid(gf)
    m_new = jnp.maximum(logf + st["m"], gi)
    i = jnp.exp(gi - m_new)
    f = jnp.exp(logf + st["m"] - m_new)
    c_new = f * st["c"] + i * jnp.tanh(gz)
    n_new = f * st["n"] + i
    h_new = jax.nn.sigmoid(go) * c_new / jnp.maximum(n_new, 1e-6)
    y = h_new.reshape(B, 1, D)
    var = jnp.mean(y * y, axis=-1, keepdims=True)
    y = y * jax.lax.rsqrt(var + 1e-5) * p["norm_w"].astype(jnp.float32)
    return (y.astype(u.dtype) @ p["down"],
            {"c": c_new, "n": n_new, "m": m_new, "h": h_new})
