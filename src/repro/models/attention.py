"""GQA attention: training/prefill (full or sliding-window causal), decode
with a KV cache (full or ring-buffer), and cross-attention (enc-dec).

The KV cache is a dict {"k","v","pos"}: k/v (B, W, kvH, hd) and pos (B, W)
holding the *absolute* position stored in each slot (-1 = empty). A full
cache has W = max_seq; a sliding-window cache is a ring buffer with
W = window — slot t % W — which is what makes 500k-token decode O(W) for
SWA models (Mixtral). RoPE is applied to k at write time, q at read time.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..sharding import constrain
from .layers import apply_rope, dense_init

NEG_INF = -1e30


def attn_init(key, d_model: int, n_heads: int, n_kv_heads: int, head_dim: int,
              *, layers: Optional[int], dtype, qkv_bias: bool = False) -> Dict:
    kq, kk, kv, ko = jax.random.split(key, 4)
    p = {
        "wq": dense_init(kq, d_model, n_heads * head_dim, layers=layers,
                         dtype=dtype),
        "wk": dense_init(kk, d_model, n_kv_heads * head_dim, layers=layers,
                         dtype=dtype),
        "wv": dense_init(kv, d_model, n_kv_heads * head_dim, layers=layers,
                         dtype=dtype),
        "wo": dense_init(ko, n_heads * head_dim, d_model, layers=layers,
                         dtype=dtype),
    }
    if qkv_bias:
        shape = lambda d: (d,) if layers is None else (layers, d)
        p["bq"] = jnp.zeros(shape(n_heads * head_dim), dtype)
        p["bk"] = jnp.zeros(shape(n_kv_heads * head_dim), dtype)
        p["bv"] = jnp.zeros(shape(n_kv_heads * head_dim), dtype)
    return p


def _project_qkv(p: Dict, x: jax.Array, n_heads: int, n_kv_heads: int,
                 head_dim: int):
    B, S, _ = x.shape
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if "bq" in p:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    q = q.reshape(B, S, n_heads, head_dim)
    k = k.reshape(B, S, n_kv_heads, head_dim)
    v = v.reshape(B, S, n_kv_heads, head_dim)
    q = constrain(q, "batch", None, "heads", None)
    k = constrain(k, "batch", None, "kv_heads", None)
    v = constrain(v, "batch", None, "kv_heads", None)
    return q, k, v


def _gqa_scores(q: jax.Array, k: jax.Array) -> jax.Array:
    """q: (B,Sq,H,hd), k: (B,Sk,Kh,hd) -> (B,H,Sq,Sk) with GQA grouping."""
    B, Sq, H, hd = q.shape
    Kh = k.shape[2]
    g = H // Kh
    qg = q.reshape(B, Sq, Kh, g, hd)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qg, k,
                   preferred_element_type=jnp.float32)
    return s.reshape(B, Kh * g, Sq, k.shape[1]) / math.sqrt(hd)


def _gqa_out(w: jax.Array, v: jax.Array) -> jax.Array:
    """w: (B,H,Sq,Sk), v: (B,Sk,Kh,hd) -> (B,Sq,H,hd)."""
    B, H, Sq, Sk = w.shape
    Kh = v.shape[2]
    g = H // Kh
    wg = w.reshape(B, Kh, g, Sq, Sk)
    o = jnp.einsum("bkgqs,bskh->bqkgh", wg.astype(v.dtype), v)
    return o.reshape(B, Sq, H, v.shape[3])


def _flash_mha(q: jax.Array, k: jax.Array, v: jax.Array,
               qpos: jax.Array, kpos: jax.Array, *, causal: bool,
               sliding_window: Optional[int], chunk: int = 512
               ) -> jax.Array:
    """Blockwise attention with online softmax (flash-style): the (Sq, Sk)
    score matrix is never materialized — only (Sq, chunk) tiles inside a
    lax.scan over KV chunks. This is the memory behavior the Pallas kernel
    (kernels/flash_attention.py) has on TPU; the pure-jnp layer mirrors it
    so compile-time memory analysis is faithful.

    q: (B,Sq,H,hd); k/v: (B,Sk,Kh,hd); qpos: (B,Sq); kpos: (B,Sk)
    (kpos < 0 marks padding)."""
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    c = min(chunk, Sk)
    nchunk = (Sk + c - 1) // c
    pad = nchunk * c - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kpos = jnp.pad(kpos, ((0, 0), (0, pad)), constant_values=-1)
    kc = k.reshape(B, nchunk, c, *k.shape[2:]).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, nchunk, c, *v.shape[2:]).transpose(1, 0, 2, 3, 4)
    pc = kpos.reshape(B, nchunk, c).transpose(1, 0, 2)

    def body(carry, xs):
        acc, m, l = carry
        k_i, v_i, p_i = xs
        s = _gqa_scores(q, k_i)                       # (B,H,Sq,c) f32
        kj = p_i[:, None, None, :]
        qi = qpos[:, None, :, None]
        mask = kj >= 0
        if causal:
            mask = mask & (kj <= qi)
        if sliding_window is not None:
            mask = mask & (qi - kj < sliding_window)
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None] + _gqa_out(p, v_i).transpose(
            0, 2, 1, 3)                               # (B,H,Sq,hd)
        return (acc, m_new, l_new), None

    acc0 = jnp.zeros((B, H, Sq, hd), jnp.float32)
    m0 = jnp.full((B, H, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, H, Sq), jnp.float32)
    # checkpoint the chunk body: backward recomputes the (Sq, chunk) score
    # tile instead of saving one per chunk (which would re-materialize the
    # full S^2 matrix across the scan — the thing flash attention avoids)
    (acc, m, l), _ = jax.lax.scan(jax.checkpoint(body), (acc0, m0, l0),
                                  (kc, vc, pc))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 2, 1, 3).astype(v.dtype)  # (B,Sq,H,hd)


def attention(p: Dict, x: jax.Array, positions: jax.Array, *,
              n_heads: int, n_kv_heads: int, head_dim: int,
              rope_theta: float, causal: bool = True,
              sliding_window: Optional[int] = None,
              chunk: int = 512) -> jax.Array:
    """Training / prefill self-attention. x: (B,S,D)."""
    B, S, D = x.shape
    q, k, v = _project_qkv(p, x, n_heads, n_kv_heads, head_dim)
    q = apply_rope(q, positions, rope_theta)
    k = apply_rope(k, positions, rope_theta)
    o = _flash_mha(q, k, v, positions, positions, causal=causal,
                   sliding_window=sliding_window, chunk=chunk)
    o = constrain(o, "batch", None, "heads", None)
    return o.reshape(B, S, n_heads * head_dim) @ p["wo"]


# ---------------------------------------------------------------------------
# KV-cache decode
# ---------------------------------------------------------------------------
def init_cache(batch: int, window: int, n_kv_heads: int, head_dim: int,
               dtype) -> Dict:
    return {
        "k": jnp.zeros((batch, window, n_kv_heads, head_dim), dtype),
        "v": jnp.zeros((batch, window, n_kv_heads, head_dim), dtype),
        "pos": jnp.full((batch, window), -1, jnp.int32),
    }


def cache_spec(batch: int, window: int, n_kv_heads: int, head_dim: int,
               dtype) -> Dict:
    return {
        "k": jax.ShapeDtypeStruct((batch, window, n_kv_heads, head_dim),
                                  dtype),
        "v": jax.ShapeDtypeStruct((batch, window, n_kv_heads, head_dim),
                                  dtype),
        "pos": jax.ShapeDtypeStruct((batch, window), jnp.int32),
    }


def decode_attention(p: Dict, x: jax.Array, t: jax.Array, cache: Dict, *,
                     n_heads: int, n_kv_heads: int, head_dim: int,
                     rope_theta: float,
                     sliding_window: Optional[int] = None
                     ) -> Tuple[jax.Array, Dict]:
    """One decode step. x: (B,1,D); t: (B,) absolute position of the new
    token. Writes slot t (full cache) or t % W (ring buffer), attends over
    all valid slots."""
    B, S, D = x.shape
    assert S == 1
    W = cache["k"].shape[1]
    q, k_new, v_new = _project_qkv(p, x, n_heads, n_kv_heads, head_dim)
    pos = t[:, None]                                   # (B,1)
    q = apply_rope(q, pos, rope_theta)
    k_new = apply_rope(k_new, pos, rope_theta)
    slot = (t % W)[:, None] if sliding_window is not None else t[:, None]
    bidx = jnp.arange(B)[:, None]
    k = cache["k"].at[bidx, slot].set(k_new)
    v = cache["v"].at[bidx, slot].set(v_new)
    cpos = cache["pos"].at[bidx, slot].set(pos)
    scores = _gqa_scores(q, k)                         # (B,H,1,W)
    kj = cpos[:, None, None, :]
    qi = t[:, None, None, None]
    mask = (kj >= 0) & (kj <= qi)
    if sliding_window is not None:
        mask = mask & (qi - kj < sliding_window)
    scores = jnp.where(mask, scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    o = _gqa_out(w, v).reshape(B, 1, n_heads * head_dim)
    return o @ p["wo"], {"k": k, "v": v, "pos": cpos}


# ---------------------------------------------------------------------------
# Cross-attention (enc-dec); encoder output is position-free (no rope)
# ---------------------------------------------------------------------------
def cross_attention(p: Dict, x: jax.Array, enc_kv: Tuple[jax.Array, jax.Array],
                    *, n_heads: int, n_kv_heads: int,
                    head_dim: int) -> jax.Array:
    B, S, D = x.shape
    q = (x @ p["wq"])
    if "bq" in p:
        q = q + p["bq"]
    q = q.reshape(B, S, n_heads, head_dim)
    k, v = enc_kv
    scores = _gqa_scores(q, k)
    w = jax.nn.softmax(scores, axis=-1)
    o = _gqa_out(w, v).reshape(B, S, n_heads * head_dim)
    return o @ p["wo"]


def cross_kv(p: Dict, enc_out: jax.Array, *, n_kv_heads: int,
             head_dim: int) -> Tuple[jax.Array, jax.Array]:
    """Precompute encoder K/V once per sequence (reused every decode step)."""
    B, S, _ = enc_out.shape
    k = enc_out @ p["wk"]
    v = enc_out @ p["wv"]
    if "bk" in p:
        k = k + p["bk"]
        v = v + p["bv"]
    return (k.reshape(B, S, n_kv_heads, head_dim),
            v.reshape(B, S, n_kv_heads, head_dim))
