"""Uniform model API over all families + input specs per benchmark cell.

``ModelAPI`` hides family differences behind four functions (init, loss,
prefill, decode) and provides ShapeDtypeStruct input specs for every
(shape x kind) cell so the launcher can lower without allocating.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, ShapeConfig
from . import encdec, transformer


def _xent(logits: jax.Array, targets: jax.Array) -> jax.Array:
    """Mean next-token cross-entropy in f32."""
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(
        logits.astype(jnp.float32), targets[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - gold)


@dataclass(frozen=True)
class ModelAPI:
    cfg: ModelConfig

    # ------------------------------------------------------------- init
    def init_params(self, key):
        if self.cfg.is_encdec:
            return encdec.init_params(self.cfg, key)
        return transformer.init_params(self.cfg, key)

    def param_spec(self):
        """ShapeDtypeStruct tree of the parameters (no allocation)."""
        return jax.eval_shape(
            lambda: self.init_params(jax.random.key(0)))

    # ------------------------------------------------------------- train
    def loss_fn(self, params, batch: Dict, *, remat: bool = False
                ) -> Tuple[jax.Array, Dict]:
        cfg = self.cfg
        if cfg.is_encdec:
            logits, aux, _ = encdec.forward(cfg, params, batch["tokens"],
                                            batch["frames"], remat=remat)
            loss = _xent(logits, batch["targets"])
        elif cfg.family == "vlm":
            logits, aux, _ = transformer.forward(
                cfg, params, batch["tokens"], patches=batch["patches"],
                remat=remat)
            logits = logits[:, cfg.vision_tokens:]   # text positions only
            loss = _xent(logits, batch["targets"])
        else:
            logits, aux, _ = transformer.forward(cfg, params,
                                                 batch["tokens"],
                                                 remat=remat)
            loss = _xent(logits, batch["targets"])
        total = loss + 0.01 * aux
        return total, {"loss": loss, "aux": aux}

    # ------------------------------------------- pipeline stages (train)
    def pipeline_supported(self) -> bool:
        """Whether the model decomposes into pipeline stages: a single
        stacked-blocks scan (dense/moe/ssm/xlstm/hybrid decoder-only).
        vlm prepends patches (stage 0 would need the vision frontend)
        and enc-dec has two stacks; both keep the single-axis path."""
        return (not self.cfg.is_encdec
                and self.cfg.family in ("dense", "moe", "ssm", "hybrid"))

    def embed_fn(self, params, tokens):
        """Input-side stage: tokens (B, S) -> activations (B, S, D)."""
        return transformer.embed_tokens(self.cfg, params, tokens)

    def stage_fn(self, io_params, blocks, h, *, remat: bool = False):
        """One stage's compute: scan a slice of the stacked blocks over
        the incoming activation. ``io_params`` carries the replicated
        non-block parameters (the hybrid family's shared attention is
        applied inside each group scan element). Returns (h, aux)."""
        return transformer.forward_stage(
            self.cfg, blocks, h, shared=io_params.get("shared"),
            remat=remat)

    def head_fn(self, params, h):
        """Output-side stage: final norm + (tied) unembedding."""
        return transformer.head_logits(self.cfg, params, h)

    def loss_from_logits(self, logits, targets):
        return _xent(logits, targets)

    # ------------------------------------------------------------- serve
    def decode_state_bdims(self, batch: int, window: int):
        """Per-leaf index of the decode state's BATCH dim, found by
        diffing the spec at two batch sizes (leaf layouts differ per
        family — stacked layer dims may precede the batch dim)."""
        s1 = self.decode_state_spec(batch, window)
        s2 = self.decode_state_spec(batch + 1, window)
        return jax.tree_util.tree_map(
            lambda a, b: next(i for i, (x, y)
                              in enumerate(zip(a.shape, b.shape))
                              if x != y), s1, s2)

    def prefill_state_fn(self, params, tokens, lengths, *, window: int):
        """Bulk prefill for RECURRENT decode states (ssm/xlstm/hybrid):
        one scanned decode pass over a padded (G, S_bucket) prompt group
        with per-request length masking — a group-batched compiled scan
        instead of one full-batch decode dispatch per token. A slot's
        state freezes once ``t >= lengths[g]`` (and its KV rows, where
        the family has them, stay untouched for the pad tail), so the
        final state equals the one token-by-token admission produces.
        Returns (next_logits (G, V) f32 at each request's own len-1,
        decode state for a G-slot batch)."""
        G, Sb = tokens.shape
        lengths = jnp.asarray(lengths, jnp.int32)
        state0 = self.init_decode_state(G, window)
        bdims = self.decode_state_bdims(G, window)

        def step(carry, t):
            state, nxt = carry
            tok = jnp.take(tokens, t, axis=1)
            logits, new_state = self.decode_fn(
                params, state, {"token": tok,
                                "t": jnp.full((G,), t, jnp.int32)})
            live = t < lengths                          # (G,)

            def sel(o, n, d):
                shape = [1] * o.ndim
                shape[d] = G
                return jnp.where(live.reshape(shape), n, o)

            state = jax.tree_util.tree_map(sel, state, new_state, bdims)
            nxt = jnp.where((t == lengths - 1)[:, None],
                            logits.astype(jnp.float32), nxt)
            return (state, nxt), None

        nxt0 = jnp.zeros((G, self.cfg.vocab_size), jnp.float32)
        (state, nxt), _ = jax.lax.scan(step, (state0, nxt0),
                                       jnp.arange(Sb, dtype=jnp.int32))
        return nxt, state

    def prefill_full_fn(self, params, batch: Dict):
        """Prefill returning logits at EVERY position (plus caches).
        Length-bucketed admission pads prompts up to a shared bucket
        length; causality keeps positions below the true prompt length
        unaffected, so the serving engine reads each request's next
        token at its own ``len - 1`` instead of the padded tail."""
        cfg = self.cfg
        if cfg.is_encdec:
            logits, _, caches = encdec.forward(cfg, params, batch["tokens"],
                                               batch["frames"],
                                               want_cache=True)
        elif cfg.family == "vlm":
            logits, _, caches = transformer.forward(
                cfg, params, batch["tokens"], patches=batch["patches"],
                want_cache=True)
        else:
            logits, _, caches = transformer.forward(
                cfg, params, batch["tokens"], want_cache=True)
        return logits, caches

    def prefill_fn(self, params, batch: Dict):
        logits, caches = self.prefill_full_fn(params, batch)
        return logits[:, -1], caches

    def decode_fn(self, params, state: Dict, batch: Dict):
        cfg = self.cfg
        if cfg.is_encdec:
            return encdec.decode_step(cfg, params, state, batch["token"],
                                      batch["t"])
        return transformer.decode_step(cfg, params, state, batch["token"],
                                       batch["t"])

    def decode_state_spec(self, batch: int, window: int):
        if self.cfg.is_encdec:
            return encdec.decode_state_spec(self.cfg, batch, window)
        return transformer.decode_state_spec(self.cfg, batch, window)

    def init_decode_state(self, batch: int, window: int):
        if self.cfg.is_encdec:
            return encdec.init_decode_state(self.cfg, batch, window)
        return transformer.init_decode_state(self.cfg, batch, window)

    # ------------------------------------------------------------- specs
    def input_specs(self, shape: ShapeConfig) -> Dict:
        """ShapeDtypeStruct stand-ins for the step inputs of this cell."""
        cfg = self.cfg
        B, S = shape.global_batch, shape.seq_len
        f = jax.ShapeDtypeStruct
        i32 = jnp.int32
        dt = jnp.dtype(cfg.dtype)
        if shape.kind == "decode":
            return {"token": f((B,), i32), "t": f((B,), i32)}
        specs: Dict = {}
        if cfg.family == "vlm":
            n_vis = cfg.vision_tokens
            specs["patches"] = f((B, n_vis, cfg.d_model), dt)
            specs["tokens"] = f((B, S - n_vis), i32)
            if shape.kind == "train":
                specs["targets"] = f((B, S - n_vis), i32)
            return specs
        if cfg.is_encdec:
            specs["frames"] = f((B, cfg.encoder_seq, cfg.d_model), dt)
        specs["tokens"] = f((B, S), i32)
        if shape.kind == "train":
            specs["targets"] = f((B, S), i32)
        return specs

    def make_inputs(self, shape: ShapeConfig, seed: int = 0) -> Dict:
        """Concrete random inputs matching input_specs (smoke tests)."""
        specs = self.input_specs(shape)
        key = jax.random.key(seed)
        out = {}
        for name, s in specs.items():
            key, sub = jax.random.split(key)
            if s.dtype == jnp.int32:
                hi = self.cfg.vocab_size if name in ("tokens", "targets",
                                                     "token") else shape.seq_len
                out[name] = jax.random.randint(sub, s.shape, 0, hi,
                                               dtype=jnp.int32)
            else:
                out[name] = jax.random.normal(sub, s.shape,
                                              jnp.float32).astype(s.dtype)
        return out


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------
_REGISTRY: Dict[str, Callable[[], ModelConfig]] = {}


def register(name: str):
    def deco(fn):
        _REGISTRY[name] = fn
        return fn
    return deco


def available() -> Tuple[str, ...]:
    _load_all()
    return tuple(sorted(_REGISTRY))


def get_config(name: str) -> ModelConfig:
    _load_all()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {available()}")
    return _REGISTRY[name]()


def get_api(name_or_cfg) -> ModelAPI:
    if isinstance(name_or_cfg, ModelConfig):
        return ModelAPI(name_or_cfg)
    return ModelAPI(get_config(name_or_cfg))


_loaded = False


def _load_all():
    global _loaded
    if _loaded:
        return
    from ..configs import archs  # noqa: F401  (registers all configs)
    _loaded = True
