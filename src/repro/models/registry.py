"""Uniform model API over all families + input specs per benchmark cell.

``ModelAPI`` hides family differences behind four functions (init, loss,
prefill, decode) and provides ShapeDtypeStruct input specs for every
(shape x kind) cell so the launcher can lower without allocating.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, ShapeConfig
from . import encdec, transformer


def _xent(logits: jax.Array, targets: jax.Array) -> jax.Array:
    """Mean next-token cross-entropy in f32."""
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(
        logits.astype(jnp.float32), targets[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - gold)


@dataclass(frozen=True)
class ModelAPI:
    cfg: ModelConfig

    # ------------------------------------------------------------- init
    def init_params(self, key):
        if self.cfg.is_encdec:
            return encdec.init_params(self.cfg, key)
        return transformer.init_params(self.cfg, key)

    def param_spec(self):
        """ShapeDtypeStruct tree of the parameters (no allocation)."""
        return jax.eval_shape(
            lambda: self.init_params(jax.random.key(0)))

    # ------------------------------------------------------------- train
    def loss_fn(self, params, batch: Dict, *, remat: bool = False
                ) -> Tuple[jax.Array, Dict]:
        cfg = self.cfg
        if cfg.is_encdec:
            logits, aux, _ = encdec.forward(cfg, params, batch["tokens"],
                                            batch["frames"], remat=remat)
            loss = _xent(logits, batch["targets"])
        elif cfg.family == "vlm":
            logits, aux, _ = transformer.forward(
                cfg, params, batch["tokens"], patches=batch["patches"],
                remat=remat)
            logits = logits[:, cfg.vision_tokens:]   # text positions only
            loss = _xent(logits, batch["targets"])
        else:
            logits, aux, _ = transformer.forward(cfg, params,
                                                 batch["tokens"],
                                                 remat=remat)
            loss = _xent(logits, batch["targets"])
        total = loss + 0.01 * aux
        return total, {"loss": loss, "aux": aux}

    # ------------------------------------------------------------- serve
    def prefill_full_fn(self, params, batch: Dict):
        """Prefill returning logits at EVERY position (plus caches).
        Length-bucketed admission pads prompts up to a shared bucket
        length; causality keeps positions below the true prompt length
        unaffected, so the serving engine reads each request's next
        token at its own ``len - 1`` instead of the padded tail."""
        cfg = self.cfg
        if cfg.is_encdec:
            logits, _, caches = encdec.forward(cfg, params, batch["tokens"],
                                               batch["frames"],
                                               want_cache=True)
        elif cfg.family == "vlm":
            logits, _, caches = transformer.forward(
                cfg, params, batch["tokens"], patches=batch["patches"],
                want_cache=True)
        else:
            logits, _, caches = transformer.forward(
                cfg, params, batch["tokens"], want_cache=True)
        return logits, caches

    def prefill_fn(self, params, batch: Dict):
        logits, caches = self.prefill_full_fn(params, batch)
        return logits[:, -1], caches

    def decode_fn(self, params, state: Dict, batch: Dict):
        cfg = self.cfg
        if cfg.is_encdec:
            return encdec.decode_step(cfg, params, state, batch["token"],
                                      batch["t"])
        return transformer.decode_step(cfg, params, state, batch["token"],
                                       batch["t"])

    def decode_state_spec(self, batch: int, window: int):
        if self.cfg.is_encdec:
            return encdec.decode_state_spec(self.cfg, batch, window)
        return transformer.decode_state_spec(self.cfg, batch, window)

    def init_decode_state(self, batch: int, window: int):
        if self.cfg.is_encdec:
            return encdec.init_decode_state(self.cfg, batch, window)
        return transformer.init_decode_state(self.cfg, batch, window)

    # ------------------------------------------------------------- specs
    def input_specs(self, shape: ShapeConfig) -> Dict:
        """ShapeDtypeStruct stand-ins for the step inputs of this cell."""
        cfg = self.cfg
        B, S = shape.global_batch, shape.seq_len
        f = jax.ShapeDtypeStruct
        i32 = jnp.int32
        dt = jnp.dtype(cfg.dtype)
        if shape.kind == "decode":
            return {"token": f((B,), i32), "t": f((B,), i32)}
        specs: Dict = {}
        if cfg.family == "vlm":
            n_vis = cfg.vision_tokens
            specs["patches"] = f((B, n_vis, cfg.d_model), dt)
            specs["tokens"] = f((B, S - n_vis), i32)
            if shape.kind == "train":
                specs["targets"] = f((B, S - n_vis), i32)
            return specs
        if cfg.is_encdec:
            specs["frames"] = f((B, cfg.encoder_seq, cfg.d_model), dt)
        specs["tokens"] = f((B, S), i32)
        if shape.kind == "train":
            specs["targets"] = f((B, S), i32)
        return specs

    def make_inputs(self, shape: ShapeConfig, seed: int = 0) -> Dict:
        """Concrete random inputs matching input_specs (smoke tests)."""
        specs = self.input_specs(shape)
        key = jax.random.key(seed)
        out = {}
        for name, s in specs.items():
            key, sub = jax.random.split(key)
            if s.dtype == jnp.int32:
                hi = self.cfg.vocab_size if name in ("tokens", "targets",
                                                     "token") else shape.seq_len
                out[name] = jax.random.randint(sub, s.shape, 0, hi,
                                               dtype=jnp.int32)
            else:
                out[name] = jax.random.normal(sub, s.shape,
                                              jnp.float32).astype(s.dtype)
        return out


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------
_REGISTRY: Dict[str, Callable[[], ModelConfig]] = {}


def register(name: str):
    def deco(fn):
        _REGISTRY[name] = fn
        return fn
    return deco


def available() -> Tuple[str, ...]:
    _load_all()
    return tuple(sorted(_REGISTRY))


def get_config(name: str) -> ModelConfig:
    _load_all()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {available()}")
    return _REGISTRY[name]()


def get_api(name_or_cfg) -> ModelAPI:
    if isinstance(name_or_cfg, ModelConfig):
        return ModelAPI(name_or_cfg)
    return ModelAPI(get_config(name_or_cfg))


_loaded = False


def _load_all():
    global _loaded
    if _loaded:
        return
    from ..configs import archs  # noqa: F401  (registers all configs)
    _loaded = True
