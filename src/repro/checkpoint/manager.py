"""Atomic, async-capable checkpointing for fault-tolerant restart.

Layout: <dir>/step_000123/ holds one .npy per parameter leaf plus a
manifest.json (tree structure, shapes, dtypes, data-pipeline state,
membership epoch). A checkpoint directory is COMMITTED by the atomic
rename of its temp dir — a crash mid-write can never produce a readable
but corrupt checkpoint (restart-safety). Writes can run on a background
thread (async) so the training loop overlaps checkpoint I/O with compute —
the phaser split-phase idea applied to I/O: "signal" (snapshot + enqueue)
early, "wait" (join) only before the next snapshot.

The manifest also records the **program-cache key** of the epoch that
produced the checkpoint (member set, schedule kind, seed/p, overlap
config — DESIGN.md §5): ``program_key()`` reads it without touching the
parameter arrays, so a resuming trainer pre-compiles the exact epoch
program before step 1 instead of discovering it at the first re-lower.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten_with_paths(tree) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        name = "_".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        out.append((name, leaf))
    return out


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3,
                 async_write: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_write = async_write
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # ----------------------------------------------------------------- save
    def save(self, step: int, params, opt_state=None,
             extra: Optional[Dict] = None,
             program_key: Optional[Dict] = None) -> None:
        """Snapshot to host memory now; write (possibly async) after.

        ``program_key`` is the epoch's program-cache identity (member
        set, kind, overlap config) — stored in the manifest so resume
        can pre-compile the exact program before step 1."""
        self.wait()           # at most one outstanding async write
        snap = {}
        snap_tree = {"params": params}
        if opt_state is not None:
            snap_tree["opt"] = opt_state._asdict() \
                if hasattr(opt_state, "_asdict") else opt_state
        for name, leaf in _flatten_with_paths(snap_tree):
            snap[name] = np.asarray(leaf)     # device -> host copy (sync)
        manifest = {
            "step": step,
            "leaves": sorted(snap),
            "extra": extra or {},
            "program": program_key,
            "time": time.time(),
        }

        def write():
            tmp = os.path.join(self.dir, f".tmp_step_{step:09d}")
            final = os.path.join(self.dir, f"step_{step:09d}")
            os.makedirs(tmp, exist_ok=True)
            for name, arr in snap.items():
                np.save(os.path.join(tmp, name + ".npy"), arr)
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)             # atomic commit
            self._gc()

        if self.async_write:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()
        else:
            write()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:09d}"),
                          ignore_errors=True)

    # -------------------------------------------------------------- restore
    def all_steps(self) -> List[int]:
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_"):
                try:
                    out.append(int(d[5:]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def program_key(self, step: Optional[int] = None) -> Optional[Dict]:
        """The program-cache key recorded at ``step`` (default latest),
        or None for checkpoints from non-engine runs. Reads only the
        manifest — cheap enough to call before the array restore."""
        self.wait()
        step = step if step is not None else self.latest_step()
        if step is None:
            return None
        path = os.path.join(self.dir, f"step_{step:09d}", "manifest.json")
        with open(path) as f:
            return json.load(f).get("program")

    def restore(self, template, step: Optional[int] = None
                ) -> Tuple[int, Any, Dict]:
        """Restore into the structure of ``template`` ({"params":..,
        "opt":..} tree). Returns (step, tree, extra)."""
        self.wait()
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = os.path.join(self.dir, f"step_{step:09d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        arrays = {name: np.load(os.path.join(d, name + ".npy"))
                  for name in manifest["leaves"]}
        names = [n for n, _ in _flatten_with_paths(template)]
        assert sorted(names) == manifest["leaves"], \
            "checkpoint/template structure mismatch"
        leaves = [arrays[n] for n in names]
        treedef = jax.tree_util.tree_structure(template)
        return step, treedef.unflatten(leaves), manifest["extra"]
