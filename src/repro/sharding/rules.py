"""Logical-axis sharding: models annotate tensors with *logical* axes;
rules map logical axes to mesh axes per architecture/policy.

Model code calls ``constrain(x, "batch", "seq", "embed")``. Under an active
``use_rules(...)`` context the logical names resolve to mesh axes and a
``with_sharding_constraint`` is emitted; with no context (single-device
smoke tests) it is a no-op — the same model code runs everywhere.

Parameter shardings are derived from the *pytree paths* of the parameter
tree by pattern rules (``param_specs``), so the model definition carries no
distribution logic at all.
"""
from __future__ import annotations

import contextlib
import re
import threading
from dataclasses import dataclass, field, replace
from typing import Dict, List, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Entry = Tuple[str, Optional[Tuple[str, ...]]]


@dataclass(frozen=True)
class ShardingRules:
    """Maps logical axis names -> mesh axis (or tuple of axes, or None)."""

    mesh: Optional[Mesh] = None
    logical: Dict[str, object] = field(default_factory=dict)
    # path-pattern -> tuple of logical axis names (one per tensor dim);
    # first match wins; unmatched params are fully replicated.
    params: Tuple[Tuple[str, Tuple[Optional[str], ...]], ...] = ()

    def resolve(self, *names: Optional[str]) -> P:
        axes = []
        for n in names:
            if n is None:
                axes.append(None)
            else:
                axes.append(self.logical.get(n))
        return P(*axes)

    def spec_for_path(self, path: str,
                      ndim: int) -> P:
        for pat, lnames in self.params:
            if re.search(pat, path):
                assert len(lnames) <= ndim, \
                    f"rule {pat} has {len(lnames)} axes, param {path} " \
                    f"has {ndim} dims"
                if len(lnames) < ndim:
                    # extra LEADING dims are stack dims (group scans add a
                    # second one); they are never sharded
                    lnames = (None,) * (ndim - len(lnames)) + tuple(lnames)
                return self.resolve(*lnames)
        return P()


_tls = threading.local()


def current_rules() -> Optional[ShardingRules]:
    return getattr(_tls, "rules", None)


@contextlib.contextmanager
def use_rules(rules: Optional[ShardingRules]):
    prev = getattr(_tls, "rules", None)
    _tls.rules = rules
    try:
        yield rules
    finally:
        _tls.rules = prev


def constrain(x: jax.Array, *logical_axes: Optional[str]) -> jax.Array:
    """Annotate activation ``x`` with logical axes (no-op w/o rules)."""
    rules = current_rules()
    if rules is None or rules.mesh is None:
        return x
    spec = rules.resolve(*logical_axes)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(rules.mesh, spec))


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def param_specs(params, rules: ShardingRules):
    """PartitionSpec tree matching ``params`` via the path rules."""
    return jax.tree_util.tree_map_with_path(
        lambda path, x: rules.spec_for_path(_path_str(path), x.ndim),
        params)


def param_shardings(params, rules: ShardingRules):
    specs = param_specs(params, rules)
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(rules.mesh, s), specs,
        is_leaf=lambda s: isinstance(s, P))
