from .rules import (ShardingRules, constrain, current_rules, param_specs,
                    use_rules)

__all__ = ["ShardingRules", "constrain", "current_rules", "param_specs",
           "use_rules"]
