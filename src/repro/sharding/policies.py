"""Per-architecture sharding policies for the production mesh.

Mesh axes: single-pod ("data", "model") = (16, 16); multi-pod adds a
leading "pod" axis folded into data parallelism. Policy knobs:

* TP      — attention heads / ff / vocab over "model" (always on)
* FSDP    — parameters additionally sharded over "data" on the non-TP dim
            (ZeRO-3 style; XLA all-gathers per scan step); enabled for
            >= ~6B-param archs by default
* EP      — MoE expert dim over "model" when n_experts divides the axis,
            otherwise TP inside each expert (Mixtral: 8 experts on a
            16-way axis would pad half the devices idle)
* head constraints are only emitted when the head count divides the TP
  axis (llava's 56 heads / smollm's 9 heads propagate from the weight
  shardings instead of forcing padded activation shardings)
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ModelConfig
from .rules import ShardingRules


def axis_size(mesh: Mesh, name) -> int:
    if name is None:
        return 1
    if isinstance(name, (tuple, list)):
        out = 1
        for n in name:
            out *= axis_size(mesh, n)
        return out
    return mesh.shape[name]


def make_rules(mesh: Mesh, cfg: ModelConfig, *,
               fsdp: Optional[bool] = None,
               moe_mode: Optional[str] = None,
               seq_shard: bool = False,
               dp_over_model: bool = False) -> ShardingRules:
    """Build the rule set for (mesh, arch).

    ``dp_over_model``: fold the model axis into data parallelism (pure
    DP-256/512, parameters replicated). The right policy for small models
    whose head counts don't divide the TP axis — TP would replicate their
    attention compute 16x (hillclimb B in EXPERIMENTS.md §Perf)."""
    multi_pod = "pod" in mesh.axis_names
    dp = ("pod", "data") if multi_pod else ("data",)
    tp = "model"
    tp_size = axis_size(mesh, tp)
    if dp_over_model:
        dp = dp + ("model",)
        tp = None
        tp_size = 1
    if fsdp is None:
        fsdp = cfg.param_count() >= 6e9
    fs = "data" if fsdp else None
    if moe_mode is None:
        moe_mode = "ep" if (cfg.n_experts and tp_size > 1 and
                            cfg.n_experts % tp_size == 0) else "tp"

    logical = {
        "batch": dp,
        "heads": tp if tp and cfg.n_heads % tp_size == 0 else None,
        "kv_heads": tp if tp and cfg.n_kv_heads % tp_size == 0 else None,
        "ff": tp,
        # non-divisible vocabs (granite 49155, whisper 51865) replicate the
        # embedding rather than padding the table (config kept exact)
        "vocab": tp if tp and cfg.vocab_size % tp_size == 0 else None,
        "experts": tp if moe_mode == "ep" else None,
        # expert-internal ff dim: TP'd only when experts are NOT the EP
        # axis (a spec may use each mesh axis once)
        "expert_ff": None if moe_mode == "ep" else tp,
        "seq": tp if seq_shard else None,
    }

    ex = ("experts" if moe_mode == "ep" else None)
    moe_inner_tp = (None if moe_mode == "ep" else "ff")
    params: Tuple = (
        # --- attention ---
        (r"blocks/attn/w[qkv]$", ("layers", "fsdp", "ff")),
        (r"blocks/attn/wo$", ("layers", "ff", "fsdp")),
        (r"blocks/attn/b[qkv]$", ("layers", "ff")),
        (r"shared/attn/w[qkv]$", ("fsdp", "ff")),
        (r"shared/attn/wo$", ("ff", "fsdp")),
        (r"shared/attn/b[qkv]$", ("ff",)),
        (r"(enc_blocks|dec_blocks)/x?attn/w[qkv]$", ("layers", "fsdp", "ff")),
        (r"(enc_blocks|dec_blocks)/x?attn/wo$", ("layers", "ff", "fsdp")),
        (r"(enc_blocks|dec_blocks)/x?attn/b[qkv]$", ("layers", "ff")),
        # --- dense mlp ---
        (r"blocks/mlp/(gate|up)$", ("layers", "fsdp", "ff")),
        (r"blocks/mlp/down$", ("layers", "ff", "fsdp")),
        (r"shared/mlp/(gate|up)$", ("fsdp", "ff")),
        (r"shared/mlp/down$", ("ff", "fsdp")),
        (r"(enc_blocks|dec_blocks)/mlp/(gate|up)$", ("layers", "fsdp", "ff")),
        (r"(enc_blocks|dec_blocks)/mlp/down$", ("layers", "ff", "fsdp")),
        # --- moe ---
        (r"blocks/moe/router$", ("layers", "fsdp", None)),
        (r"blocks/moe/(gate|up)$", ("layers", ex, "fsdp", moe_inner_tp)),
        (r"blocks/moe/down$", ("layers", ex, moe_inner_tp, "fsdp")),
        # --- mamba2 ---
        (r"blocks/ssm/in_proj$", ("layers", "fsdp", "ff")),
        (r"blocks/ssm/out_proj$", ("layers", "ff", "fsdp")),
        (r"blocks/ssm/conv_w$", ("layers", None, "ff")),
        (r"blocks/ssm/(A_log|D|dt_bias)$", ("layers", None)),
        (r"blocks/ssm/norm_w$", ("layers", "ff")),
        # --- xlstm ---
        (r"blocks/[ms]lstm/(up|wq|wk|wv)$", ("layers", "fsdp", "ff")),
        (r"blocks/[ms]lstm/(down|wx)$", ("layers", "ff", "fsdp")),
        (r"blocks/mlstm/w[if]$", ("layers", "fsdp", None)),
        (r"blocks/slstm/wr$", ("layers", None, "ff", None)),
        (r"blocks/[ms]lstm/norm_w$", ("layers", "ff")),
        (r"blocks/[ms]lstm/(fb|b)$", ("layers", None)),
        # --- embeddings ---
        (r"^(embed|lm_head)$", ("vocab", "fsdp")),
        # norms etc. fall through -> replicated
    )
    logical = dict(logical)
    logical["layers"] = None
    logical["fsdp"] = fs
    return ShardingRules(mesh=mesh, logical=logical, params=params)


# ---------------------------------------------------------------------------
# Pipeline-parallel 2-D mesh
# ---------------------------------------------------------------------------
def stage_data_mesh(n_stages: int, n_data: int, *,
                    data_axis: str = "data", stage_axis: str = "stage",
                    devices=None) -> Mesh:
    """The 2-D (stage x data) mesh of the pipeline subsystem
    (``pipeline_exec``): ``n_stages`` model-parallel pipeline rows, each
    a full data-parallel team of ``n_data``. Devices fill stage-major —
    a data column's stages sit on CONSECUTIVE devices, so the per-wave
    activation ``ppermute`` hops between physical neighbours while the
    data-axis collective spans the stride."""
    import numpy as np
    devices = list(devices) if devices is not None else jax.devices()
    need = n_stages * n_data
    assert len(devices) >= need, \
        f"need {n_stages}x{n_data}={need} devices for the " \
        f"({stage_axis!r}, {data_axis!r}) mesh, have {len(devices)}"
    arr = np.array(devices[:need]).reshape(n_data, n_stages).T
    return Mesh(arr.copy(), (stage_axis, data_axis))


# ---------------------------------------------------------------------------
# Batch / decode-state shardings
# ---------------------------------------------------------------------------
def batch_specs(rules: ShardingRules, batch: Dict) -> Dict:
    """PartitionSpec per input field: batch dim over dp, rest replicated."""
    def spec(leaf):
        return P(rules.logical["batch"], *([None] * (leaf.ndim - 1)))
    return jax.tree_util.tree_map(spec, batch)


_STATE_RULES: Tuple = (
    # (regex on the state-tree path; axes are left-padded with None for
    # any extra leading stack dims)
    (r"slstm/", (None, "batch", "ssm_heads", None)),  # c/n/m/h (G,B,nh,hd)
    (r"mlstm/m$", ("batch", "ssm_heads")),            # (G,k-1,B,nh)
    (r"(^|/)conv$", (None, "batch", None, "ff")),
    (r"(^|/)(k|v)$", (None, "batch", "window", "kv_heads", None)),
    (r"(^|/)pos$", (None, "batch", "window")),
    (r"(^|/)cross_(k|v)$", (None, "batch", None, "kv_heads", None)),
    (r"(^|/)h$", (None, "batch", "ssm_heads", None, None)),
    (r"(^|/)C$", (None, "batch", "ssm_heads", None, None)),
    (r"(^|/)n$", (None, "batch", "ssm_heads", None)),
    (r"(^|/)m$", (None, "batch", "ssm_heads")),
    (r"(^|/)c$", (None, "batch", "ssm_heads", None)),
)


def decode_state_specs(rules: ShardingRules, cfg: ModelConfig, state_tree,
                       mesh: Mesh, batch: Optional[int] = None,
                       split_k: bool = False) -> Dict:
    """Decode-state shardings. When the request batch does not divide the
    dp axes (long-context, batch=1), the KV-cache WINDOW dim is sharded
    over 'data' instead (sequence-sharded cache — the serving analogue of
    ring attention).

    ``split_k``: shard the window dim over the MODEL axis (mesh-level
    FlashDecoding split-K): non-divisible kv-head counts otherwise leave
    the cache replicated 16x over the model axis, making cache reads the
    decode bottleneck (hillclimb C in EXPERIMENTS.md §Perf)."""
    import re

    from ..models.ssm import ssm_dims
    tp_size = axis_size(mesh, "model")
    if cfg.family in ("ssm", "hybrid") and not cfg.slstm_every:
        nh = ssm_dims(cfg.d_model, cfg.ssm_expand, cfg.ssm_headdim)[1]
    else:
        nh = cfg.n_heads
    logical = dict(rules.logical)
    logical["ssm_heads"] = "model" if nh % tp_size == 0 else None
    dp_size = axis_size(mesh, logical.get("batch"))
    batch_ok = batch is None or (batch % dp_size == 0)
    logical["window"] = "model" if split_k else None
    if split_k:
        logical["kv_heads"] = None    # window takes the model axis
    if not batch_ok:
        logical["batch"] = None
        logical["window"] = ("data", "model") if split_k else "data"
    r2 = ShardingRules(mesh=mesh, logical=logical, params=rules.params)

    def spec_for(path, leaf):
        p = "/".join(str(getattr(x, "key", getattr(x, "idx", x)))
                     for x in path)
        for pat, axes in _STATE_RULES:
            if re.search(pat, p):
                fit = tuple(axes)
                if len(fit) < leaf.ndim:   # extra LEADING stack dims
                    fit = (None,) * (leaf.ndim - len(fit)) + fit
                return r2.resolve(*fit[:leaf.ndim])
        return P(*([None] * leaf.ndim))

    return jax.tree_util.tree_map_with_path(spec_for, state_tree)
