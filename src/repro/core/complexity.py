"""Analytic complexity models from paper §3, used by benchmarks to compare
measured message counts / critical-path lengths against the claimed bounds.

Paper claims (n signalers, skip-list inter-level probability p):
  * signal aggregation:   expected critical path  O(log n)
  * eager insertion:      time & messages         O(log n)
  * lazy promotion:       per-node               O(p/(1-p) · log(C·p/(1-p)))
                          for a group of C concurrently promoting nodes
  * deletion:             messages & time         O(log n)
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Callable, List, Sequence, Tuple


def expected_height(p: float) -> float:
    """E[height] of a skip-list node: geometric(1-p) => 1/(1-p)."""
    return 1.0 / (1.0 - p)


def expected_depth(n: int, p: float = 0.5) -> float:
    """Expected search/signal path length ~ log_{1/p}(n) · 1/(1-p)."""
    if n <= 1:
        return 1.0
    return math.log(n, 1.0 / p) / (1.0 - p)


def signal_bound(n: int, p: float = 0.5, c: float = 3.0) -> float:
    """O(log n) with explicit constant for assertions in benchmarks."""
    return c * max(1.0, expected_depth(n, p)) + c


def insertion_bound(n: int, p: float = 0.5, c: float = 4.0) -> float:
    """Eager insertion: search O(log n) + constant splice traffic."""
    return c * max(1.0, expected_depth(n, p)) + 8.0


def deletion_bound(n: int, p: float = 0.5, c: float = 6.0) -> float:
    """Deletion: O(log n) levels, constant messages per level."""
    exp_levels = min(expected_height(p) + math.log(max(n, 2), 1 / p),
                     64.0)
    return c * exp_levels + 8.0


def lazy_promotion_bound(C: int, p: float = 0.5, c: float = 8.0) -> float:
    """Paper: per-node lazy cost O(p/(1-p) · log(C·p/(1-p)))."""
    r = p / (1.0 - p)
    return c * max(1.0, r * math.log(max(C * r, 2.0))) + c


@dataclass
class Fit:
    """Least-squares fit of y ~ a·log2(x) + b — benchmarks use it to verify
    measured curves are logarithmic (R² close to 1, small residual slope in
    log-space)."""

    a: float
    b: float
    r2: float

    @classmethod
    def log_fit(cls, xs: Sequence[float], ys: Sequence[float]) -> "Fit":
        lx = [math.log2(x) for x in xs]
        n = len(lx)
        mx = sum(lx) / n
        my = sum(ys) / n
        sxx = sum((x - mx) ** 2 for x in lx)
        sxy = sum((x - mx) * (y - my) for x, y in zip(lx, ys))
        a = sxy / sxx if sxx else 0.0
        b = my - a * mx
        ss_res = sum((y - (a * x + b)) ** 2 for x, y in zip(lx, ys))
        ss_tot = sum((y - my) ** 2 for y in ys)
        r2 = 1.0 - ss_res / ss_tot if ss_tot else 1.0
        return cls(a=a, b=b, r2=r2)

    def predict(self, x: float) -> float:
        return self.a * math.log2(x) + self.b


def is_logarithmic(xs: Sequence[float], ys: Sequence[float],
                   r2_min: float = 0.85) -> Tuple[bool, Fit]:
    """True if ys grows ~log(xs): good log-fit AND sublinear growth.

    The sublinearity check: doubling x from the median should grow y by a
    roughly additive (not multiplicative) amount — ratio of increments per
    doubling stays bounded.
    """
    fit = Fit.log_fit(xs, ys)
    # linear fit for comparison
    n = len(xs)
    mx = sum(xs) / n
    my = sum(ys) / n
    sxx = sum((x - mx) ** 2 for x in xs)
    sxy = sum((x - mx) * (y - my) for x, y in zip(xs, ys))
    a_lin = sxy / sxx if sxx else 0.0
    b_lin = my - a_lin * mx
    ss_res_lin = sum((y - (a_lin * x + b_lin)) ** 2
                     for x, y in zip(xs, ys))
    ss_res_log = sum((y - fit.predict(x)) ** 2 for x, y in zip(xs, ys))
    ok = fit.r2 >= r2_min and ss_res_log <= ss_res_lin * 1.5
    return ok, fit
