"""Point-to-point phaser modes: producer-consumer and pipeline graphs.

The paper's defining claim is that ONE primitive unifies collective and
point-to-point coordination through *registration modes*: a task
registered SIG is a pure producer (it signals phases, never blocks), a
task registered WAIT is a pure consumer (it observes phase advances,
never gates them), and SIG_WAIT is both. ``core/phaser.py`` already
carries the modes through the protocol — a SIG-only task joins the SCSL
but not the SNSL, a WAIT-only task the reverse — but nothing in the repo
exercised the point-to-point half. This module is that half:

* ``P2PPhaser`` — one phaser with explicit per-participant modes and the
  paper's **signal-accumulation** semantics: a producer may run
  arbitrarily far ahead (each ``signal`` contributes to the next unsignaled
  phase; the head releases phase k once every registered signaler has
  accumulated k+1 signals), and a consumer's ``wait(phase)`` is satisfied
  exactly when the SNSL has diffused the release of ``phase`` to it.
  This is the phaser generalization of semaphores/producer-consumer: the
  signal count is the semaphore value, phases are its history.

* ``PipelinePhaserGraph`` — a directed stage graph with one P2P phaser
  per edge: edge (u, v) registers u as SIG and v as WAIT, so interior
  pipeline stages are SIG toward their successor and WAIT on their
  predecessor (SIG_WAIT across their two edge phasers — exactly the
  dependency structure of pipeline parallelism). ``run_program`` drives
  an instruction stream (signal/wait ops) through the REAL protocol
  actors and records the global release order; ``simulate_program`` is
  the host counter oracle it must match (the p2p analogue of
  ``simulate_schedule`` for collective rounds).

The deterministic skip-list oracle extends to modes structurally: the
SCSL is the oracle over the *signaler* key set, the SNSL over the
*waiter* key set (``P2PPhaser.verify_topology``).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple

from .phaser import SCSL, SNSL, SIG_MODE, SIG_WAIT, WAIT_MODE, DistPhaser
from .runtime import FifoScheduler, Scheduler
from .skiplist import HEAD, SkipList

MODES = (SIG_MODE, WAIT_MODE, SIG_WAIT)


class P2PPhaser:
    """One phaser with explicit per-participant registration modes.

    ``modes`` maps rank -> SIG | WAIT | SIG_WAIT for ranks 0..n-1.
    Signals accumulate: ``signal(rank, times)`` contributes ``times``
    consecutive phases without ever blocking (the protocol buffers the
    run-ahead; phase k is released only when every signaler reached it).
    ``wait(rank, phase)`` is the non-blocking completion test after the
    protocol ran to quiescence — the data plane's "may I consume item
    ``phase``" check.
    """

    def __init__(self, modes: Dict[int, str], *, seed: int = 0,
                 name: str = "p2p",
                 scheduler: Optional[Callable[[], Scheduler]] = None):
        assert modes, "empty phaser"
        assert all(m in MODES for m in modes.values()), modes
        assert sorted(modes) == list(range(len(modes))), \
            f"ranks must be 0..n-1, got {sorted(modes)}"
        self.name = name
        self.modes = dict(modes)
        self._make_scheduler = scheduler or FifoScheduler
        self.ph = DistPhaser(len(modes), modes=self.modes, seed=seed)
        self.signaled: Dict[int, int] = {r: 0 for r in modes}

    # ------------------------------------------------------------ mode sets
    def signalers(self) -> List[int]:
        return [r for r, m in self.modes.items()
                if m in (SIG_MODE, SIG_WAIT)]

    def waiters(self) -> List[int]:
        return [r for r, m in self.modes.items()
                if m in (WAIT_MODE, SIG_WAIT)]

    # ------------------------------------------------------------- task API
    def signal(self, rank: int, times: int = 1) -> None:
        """Producer side: accumulate ``times`` signals (run-ahead is
        unbounded — the paper's asynchronous signal)."""
        assert self.modes[rank] in (SIG_MODE, SIG_WAIT), \
            f"rank {rank} is {self.modes[rank]}: cannot signal"
        for _ in range(times):
            self.ph.signal(rank)
        self.signaled[rank] += times
        self.run()

    def wait(self, rank: int, phase: int) -> bool:
        """Consumer side: has ``phase`` been released to ``rank``?"""
        assert self.modes[rank] in (WAIT_MODE, SIG_WAIT), \
            f"rank {rank} is {self.modes[rank]}: cannot wait"
        self.run()
        return self.released(rank) >= phase

    def pending(self, rank: int) -> int:
        """Signals a producer has issued beyond the released phase — the
        accumulated run-ahead (the semaphore value)."""
        return self.signaled[rank] - (self.ph.released() + 1)

    # --------------------------------------------------------- watermarks
    def enable_watermarks(self, pid: int = 0):
        """Install a live phase-watermark tracker (obs plane): the
        underlying actors report per-rank (signal, wait) phases and the
        signal->release gap through the facade hooks; modes are seeded
        so the tracker's view matches the registration table."""
        from ..obs.live import WatermarkTracker
        wm = WatermarkTracker(pid)
        for r, m in self.modes.items():
            wm.set_mode(r, m)
        self.ph.watermarks = wm
        return wm

    @property
    def watermarks(self):
        return self.ph.watermarks

    def released(self, rank: Optional[int] = None) -> int:
        return self.ph.released(rank)

    def add_participant(self, parent: int, rank: int, mode: str) -> None:
        """Dynamic registration with an explicit mode (paper Fig. 2)."""
        self.ph.async_add(parent, rank, mode)
        self.modes[rank] = mode
        self.signaled[rank] = 0
        if self.ph.watermarks is not None:
            self.ph.watermarks.set_mode(rank, mode)
        self.run()

    def demote(self, rank: int) -> None:
        """Straggler demotion on a p2p phaser: pin ``rank`` to a leaf
        (height 1) in whichever lists its mode materializes it — it
        keeps signaling/waiting, but loses every skip-list dependent.
        The mode-filtered oracle (``verify_topology``) follows because
        it builds with ``leaf_keys = demoted``."""
        self.run()
        self.ph.demote(rank)
        self.run()

    def repromote(self, rank: int) -> None:
        """Undo a demotion: restore the deterministic drawn height."""
        self.run()
        self.ph.repromote(rank)
        self.run()

    def run(self) -> int:
        return self.ph.run(self._make_scheduler())

    # ---------------------------------------------------------- topology
    def _lanes(self, lid: int) -> List[List[int]]:
        lanes, l = [], 0
        while True:
            st = self.ph.actors[HEAD].st(lid)
            cur = st.nxt[l] if l < len(st.nxt) else None
            lane = []
            while cur is not None:
                lane.append(cur)
                nst = self.ph.actors[cur].st(lid)
                cur = nst.nxt[l] if l < nst.height else None
            if not lane and l > 0:
                break
            lanes.append(lane)
            l += 1
        return [lane for lane in lanes if lane] or [[]]

    def verify_topology(self) -> None:
        """Mode-filtered oracle check: the SCSL must be the deterministic
        skip list over the *signaler* keys, the SNSL over the *waiter*
        keys — the modes select which list a key materializes in, the
        heights stay a function of the key alone."""
        assert self.ph.net.idle(), "verify requires quiescence"
        for lid, keys in ((SCSL, self.signalers()), (SNSL, self.waiters())):
            sl = SkipList.build(keys, p=self.ph.p,
                                max_height=self.ph.max_height,
                                seed=self.ph.seed,
                                leaf_keys=self.ph.demoted)
            want = [sl.level_chain(l)
                    for l in range(max((sl.nodes[k].height
                                        for k in sl.keys()), default=1))]
            want = [lane for lane in want if lane] or [[]]
            got = self._lanes(lid)
            assert got == want, \
                f"{self.name} lid={lid}: lanes {got} != oracle {want}"


# ---------------------------------------------------------------------------
# Stage graphs: one P2P phaser per dependency edge
# ---------------------------------------------------------------------------
# an instruction: ("signal", (u, v)) or ("wait", (u, v), phase)
Op = Tuple
Edge = Tuple[int, int]


@dataclass(frozen=True)
class ReleaseEvent:
    edge: Edge
    phase: int


class PipelinePhaserGraph:
    """A directed stage graph as a family of point-to-point phasers.

    One phaser per edge (u, v): u registered SIG, v registered WAIT.
    A node with out-edges and in-edges is therefore SIG_WAIT *across the
    graph* — the paper's claim that phaser modes subsume producer-consumer
    and pipeline dependency structures, realized on the live actors.
    """

    def __init__(self, n_nodes: int, edges: Sequence[Edge], *,
                 seed: int = 0,
                 scheduler: Optional[Callable[[], Scheduler]] = None):
        self.n_nodes = n_nodes
        self.edges = tuple(edges)
        assert len(set(self.edges)) == len(self.edges), "duplicate edge"
        self.release_log: List[ReleaseEvent] = []
        self.phasers: Dict[Edge, P2PPhaser] = {}
        for (u, v) in self.edges:
            assert 0 <= u < n_nodes and 0 <= v < n_nodes and u != v
            p = P2PPhaser({0: SIG_MODE, 1: WAIT_MODE}, seed=seed,
                          name=f"edge{u}->{v}", scheduler=scheduler)
            # the release instant, observed from inside the head actor:
            # the global interleaving of per-edge phase releases
            p.ph.release_monitor = (
                lambda ph, k, e=(u, v):
                self.release_log.append(ReleaseEvent(e, k)))
            self.phasers[(u, v)] = p

    # ------------------------------------------------------------- node view
    def mode_of(self, node: int) -> str:
        """The node's aggregated registration across the graph."""
        sig = any(u == node for u, _ in self.edges)
        wai = any(v == node for _, v in self.edges)
        if sig and wai:
            return SIG_WAIT
        return SIG_MODE if sig else WAIT_MODE

    # ------------------------------------------------------------ execution
    def signal(self, edge: Edge) -> None:
        self.phasers[edge].signal(0)

    def wait(self, edge: Edge, phase: int) -> bool:
        return self.phasers[edge].wait(1, phase)

    def demote(self, edge: Edge, rank: int) -> None:
        """Mid-program straggler demotion of one edge phaser's
        participant (0 = the SIG producer, 1 = the WAIT consumer):
        release semantics are unchanged — only the skip-list topology
        degrades to the leaf-pinned oracle."""
        self.phasers[tuple(edge)].demote(rank)

    def repromote(self, edge: Edge, rank: int) -> None:
        self.phasers[tuple(edge)].repromote(rank)

    def run_program(self, program: Iterable[Op]) -> List[ReleaseEvent]:
        """Drive an instruction stream through the real protocol actors.
        Every ``wait`` must already be satisfied when reached (the
        program claims to be a valid linearization of the dependency
        graph); raises AssertionError otherwise. Returns the observed
        global release order."""
        self.release_log.clear()
        for op in program:
            if op[0] == "signal":
                self.signal(op[1])
            else:
                _, edge, phase = op
                assert self.wait(edge, phase), \
                    f"wait{edge} phase {phase} not satisfied " \
                    f"(released={self.phasers[edge].released(1)})"
        return list(self.release_log)

    def verify_topologies(self) -> None:
        for p in self.phasers.values():
            p.verify_topology()

    def stats(self) -> Dict[str, int]:
        return {"edges": len(self.edges),
                "messages": sum(p.ph.net.total_sent()
                                for p in self.phasers.values()),
                "releases": len(self.release_log)}


def simulate_program(edges: Sequence[Edge],
                     program: Iterable[Op]) -> List[ReleaseEvent]:
    """Host counter oracle for a p2p instruction stream — the exact
    mirror of ``PipelinePhaserGraph.run_program`` (the p2p analogue of
    ``simulate_schedule``): per edge, the accumulated signal count IS the
    released phase + 1; a ``wait(edge, k)`` is satisfied iff the count
    exceeds ``k``. Returns the release order; raises on an unsatisfied
    wait (an invalid linearization)."""
    count = {tuple(e): 0 for e in edges}
    log: List[ReleaseEvent] = []
    for op in program:
        if op[0] == "signal":
            e = tuple(op[1])
            log.append(ReleaseEvent(e, count[e]))
            count[e] += 1
        else:
            _, edge, phase = op
            assert count[tuple(edge)] > phase, \
                f"oracle: wait{tuple(edge)} phase {phase} unsatisfied " \
                f"(count={count[tuple(edge)]})"
    return log
