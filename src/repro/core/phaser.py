"""Distributed-phaser protocol actors.

Faithful control-plane reproduction of the paper's design (DESIGN.md §1-2):

* one actor per participant, plus a sentinel HEAD actor (-1) that plays the
  designated head-signaler (SCSL root) and head-waiter (SNSL root);
* signals flow child -> parent along *signal edges* (each node's predecessor
  at its own top lane), aggregated hierarchically; phase-advance ADVs diffuse
  down the SNSL along the reverse edges;
* dynamic addition = eager level-0 splice (TUS/TDS search + MURS fast
  single-link-modify) followed by lazy hand-over-hand MULS promotions;
* dynamic deletion = level-by-level top-down unlink (UNL);
* registration accounting (ENSP/DEREG deltas) rides the same FIFO channels
  as the signals, which makes head bookkeeping race-free.

Correctness architecture: the substrate is *eager pass-through routing* —
any count a node cannot account for is forwarded toward the head, and the
head's completion test is count-based (collected == expected). Hierarchical
combining (per-node books of children intervals) is an optimization layered
on top; its bookkeeping can lag behind structural churn without ever losing
or double-counting a signal. The model checker (core/modelcheck.py) verifies
the interaction of both layers under all interleavings for small configs.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from . import messages as M
from .runtime import Actor, Network, Scheduler, FifoScheduler
from .skiplist import HEAD, SkipList, det_height

SIG_MODE = "SIG"
WAIT_MODE = "WAIT"
SIG_WAIT = "SIG_WAIT"

SCSL, SNSL = 0, 1


@dataclass
class ListState:
    """Per-(node, list) protocol state: local links + combining books."""

    lid: int
    key: int
    height: int = 1
    target_height: int = 1
    nxt: List[Optional[int]] = field(default_factory=lambda: [None])
    prv: List[Optional[int]] = field(default_factory=lambda: [None])
    member: bool = False          # participates in this list at all
    joined: bool = False          # eager insert completed (links valid)
    departed: bool = False        # drop() finished
    # --- combining books (SCSL) / forwarding set (SNSL) ---
    # child -> list of [from_phase, to_phase|None) intervals
    books: Dict[int, List[List[Optional[int]]]] = field(default_factory=dict)
    # advertised intervals: [lo, hi|None, parent] — the exact mirror of the
    # interval this node has opened (CHILD_ADD / splice) and closed
    # (CHILD_DEL) in each parent's books. The single source of truth for
    # "who expects my closing report for phase k" — keeping it mirrored by
    # construction is what makes head accounting race-free.
    adv: List[List[Optional[int]]] = field(default_factory=list)
    closed: int = -1              # highest phase whose aggregate we sent
    buf: Dict[int, int] = field(default_factory=dict)
    reported: Dict[int, set] = field(default_factory=dict)
    selfsig: set = field(default_factory=set)
    first_phase: int = 0
    dereg_phase: Optional[int] = None   # signaler-active for first<=k<dereg
    # --- hand-over-hand latches for MULS splices (level -> new_id) ---
    latch: Dict[int, int] = field(default_factory=dict)
    latch_q: Dict[int, List[int]] = field(default_factory=dict)
    # walkers deferred at a dropping node until its level unlinks
    # (abort-retry against a leaving lane member would livelock)
    defer_q: Dict[int, List[int]] = field(default_factory=dict)
    # UNLs parked behind an open MULS latch at the same level
    unl_park: Dict[int, List] = field(default_factory=dict)
    # structural traffic deferred until our own eager insert completes
    # (serving a search/splice before MURS_ACK initializes our links
    # would be clobbered by the ack)
    join_defer: List = field(default_factory=list)
    # --- SCSL re-parent handshake (chain invariant, DESIGN.md §10) ---
    rp_pending: Optional[int] = None     # CHILD_ADD sent, awaiting ACK
    rp_queue: Optional[Tuple[int, int]] = None  # (next_parent, effective)
    # --- SNSL ---
    released: int = -1
    # --- deletion driver ---
    dropping: bool = False
    # demotion: unlink stops when the level falls below this (1 = keep
    # level 0 — the node stays a member, pinned to a leaf position);
    # 0 = full departure (the plain drop path)
    demote_stop: int = 0
    unlink_level: Optional[int] = None
    unlink_waiting: bool = False      # paused on an open MULS latch
    unl_sent_succ: Optional[int] = None   # succ snapshot in the last UNL
    unl0_sent: bool = False           # level-0 UNL in flight
    splice_defer: List[int] = field(default_factory=list)
    final_childdel_sent: bool = False

    @property
    def top(self) -> int:
        return self.height - 1

    def covers(self, child: int, k: int) -> bool:
        for lo, hi in self.books.get(child, ()):  # type: ignore[misc]
            if lo <= k and (hi is None or k < hi):
                return True
        return False

    def active_children(self, k: int) -> List[int]:
        return [c for c in self.books if self.covers(c, k)]

    def any_coverage(self, k: int) -> bool:
        return any(self.covers(c, k) for c in self.books)

    def max_to(self) -> int:
        """Highest to_phase over closed child intervals (0 if none)."""
        m = 0
        for iv in self.books.values():
            for lo, hi in iv:
                if hi is not None:
                    m = max(m, hi)
        return m

    def all_children_closed(self) -> bool:
        return all(hi is not None for iv in self.books.values()
                   for lo, hi in iv)

    # -- advertised upstream intervals ------------------------------------
    def route_for(self, k: int) -> Optional[int]:
        """Parent whose books cover phase k; else the interval with the
        largest lo <= k; else the earliest parent (pass-through routing can
        always make progress toward the head)."""
        best = None
        for lo, hi, par in self.adv:
            if lo <= k and (hi is None or k < hi):
                return par
            if lo <= k and (best is None or lo >= best[0]):
                best = (lo, par)
        if best is not None:
            return best[1]
        if self.adv:
            return self.adv[0][2]
        return None

    def adv_covers(self, k: int) -> bool:
        return any(lo <= k and (hi is None or k < hi)
                   for lo, hi, _ in self.adv)

    def adv_open_iv(self) -> Optional[List[Optional[int]]]:
        for iv in self.adv:
            if iv[1] is None:
                return iv
        return None

    def adv_open(self, lo: int, parent: int) -> None:
        assert self.adv_open_iv() is None, "double-open advertised interval"
        self.adv.append([lo, None, parent])
        self.adv.sort(key=lambda iv: iv[0])

    def adv_close(self, hi: int) -> int:
        """Close the open interval at max(lo, hi); returns the actual end
        (the from_phase to use in the CHILD_DEL — mirrors book_del)."""
        iv = self.adv_open_iv()
        assert iv is not None, "no open advertised interval"
        end = max(iv[0], hi)
        iv[1] = end
        return end

    def book_add(self, child: int, from_phase: int) -> None:
        self.books.setdefault(child, []).append([from_phase, None])

    def book_del(self, child: int, from_phase: int) -> None:
        ivs = self.books.setdefault(child, [])
        for iv in reversed(ivs):
            if iv[1] is None:
                iv[1] = max(iv[0], from_phase)
                return
        # DEL for an interval we never opened (books lag): record empty
        ivs.append([from_phase, from_phase])

    def signaler_active(self, k: int) -> bool:
        if self.lid != SCSL or not self.member:
            return False
        if k < self.first_phase:
            return False
        return self.dereg_phase is None or k < self.dereg_phase


class PhaserActor(Actor):
    """One per participant task; also the base for the HEAD sentinel."""

    def __init__(self, rank: int, net: Network, mode: str, *,
                 phaser: "DistPhaser"):
        super().__init__(rank, net)
        self.mode = mode
        self.ph = phaser
        self.sc = ListState(SCSL, rank)
        self.sn = ListState(SNSL, rank)
        self.sc.member = mode in (SIG_MODE, SIG_WAIT) or rank == HEAD
        self.sn.member = mode in (WAIT_MODE, SIG_WAIT) or rank == HEAD
        self.sig_next = 0           # next phase this task will signal
        self.wait_next = 0          # next phase this task will wait on
        self.presig = 0             # signals issued before eager insert done
        self.pending_drop = False   # drop() issued before eager insert done
        self.async_children_attached: set = set()
        # HEAD-only accounting
        self.expected_base = 0
        self.deltas: Dict[int, int] = {}
        self.head_released = -1

    # ------------------------------------------------------------------ util
    def st(self, lid: int) -> ListState:
        return self.sc if lid == SCSL else self.sn

    @property
    def is_head(self) -> bool:
        return self.rank == HEAD

    def _send(self, dst: int, msg: M.Msg) -> None:
        self.send(dst, msg)

    # ------------------------------------------------------------- public API
    def local_signal(self) -> None:
        """Task-level signal(): contribute +1 for phase ``sig_next``."""
        assert self.sc.member and not self.sc.departed
        if not self.sc.joined:
            # Eager insert still in flight: the first phase this task is
            # registered for is unknown until MURS_ACK. Buffer locally;
            # applied in order starting at first_phase on join.
            self.presig += 1
            return
        k = self.sig_next
        self.sig_next += 1
        self.sc.selfsig.add(k)
        self.sc.buf[k] = self.sc.buf.get(k, 0) + 1
        # phase-watermark hook (obs plane): facades that track live
        # watermarks implement it; plain facades don't pay for it
        cb = getattr(self.ph, "on_local_signal", None)
        if cb is not None:
            cb(self.rank, k)
        self._try_close_sc()

    def local_drop(self) -> None:
        """Deregister from the phaser; level-by-level unlink (paper §2)."""
        if (self.sc.member and not self.sc.joined) or \
                (self.sn.member and not self.sn.joined):
            self.pending_drop = True  # executed once eager insert completes
            return
        if self.sc.demote_stop or self.sn.demote_stop:
            # a demotion unlink is in flight: its driver state (dropping,
            # unlink_level) is busy — run the drop when it completes
            self.pending_drop = True
            return
        if self.sc.member and not self.sc.dropping:
            self.sc.dropping = True
            self.sc.dereg_phase = self.sig_next
            par = self.sc.route_for(self.sig_next)
            if par is not None:
                self._send(par, M.DEREG(self.rank, par,
                                        phase=self.sig_next, delta=-1))
            self._unlink_next_level(self.sc)
        if self.sn.member and not self.sn.dropping:
            self.sn.dropping = True
            self._unlink_next_level(self.sn)

    def local_demote(self) -> None:
        """Straggler demotion: unlink every express lane but KEEP the
        level-0 membership — the node becomes a leaf of the SCSL reduce
        tree (fewest dependents) while still signaling every phase. The
        same top-down UNL driver as deletion, stopped at level 1; no
        DEREG (the head's expectation is unchanged)."""
        for st in (self.sc, self.sn):
            if not st.member or st.departed or st.dropping:
                continue
            st.target_height = 1
            if st.height <= 1:
                continue
            st.dropping = True          # lanes >= 1 behave as leaving
            st.demote_stop = 1
            st.unlink_level = None
            self._unlink_next_level(st)

    def local_promote_to(self, height: int) -> None:
        """Reverse a demotion: restore the drawn target height and walk
        the lazy MULS promotions back up."""
        for st in (self.sc, self.sn):
            if not st.member or st.departed or st.dropping:
                continue
            st.target_height = height
            self.start_promotion(st.lid)

    def start_insert(self, new_id: int, lid: int) -> None:
        """Initiate the eager insertion search from this (member) node."""
        st = self.st(lid)
        assert st.member and st.joined
        self.handle(M.TUS(self.rank, self.rank, key=new_id, new_id=new_id,
                          lid=lid))

    def start_promotion(self, lid: int) -> None:
        st = self.st(lid)
        if st.height < st.target_height and not st.dropping:
            self._muls_walk(st, st.height)

    # ------------------------------------------------------------ dispatcher
    def handle(self, msg: M.Msg) -> None:
        # A member whose own eager insert is still in flight cannot serve
        # protocol traffic (its links/routing are uninitialized and the
        # MURS_ACK would clobber anything it set): defer everything except
        # the join ack itself; replayed in _on_MURS_ACK.
        lid = getattr(msg, "lid", None)
        if lid is not None and msg.kind not in ("MURS_ACK", "AT"):
            st = self.st(lid)
            if st.member and not st.joined:
                st.join_defer.append(msg)
                return
        h = getattr(self, f"_on_{msg.kind}", None)
        assert h is not None, f"no handler for {msg.kind}"
        h(msg)

    # ------------------------------------------------------------- search
    def _on_TUS(self, m: M.TUS) -> None:
        st = self.st(m.lid)
        if st.departed:
            tgt = st.prv[0] if st.prv[0] is not None else HEAD
            self._send(tgt, m.replace(src=self.rank, dst=tgt))
            return
        if self.rank != HEAD and self.rank >= m.key:
            # ascend-left toward a node with key < target
            tgt = st.prv[st.top]
            assert tgt is not None
            self._send(tgt, m.replace(src=self.rank, dst=tgt))
        else:
            self._descend(st, m.key, st.top, m.new_id)

    def _on_TDS(self, m: M.TDS) -> None:
        st = self.st(m.lid)
        if st.departed:
            tgt = st.prv[0] if st.prv[0] is not None else HEAD
            self._send(tgt, M.TUS(self.rank, tgt, key=m.key, new_id=m.new_id,
                                  lid=m.lid))
            return
        # resume from OUR top lane, not the arrival lane: a rightward
        # walker at y < key may climb onto any of y's express lanes (all
        # its future hops stay < key) — capping at the arrival lane would
        # degenerate the search into a level-0 walk, O(n) not O(log n)
        self._descend(st, m.key, st.top, m.new_id)

    def _descend(self, st: ListState, key: int, level: int,
                 new_id: int) -> None:
        l = level
        while l >= 0:
            nk = st.nxt[l]
            if nk is not None and nk < key:
                self._send(nk, M.TDS(self.rank, nk, key=key, level=l,
                                     new_id=new_id, lid=st.lid))
                return
            l -= 1
        self._splice_level0(st, new_id)

    # ------------------------------------------------------------- splice
    def _splice_level0(self, st: ListState, new_id: int) -> None:
        """We are the level-0 predecessor: fast single-link-modify."""
        if st.unl0_sent:
            # our level-0 UNL (with its succ snapshot) is in flight: a
            # splice now would diverge the chain views (the bypassing
            # predecessor and we would each own a fork). Defer; flushed
            # as a fresh search from the bypassing pred at UNL_ACK.
            st.splice_defer.append(new_id)
            return
        succ = st.nxt[0]
        st.nxt[0] = new_id
        if st.lid == SCSL:
            first = st.closed + 1 if not self.is_head else self.head_released + 1
            st.book_add(new_id, first)
        else:
            first = self.st(SNSL).released + 1
            st.book_add(new_id, first)
        rel = self.st(SNSL).released if st.lid == SNSL else -1
        self._send(new_id, M.MURS_ACK(self.rank, new_id, new_id=new_id,
                                      succ=succ, first_phase=first,
                                      released=rel, lid=st.lid))
        if succ is not None:
            self._send(succ, M.PRV(self.rank, succ, level=0, prv=new_id,
                                   effective=first, lid=st.lid))

    def _on_MURS(self, m: M.MURS) -> None:
        # Direct splice request (initiator already adjacent); same path.
        self._splice_level0(self.st(m.lid), m.new_id)

    def _on_MURS_ACK(self, m: M.MURS_ACK) -> None:
        st = self.st(m.lid)
        st.height = 1
        st.nxt = [m.succ]
        st.prv = [m.src]
        st.joined = True
        st.first_phase = m.first_phase
        st.closed = m.first_phase - 1  # phases before our membership
        st.adv_open(m.first_phase, m.src)
        st.target_height = self.ph.height_of(self.rank)
        if st.lid == SCSL:
            self.sig_next = m.first_phase
            # ENSP: activate signal edge + push the +1 delta toward the head
            self._send(m.src, M.ENSP(self.rank, m.src, phase=m.first_phase,
                                     delta=+1, lid=SCSL))
            # replay signals issued while the insert was in flight
            cb = getattr(self.ph, "on_local_signal", None)
            while self.presig > 0:
                self.presig -= 1
                k = self.sig_next
                self.sig_next += 1
                st.selfsig.add(k)
                st.buf[k] = st.buf.get(k, 0) + 1
                if cb is not None:
                    cb(self.rank, k)
            self._try_close_sc()
        else:
            st.released = max(st.released, m.released)
            self.wait_next = max(self.wait_next, m.first_phase)
            if st.released >= 0:
                cb = getattr(self.ph, "on_wait_advance", None)
                if cb is not None:
                    cb(self.rank, st.released)
        parent = self.ph.async_parent.get(self.rank)
        if parent is not None and parent != self.rank \
                and self.ph.lists_done(self.rank):
            self._send(parent, M.AT(self.rank, parent, new_id=self.rank,
                                    first_phase=m.first_phase, lid=st.lid))
        # replay structural traffic that arrived before we joined
        deferred = st.join_defer
        st.join_defer = []
        for msg in deferred:
            self.handle(msg)
        if self.pending_drop and self.ph.lists_done(self.rank):
            self.pending_drop = False
            self.local_drop()
            return
        self.start_promotion(st.lid)

    def _on_AT(self, m: M.AT) -> None:
        self.async_children_attached.add(m.new_id)

    def _on_ENSP(self, m: M.ENSP) -> None:
        # Registration delta: head applies, others forward along the parent
        # edge covering the delta's phase — that chain is the one whose
        # closing reports gate the head's release of that phase, so the
        # delta provably arrives before the phase can be released.
        if self.is_head:
            self.deltas[m.phase] = self.deltas.get(m.phase, 0) + m.delta
            self._try_release_head()
            return
        st = self.st(m.lid)
        par = st.route_for(m.phase)
        assert par is not None
        self._send(par, m.replace(src=self.rank, dst=par))

    def _on_DEREG(self, m: M.DEREG) -> None:
        if self.is_head:
            self.deltas[m.phase] = self.deltas.get(m.phase, 0) + m.delta
            self._try_release_head()
            return
        st = self.st(m.lid)
        par = st.route_for(m.phase)
        assert par is not None
        self._send(par, m.replace(src=self.rank, dst=par))

    # ------------------------------------------------------- lazy promotion
    def _muls_walk(self, st: ListState, level: int) -> None:
        """Walk left along lane level-1 for our lane-``level`` predecessor."""
        tgt = st.prv[level - 1]
        assert tgt is not None
        self._send(tgt, M.MULS1(self.rank, tgt, level=level,
                                new_id=self.rank, lid=st.lid))

    def _on_MULS1(self, m: M.MULS1) -> None:
        st = self.st(m.lid)
        if st.departed or (not self.is_head and st.height <= m.level):
            # not on the lane: hand-over-hand, keep walking left
            tgt = st.prv[min(m.level - 1, st.top)] if not st.departed else st.prv[0]
            tgt = tgt if tgt is not None else HEAD
            self._send(tgt, m.replace(src=self.rank, dst=tgt))
            return
        if st.dropping:
            # leaving this lane: granting would race our unlink, and
            # bouncing the walker left would livelock (the grantor keeps
            # re-offering us as succ). Defer; flushed to the bypassing
            # predecessor when this level's unlink completes.
            st.defer_q.setdefault(m.level, []).append(m.new_id)
            return
        if m.level in st.latch:
            st.latch_q.setdefault(m.level, []).append(m.new_id)
            return
        st.latch[m.level] = m.new_id
        succ = st.nxt[m.level] if m.level < len(st.nxt) else None
        self._send(m.new_id, M.MULS2(self.rank, m.new_id, level=m.level,
                                     succ=succ, lid=m.lid))

    def _on_MULS2(self, m: M.MULS2) -> None:
        st = self.st(m.lid)
        if st.dropping or st.height != m.level \
                or st.target_height <= m.level:
            # leaving, or the walk went stale (a demotion shrank our
            # height / target while the MULS1 was in flight): decline —
            # the grantor releases its latch and serves the next walker
            self._send(m.src, M.MULS3(self.rank, m.src, level=m.level,
                                      new_id=self.rank, commit=False,
                                      lid=m.lid))
            return
        if m.succ is not None and m.succ < self.rank:
            # a closer predecessor was spliced concurrently: abort, re-aim
            self._send(m.src, M.MULS3(self.rank, m.src, level=m.level,
                                      new_id=self.rank, commit=False,
                                      lid=m.lid))
            self._send(m.succ, M.MULS1(self.rank, m.succ, level=m.level,
                                       new_id=self.rank, lid=m.lid))
            return
        st.nxt.append(m.succ)
        st.prv.append(m.src)
        st.height += 1
        self._send(m.src, M.MULS3(self.rank, m.src, level=m.level,
                                  new_id=self.rank, commit=True, lid=m.lid))
        if m.succ is not None:
            self._send(m.succ, M.PRV(self.rank, m.succ, level=m.level,
                                     prv=self.rank,
                                     effective=st.closed + 1, lid=m.lid))
        # our own signal edge moved: new parent is the lane-level predecessor
        if st.lid == SCSL:
            self._reparent(st, m.src, st.closed + 1)
        else:
            self._reparent(st, m.src, st.released + 1)
        self.start_promotion(st.lid)

    def _on_MULS3(self, m: M.MULS3) -> None:
        st = self.st(m.lid)
        if m.commit:
            st.nxt[m.level] = m.new_id
        del st.latch[m.level]
        if st.dropping:
            # we are leaving: queued walkers join the deferred set (flushed
            # at this level's unlink), parked UNLs proceed, and any paused
            # unlink resumes
            st.defer_q.setdefault(m.level, []).extend(
                st.latch_q.pop(m.level, []))
            for unl in st.unl_park.pop(m.level, []):
                self._on_UNL(unl)
            if st.unlink_waiting and st.unlink_level == m.level:
                st.unlink_waiting = False
                self._unlink_next_level(st)
            return
        for unl in st.unl_park.pop(m.level, []):
            self._on_UNL(unl)
        q = st.latch_q.get(m.level, [])
        if q:
            nxt = q.pop(0)
            self.handle(M.MULS1(nxt, self.rank, level=m.level, new_id=nxt,
                                lid=m.lid))

    # --------------------------------------------------------------- unlink
    def _unlink_next_level(self, st: ListState) -> None:
        if st.unlink_level is None:
            st.unlink_level = st.top
        l = st.unlink_level
        if st.demote_stop > 0 and l < st.demote_stop:
            # demotion complete: level 0 kept, node stays a live member
            st.dropping = False
            st.demote_stop = 0
            st.unlink_level = None
            st.unlink_waiting = False
            if self.pending_drop and not (self.sc.demote_stop
                                          or self.sn.demote_stop):
                self.pending_drop = False
                self.local_drop()
            return
        if l < 0:
            st.departed = True
            self._finalize_drop(st)
            return
        if l in st.latch:
            # an in-flight splice holds this level: pause; the MULS3 that
            # releases the latch resumes the unlink (latch/unlink mutual
            # exclusion — required for lane integrity under concurrent
            # insert+delete)
            st.unlink_waiting = True
            return
        pred = st.prv[l]
        assert pred is not None
        st.unl_sent_succ = st.nxt[l]
        if l == 0:
            st.unl0_sent = True
        self._send(pred, M.UNL(self.rank, pred, level=l, node=self.rank,
                               succ=st.nxt[l], lid=st.lid))

    def _on_UNL(self, m: M.UNL) -> None:
        st = self.st(m.lid)
        if not st.departed and (self.is_head or st.height > m.level) \
                and m.level in st.latch:
            # an open MULS latch at this level means a splice (whose
            # MULS2 carried our pre-bypass successor) may still commit
            # and re-link the departing node: park the UNL until the
            # latch releases (processed in _on_MULS3)
            st.unl_park.setdefault(m.level, []).append(m)
            return
        if st.departed or (not self.is_head and st.height <= m.level) \
                or st.nxt[m.level] != m.node:
            # stale pred (we moved/were bypassed): forward toward the node's
            # current predecessor via our own link at that level
            tgt = st.nxt[m.level] if (not st.departed and
                                      (self.is_head or st.height > m.level)) \
                else st.prv[0]
            tgt = tgt if tgt is not None else HEAD
            if tgt != m.node:
                self._send(tgt, m.replace(src=self.rank, dst=tgt))
                return
        st.nxt[m.level] = m.succ
        if m.succ is not None:
            eff = (st.closed + 1) if st.lid == SCSL else (st.released + 1)
            self._send(m.succ, M.PRV(self.rank, m.succ, level=m.level,
                                     prv=self.rank, effective=eff, lid=m.lid))
        self._send(m.node, M.UNL_ACK(self.rank, m.node, level=m.level,
                                     node=m.node, lid=m.lid))

    def _on_UNL_ACK(self, m: M.UNL_ACK) -> None:
        st = self.st(m.lid)
        if st.unlink_level != m.level:
            return   # late/duplicate ack (NXT-walk bypasses re-ack)
        cur = st.nxt[m.level]
        snap = st.unl_sent_succ
        if cur != snap:
            # our nxt changed after the UNL snapshot (we bypassed a
            # concurrently-deleting successor, or a chained NXT handed us
            # a node): the bypassing predecessor linked to the STALE succ.
            if cur is not None:
                # merge our live successor in (ordered NXT walk)
                self._send(m.src, M.NXT(self.rank, m.src, level=m.level,
                                        nxt=cur, lid=st.lid))
            elif snap is not None:
                # our successor left the lane entirely: the pred must
                # bypass the stale snapshot node to end-of-lane
                self._send(m.src, M.UNL(self.rank, m.src, level=m.level,
                                        node=snap, succ=None, lid=st.lid))
        if m.level == 0:
            # flush deferred splices as fresh searches from the live pred
            for nid in st.splice_defer:
                self._send(m.src, M.TUS(self.rank, m.src, key=nid,
                                        new_id=nid, lid=st.lid))
            st.splice_defer = []
        if st.lid == SCSL and m.level > 0 and m.level == st.top:
            # our top drops: re-parent to the predecessor at the new top
            self._reparent(st, st.prv[m.level - 1], st.closed + 1)
        # flush walkers deferred on this level to the bypassing pred
        for nid in st.defer_q.pop(m.level, []):
            self._send(m.src, M.MULS1(self.rank, m.src, level=m.level,
                                      new_id=nid, lid=st.lid))
        if m.level > 0:
            st.height = m.level
            st.nxt = st.nxt[:m.level]
            st.prv = st.prv[:m.level]
        st.unlink_level = m.level - 1
        self._unlink_next_level(st)

    def _on_NXT(self, m: M.NXT) -> None:
        """Ordered merge-walk: insert the handed-over node at its sorted
        position (my chain may have grown since the hand-over was sent;
        a blind overwrite would orphan the newer splice)."""
        st = self.st(m.lid)
        if st.departed or st.height <= m.level:
            # we are off this lane — the sender's link to us is stale:
            # have it bypass us directly to the handed-over node
            self._send(m.src, M.UNL(self.rank, m.src, level=m.level,
                                    node=self.rank, succ=m.nxt, lid=m.lid))
            return
        cur = st.nxt[m.level]
        if cur == m.nxt:
            if st.dropping and m.level >= st.demote_stop:
                # the handed node is already our successor, but WE are
                # leaving this lane (a demoting node keeps the lanes
                # below its demote_stop): the sender must bypass us
                self._send(m.src, M.UNL(self.rank, m.src, level=m.level,
                                        node=self.rank, succ=m.nxt,
                                        lid=m.lid))
            return                          # already linked
        if cur is not None and cur < m.nxt:
            # walk right: the handed node sorts after my successor
            self._send(cur, m.replace(src=self.rank, dst=cur))
            return
        st.nxt[m.level] = m.nxt
        eff = (st.closed + 1) if st.lid == SCSL else (st.released + 1)
        self._send(m.nxt, M.PRV(self.rank, m.nxt, level=m.level,
                                prv=self.rank, effective=eff, lid=m.lid))
        if cur is not None:
            # my old successor re-attaches after the handed node (its own
            # walk continues the merge down its chain)
            self._send(m.nxt, M.NXT(self.rank, m.nxt, level=m.level,
                                    nxt=cur, lid=m.lid))

    def _finalize_drop(self, st: ListState) -> None:
        if st.lid == SCSL:
            self._try_close_sc()
        # SNSL ghosts keep forwarding ADVs until children re-parent; nothing
        # further to do here.

    # ------------------------------------------------- neighbor/books events
    def _on_PRV(self, m: M.PRV) -> None:
        st = self.st(m.lid)
        if st.departed or st.height <= m.level:
            return  # stale
        st.prv[m.level] = m.prv
        if m.level == st.top:
            self._reparent(st, m.prv, m.effective)

    def _reparent(self, st: ListState, new_parent: int,
                  effective: int) -> None:
        """Move the open advertised interval to ``new_parent``.

        SNSL: immediate switch (ADV is idempotent-monotone; a catch-up ADV
        from the new parent repairs any gap).

        SCSL: two-way handshake. Fire-and-forget switching is UNSOUND: the
        new parent may have already closed (reported) the phases we would
        hand it, silently breaking the closing-report obligation chain to
        the head — and with it the safety of report-gated release against
        in-flight registration deltas. Instead we CHILD_ADD(from=f0) and
        keep the old interval open until the parent's CHILD_ADD_ACK grants
        coverage from ``granted = max(f0, parent.closed+1)``; phases below
        the grant stay with the old parent, whose book is still open."""
        iv = st.adv_open_iv()
        if iv is None:
            # fully deregistered (final CHILD_DEL already sent): no further
            # combining obligations to move
            return
        if st.lid == SNSL:
            old = iv[2]
            if old == new_parent:
                return
            switch = max(effective, st.released + 1, iv[0])
            end = st.adv_close(switch)
            self._send(old, M.CHILD_DEL(self.rank, old, from_phase=end,
                                        lid=st.lid))
            st.adv_open(end, new_parent)
            self._send(new_parent, M.CHILD_ADD(self.rank, new_parent,
                                               from_phase=end, lid=st.lid))
            return
        # ---- SCSL handshake ----
        if st.rp_pending is not None:
            if st.rp_pending != new_parent:
                st.rp_queue = (new_parent, effective)
            return
        if iv[2] == new_parent:
            return
        f0 = max(effective, st.closed + 1, iv[0])
        st.rp_pending = new_parent
        self._send(new_parent, M.CHILD_ADD(self.rank, new_parent,
                                           from_phase=f0, lid=st.lid))

    def _on_CHILD_ADD_ACK(self, m: M.CHILD_ADD_ACK) -> None:
        """Complete the SCSL re-parent: close the old interval at the
        granted phase and open [granted, None) at the granting parent
        (which may differ from the node we asked — departed relays forward
        the CHILD_ADD to their own parent)."""
        st = self.st(m.lid)
        st.rp_pending = None
        iv = st.adv_open_iv()
        if iv is None:
            # dropped while the handshake was in flight: release the
            # speculative book the granter opened for us
            self._send(m.src, M.CHILD_DEL(self.rank, m.src,
                                          from_phase=m.granted, lid=m.lid))
            return
        old = iv[2]
        if old == m.src:
            # the relayed request cycled back to our current parent: drop
            # the speculative grant (CHILD_DEL closes the granter's newest
            # open interval for us) and keep our existing interval
            self._send(m.src, M.CHILD_DEL(self.rank, m.src,
                                          from_phase=m.granted, lid=m.lid))
        else:
            end = st.adv_close(max(m.granted, iv[0]))
            self._send(old, M.CHILD_DEL(self.rank, old, from_phase=end,
                                        lid=st.lid))
            st.adv_open(end, m.src)
            # Catch-up: phases in [granted, closed] were discharged via the
            # old route while the handshake was in flight; the granter's
            # book covers them — zero-count closing reports clear its gate.
            for k in range(end, st.closed + 1):
                self._send(m.src, M.SIG(self.rank, m.src, phase=k, count=0,
                                        closing=True, lid=SCSL))
        if st.rp_queue is not None:
            nxt, eff = st.rp_queue
            st.rp_queue = None
            self._reparent(st, nxt, eff)
        self._try_close_sc()

    def _on_CHILD_ADD(self, m: M.CHILD_ADD) -> None:
        st = self.st(m.lid)
        child = m.child if m.child is not None else m.src
        if st.lid == SNSL:
            st.book_add(child, m.from_phase)
            # catch the new child up on releases it may have missed
            rel = self.head_released if self.is_head else st.released
            if rel >= 0:
                self._send(child, M.ADV(self.rank, child, phase=rel,
                                        lid=SNSL))
            return
        # ---- SCSL: grant (or relay) ----
        if not self.is_head and (st.departed or st.final_childdel_sent):
            # no chain of our own: relay toward our last known parent
            par = st.route_for(m.from_phase)
            tgt = par if par is not None else HEAD
            self._send(tgt, M.CHILD_ADD(self.rank, tgt,
                                        from_phase=m.from_phase,
                                        child=child, lid=SCSL))
            return
        base = self.head_released if self.is_head else st.closed
        granted = max(m.from_phase, base + 1)
        st.book_add(child, granted)
        self._send(child, M.CHILD_ADD_ACK(self.rank, child, granted=granted,
                                          lid=SCSL))

    def _on_CHILD_DEL(self, m: M.CHILD_DEL) -> None:
        st = self.st(m.lid)
        st.book_del(m.src, m.from_phase)
        if st.lid == SCSL:
            if self.is_head:
                self._try_release_head()
            else:
                self._try_close_sc()

    # ------------------------------------------------------------ signaling
    def _will_close(self, st: ListState, k: int) -> bool:
        """Will we ever emit our own aggregate for phase k? If not, any count
        for k must be passed through immediately (never parked in buf)."""
        return (st.signaler_active(k) or st.any_coverage(k)
                or st.adv_covers(k))

    def _on_SIG(self, m: M.SIG) -> None:
        st = self.sc
        if self.is_head:
            st.buf[m.phase] = st.buf.get(m.phase, 0) + m.count
            if m.closing and st.covers(m.src, m.phase):
                st.reported.setdefault(m.phase, set()).add(m.src)
            self._try_release_head()
            return
        if m.phase <= st.closed or not self._will_close(st, m.phase):
            # already reported (or never will): pass through toward the head
            par = st.route_for(m.phase)
            assert par is not None
            self._send(par, M.SIG(self.rank, par, phase=m.phase,
                                  count=m.count, closing=False, lid=SCSL))
            return
        st.buf[m.phase] = st.buf.get(m.phase, 0) + m.count
        if m.closing and st.covers(m.src, m.phase):
            st.reported.setdefault(m.phase, set()).add(m.src)
        self._try_close_sc()

    def _try_close_sc(self) -> None:
        st = self.sc
        if not st.joined and not st.member:
            return
        self._close_loop(st)
        self._maybe_final_childdel(st)

    def _close_loop(self, st: ListState) -> None:
        while True:
            k = st.closed + 1
            need_self = st.signaler_active(k)
            if need_self and k not in st.selfsig:
                return
            kids = st.active_children(k)
            if any(c not in st.reported.get(k, ()) for c in kids):
                return
            # Deregistered: phases >= K (our interval's eventual close
            # point) are owned by the final-CHILD_DEL epilogue — do not
            # proactively close them (unbounded otherwise). Phases below
            # an already-CLOSED advertised interval's end are firm
            # promises (e.g. a re-parent grant clamped the close point
            # upward) and must still be reported.
            if not need_self and st.dereg_phase is not None and not kids:
                K = max(st.dereg_phase, st.max_to())
                promised = max((iv[1] for iv in st.adv
                                if iv[1] is not None), default=0)
                if k >= max(K, promised):
                    return
            # Contract with the parent: a closing report for exactly the
            # phases covered by our advertised intervals — which mirror the
            # parent's books by construction, so neither side ever waits
            # for a report the other will not produce.
            expects_us = st.adv_covers(k)
            if not (need_self or kids or expects_us):
                # No combining obligations at k. If anything pends at or
                # beyond k, flush-and-advance (pass-through) so parked
                # counts can never wedge behind an idle phase.
                if any(p >= k for p in st.buf):
                    par = st.route_for(k)
                    if par is None:
                        return
                    total = st.buf.pop(k, 0)
                    if total:
                        self._send(par, M.SIG(self.rank, par, phase=k,
                                              count=total, closing=False,
                                              lid=SCSL))
                    st.reported.pop(k, None)
                    st.closed = k
                    continue
                return
            par = st.route_for(k)
            if par is None:
                return
            total = st.buf.pop(k, 0)
            if expects_us or total:
                self._send(par, M.SIG(self.rank, par, phase=k, count=total,
                                      closing=bool(expects_us), lid=SCSL))
            st.reported.pop(k, None)
            st.closed = k

    def _maybe_final_childdel(self, st: ListState) -> None:
        """Deregistration epilogue: once every child interval is closed and
        all covered phases are reported, close our own open advertised
        interval — the parent stops expecting us from K on."""
        if (st.dropping and st.departed and not st.final_childdel_sent
                and st.all_children_closed()
                and st.closed >= st.max_to() - 1
                and st.adv_open_iv() is not None):
            K = max(st.dereg_phase if st.dereg_phase is not None else 0,
                    st.max_to())
            end = st.adv_close(K)
            par = st.route_for(end)
            if par is not None:
                self._send(par, M.CHILD_DEL(self.rank, par,
                                            from_phase=end, lid=SCSL))
            st.final_childdel_sent = True
            # any phases still covered (closed+1 .. end-1) will be reported
            # by the regular close loop; counts beyond flow as pass-through
            self._close_loop(st)

    # HEAD: count-based completion --------------------------------------
    def _expected(self, k: int) -> int:
        return self.expected_base + sum(v for p, v in self.deltas.items()
                                        if p <= k)

    def _try_release_head(self) -> None:
        assert self.is_head
        while True:
            k = self.head_released + 1
            exp = self._expected(k)
            got = self.sc.buf.get(k, 0)
            assert got <= max(exp, self._expected_final_bound(k)), \
                "over-collection: conservation violated"
            if exp == 0 or got < exp:
                return
            # Completion is count-based AND report-based: every book-child
            # interval covering k must have delivered its closing report.
            # This is what makes release race-free against in-flight
            # registration deltas — a child that admitted a new signaler
            # for phase k withholds its own closing report for k until the
            # new task's report arrives, and the new task's ENSP (+1)
            # FIFO-precedes its first count on every channel toward the
            # head. Count-only release could fire between a DEREG and a
            # concurrent ENSP (premature phase advance).
            kids = self.sc.active_children(k)
            if any(c not in self.sc.reported.get(k, ()) for c in kids):
                return
            self.sc.buf.pop(k, None)
            self.sc.reported.pop(k, None)
            self.head_released = k
            self.ph.on_release(k)
            self._fanout_adv(k)

    def _expected_final_bound(self, k: int) -> int:
        # upper bound used only for the conservation assertion
        return self.expected_base + sum(abs(v) for v in self.deltas.values())

    def _fanout_adv(self, k: int) -> None:
        for c in list(self.sn.books):
            if any(True for _ in self.sn.books[c]):
                self._send(c, M.ADV(self.rank, c, phase=k, lid=SNSL))

    # ---------------------------------------------------------- notification
    def _on_ADV(self, m: M.ADV) -> None:
        st = self.sn
        if m.phase <= st.released:
            return
        st.released = m.phase
        # wait-watermark hook: phase m.phase is now released to this
        # participant — the signal->here gap is its blocked-on-WAIT time
        cb = getattr(self.ph, "on_wait_advance", None)
        if cb is not None:
            cb(self.rank, m.phase)
        for c in list(st.books):
            self._send(c, M.ADV(self.rank, c, phase=m.phase, lid=SNSL))


class DistPhaser:
    """Facade: builds the phaser, owns the network, exposes the task API.

    The initial team topology is derived from the deterministic skip-list
    oracle (every rank computes it identically — the data-plane adaptation of
    the paper's collective creation step; ``core/creation.py`` reproduces the
    recursive-doubling exchange itself and verifies it converges to the same
    structure)."""

    def __init__(self, n: int, *, modes: Optional[Dict[int, str]] = None,
                 p: float = 0.5, seed: int = 0, max_height: int = 32,
                 net: Optional[Network] = None):
        self.n = n
        self.p = p
        self.seed = seed
        self.max_height = max_height
        self.net = net or Network()
        self.modes = {r: SIG_WAIT for r in range(n)}
        if modes:
            self.modes.update(modes)
        self.async_parent: Dict[int, int] = {}
        self.release_log: List[int] = []
        self.actors: Dict[int, PhaserActor] = {}
        # demoted keys: height pinned to 1 (leaf of the reduce tree);
        # part of the topology identity the oracle re-derives
        self.demoted: set = set()
        # optional monitor(ph, k) invoked at the release instant (modelcheck)
        self.release_monitor = None
        # optional WatermarkTracker (obs plane): installed by consumers
        # that want live phase watermarks (P2PPhaser.enable_watermarks)
        self.watermarks = None

        head = PhaserActor(HEAD, self.net, SIG_WAIT, phaser=self)
        self.actors[HEAD] = head
        self.net.register(head)
        for r in range(n):
            a = PhaserActor(r, self.net, self.modes[r], phaser=self)
            self.actors[r] = a
            self.net.register(a)

        sig_keys = [r for r in range(n) if self.modes[r] in (SIG_MODE, SIG_WAIT)]
        wait_keys = [r for r in range(n) if self.modes[r] in (WAIT_MODE, SIG_WAIT)]
        self._init_list(SCSL, sig_keys)
        self._init_list(SNSL, wait_keys)
        head.expected_base = len(sig_keys)

    # ------------------------------------------------------------- topology
    def height_of(self, key: int) -> int:
        if key in self.demoted:
            return 1
        return det_height(key, p=self.p, max_height=self.max_height,
                          seed=self.seed)

    def oracle(self, keys) -> SkipList:
        return SkipList.build(keys, p=self.p, max_height=self.max_height,
                              seed=self.seed, leaf_keys=self.demoted)

    def _init_list(self, lid: int, keys: List[int]) -> None:
        sl = self.oracle(keys)
        for k in [HEAD] + keys:
            node = sl.nodes[k]
            st = self.actors[k].st(lid)
            st.member = True
            st.joined = True
            st.height = node.height if k != HEAD else node.height
            st.target_height = st.height
            st.nxt = list(node.nxt)
            st.prv = list(node.prv)
            st.books = {c: [[0, None]] for c in sl.children(k)}
            par = sl.parent(k)
            if par is not None:
                st.adv = [[0, None, par]]
            if lid == SNSL:
                st.released = -1

    def lists_done(self, rank: int) -> bool:
        a = self.actors[rank]
        ok = True
        if a.sc.member:
            ok &= a.sc.joined
        if a.sn.member:
            ok &= a.sn.joined
        return ok

    # ------------------------------------------------------------- task API
    def signal(self, rank: int) -> None:
        self.actors[rank].local_signal()

    def drop(self, rank: int) -> None:
        self.actors[rank].local_drop()
        self.demoted.discard(rank)

    def demote(self, rank: int) -> None:
        """Pin ``rank`` to a leaf position (height 1) in both lists: the
        straggler keeps signaling but loses every dependent in the
        hierarchical combining tree. Structural work is the deletion
        unlink stopped at level 1 — no DEREG, no departure."""
        assert self.lists_done(rank), rank
        self.demoted.add(rank)
        self.actors[rank].local_demote()

    def repromote(self, rank: int) -> None:
        """Undo a demotion: restore the deterministic drawn height and
        run the lazy MULS promotions back up the lanes."""
        self.demoted.discard(rank)
        self.actors[rank].local_promote_to(self.height_of(rank))

    def async_add(self, parent: int, new_rank: int,
                  mode: str = SIG_WAIT) -> None:
        """Paper Fig. 2: ``parent`` asyncs ``new_rank`` onto the phaser."""
        assert new_rank not in self.actors or not any(
            self.actors[new_rank].st(l).member for l in (SCSL, SNSL))
        a = PhaserActor(new_rank, self.net, mode, phaser=self)
        self.actors[new_rank] = a
        self.net.register(a)
        self.modes[new_rank] = mode
        self.async_parent[new_rank] = parent
        if mode in (SIG_MODE, SIG_WAIT):
            a.sc.member = True
            init = parent if self.modes.get(parent) in (SIG_MODE, SIG_WAIT) \
                else HEAD
            self.actors[init].start_insert(new_rank, SCSL)
        if mode in (WAIT_MODE, SIG_WAIT):
            a.sn.member = True
            init = parent if self.modes.get(parent) in (WAIT_MODE, SIG_WAIT) \
                else HEAD
            self.actors[init].start_insert(new_rank, SNSL)

    def released(self, rank: Optional[int] = None) -> int:
        if rank is None:
            return self.actors[HEAD].head_released
        a = self.actors[rank]
        return a.sn.released if a.sn.member else self.actors[HEAD].head_released

    def on_release(self, k: int) -> None:
        self.release_log.append(k)
        if self.release_monitor is not None:
            self.release_monitor(self, k)

    # -------------------------------------------------- watermark hooks
    def on_local_signal(self, rank: int, phase: int) -> None:
        if self.watermarks is not None:
            self.watermarks.on_signal(rank, phase)

    def on_wait_advance(self, rank: int, phase: int) -> None:
        if self.watermarks is not None:
            self.watermarks.on_wait_advance(rank, phase)

    # ------------------------------------------------------------- driving
    def run(self, scheduler: Optional[Scheduler] = None,
            max_steps: int = 1_000_000) -> int:
        return (scheduler or FifoScheduler()).run(self.net, max_steps)

    def next(self, ranks=None, scheduler: Optional[Scheduler] = None) -> int:
        """Convenience: everyone signals, run to quiescence, phase advances."""
        for r in (ranks if ranks is not None else
                  [r for r in self.modes
                   if self.modes[r] in (SIG_MODE, SIG_WAIT)
                   and self.actors[r].sc.member
                   and not self.actors[r].sc.dropping]):
            self.signal(r)
        self.run(scheduler)
        return self.actors[HEAD].head_released

    # ------------------------------------------------------------ inspection
    def check_quiescent_invariants(self) -> None:
        """Structural + bookkeeping invariants at quiescence (used by tests
        and the model checker)."""
        assert self.net.idle()
        for lid in (SCSL, SNSL):
            keys = sorted(r for r, a in self.actors.items()
                          if r != HEAD and a.st(lid).member
                          and a.st(lid).joined and not a.st(lid).departed)
            # walk level-0 from head: must be exactly `keys` in order
            seen = []
            cur = self.actors[HEAD].st(lid).nxt[0]
            while cur is not None:
                seen.append(cur)
                cur = self.actors[cur].st(lid).nxt[0]
            assert seen == keys, f"lid={lid}: level-0 chain {seen} != {keys}"
            for l in range(1, self.max_height):
                lane = []
                st = self.actors[HEAD].st(lid)
                cur = st.nxt[l] if l < len(st.nxt) else None
                while cur is not None:
                    lane.append(cur)
                    nst = self.actors[cur].st(lid)
                    cur = nst.nxt[l] if l < nst.height else None
                expect = [k for k in keys
                          if self.actors[k].st(lid).height > l]
                assert lane == expect, \
                    f"lid={lid} lane {l}: {lane} != {expect}"
