"""Event-driven message-passing runtime for the distributed-phaser protocol.

Models an APGAS-style cluster: one actor per participant, FIFO channels per
(src, dst) pair, and a pluggable delivery scheduler. Three schedulers cover
the three uses of the runtime:

* ``RandomScheduler``  — seeded adversarial interleavings (property tests);
* ``FifoScheduler``    — deterministic round-robin (benchmarks, examples);
* external control     — the model checker drives ``deliver_from`` directly.

Complexity accounting: every message carries a Lamport-style ``depth`` so the
*critical path length* (the paper's time-complexity measure) is observable
independently of the interleaving; total message counts per kind give the
message complexity.
"""
from __future__ import annotations

import random
from collections import defaultdict, deque
from dataclasses import dataclass, field
from typing import Callable, Deque, Dict, List, Optional, Tuple

from .messages import Msg


@dataclass
class Envelope:
    msg: Msg
    depth: int  # critical-path hops accumulated when this message departs
    # span context (trace id, span id, tree depth) when the network has a
    # tracer attached — a plain tuple so it pickles across transports
    trace: Optional[tuple] = None
    # membership generation (incarnation) stamped by the partitioned
    # network at post time: after a non-cooperative eviction rebuilds the
    # survivors, in-flight frames of the old incarnation are fenced at
    # ingest instead of corrupting the fresh phase state
    gen: int = 0


class Actor:
    """Base actor. Subclasses implement ``handle(msg)`` and use ``send``."""

    def __init__(self, rank: int, net: "Network"):
        self.rank = rank
        self.net = net
        self.clock = 0  # Lamport critical-path clock (hops)

    def send(self, dst: int, msg: Msg) -> None:
        assert msg.src == self.rank and msg.dst == dst, (msg, self.rank, dst)
        env = Envelope(msg, self.clock + 1)
        tr = self.net.tracer
        if tr is not None:
            env.trace = tr.on_send(self.rank, msg, env.depth)
        self.net.post(env)

    def handle(self, msg: Msg) -> None:  # pragma: no cover - abstract
        raise NotImplementedError


class Network:
    """FIFO channels + stats. Delivery order across channels is the
    scheduler's choice; within a channel it is FIFO (matching the paper's
    point-to-point ordering assumption)."""

    def __init__(self):
        self.channels: Dict[Tuple[int, int], Deque[Envelope]] = defaultdict(deque)
        self.actors: Dict[int, Actor] = {}
        self.sent: Dict[str, int] = defaultdict(int)
        self.delivered: Dict[str, int] = defaultdict(int)
        self.max_depth = 0
        self.trace: Optional[List[Msg]] = None  # set to [] to record
        self.tracer = None  # obs.trace.Tracer: per-envelope span contexts

    # -- wiring -------------------------------------------------------------
    def register(self, actor: Actor) -> None:
        self.actors[actor.rank] = actor

    def post(self, env: Envelope) -> None:
        self.sent[env.msg.kind] += 1
        self.channels[(env.msg.src, env.msg.dst)].append(env)

    # -- delivery -----------------------------------------------------------
    def nonempty_channels(self) -> List[Tuple[int, int]]:
        return sorted(k for k, q in self.channels.items() if q)

    def deliver_from(self, channel: Tuple[int, int]) -> Msg:
        env = self.channels[channel].popleft()
        actor = self.actors[env.msg.dst]
        actor.clock = max(actor.clock, env.depth)
        self.max_depth = max(self.max_depth, env.depth)
        self.delivered[env.msg.kind] += 1
        if self.trace is not None:
            self.trace.append(env.msg)
        if self.tracer is not None and env.trace is not None:
            # closes the span AND makes it the handler's current context
            # (sends inside handle() become its children)
            self.tracer.on_deliver(env.trace, env.msg.dst)
        actor.handle(env.msg)
        return env.msg

    def idle(self) -> bool:
        return not any(self.channels.values())

    # -- stats ----------------------------------------------------------------
    def total_sent(self) -> int:
        return sum(self.sent.values())

    def reset_stats(self) -> None:
        self.sent.clear()
        self.delivered.clear()
        self.max_depth = 0
        for a in self.actors.values():
            a.clock = 0


class Scheduler:
    def step(self, net: Network) -> bool:  # pragma: no cover - abstract
        raise NotImplementedError

    def run(self, net: Network, max_steps: int = 10_000_000) -> int:
        """Drive to quiescence; returns number of deliveries."""
        n = 0
        while not net.idle():
            if not self.step(net):
                break
            n += 1
            if n > max_steps:
                raise RuntimeError("scheduler did not quiesce "
                                   f"(>{max_steps} deliveries)")
        return n


class FifoScheduler(Scheduler):
    """Deterministic round-robin over channels."""

    def __init__(self):
        self._rr = 0

    def step(self, net: Network) -> bool:
        chans = net.nonempty_channels()
        if not chans:
            return False
        net.deliver_from(chans[self._rr % len(chans)])
        self._rr += 1
        return True


class RandomScheduler(Scheduler):
    """Seeded adversarial interleaving: uniformly random channel each step."""

    def __init__(self, seed: int = 0):
        self.rng = random.Random(seed)

    def step(self, net: Network) -> bool:
        chans = net.nonempty_channels()
        if not chans:
            return False
        net.deliver_from(self.rng.choice(chans))
        return True


class PriorityScheduler(Scheduler):
    """Deliver non-focus messages eagerly/deterministically; used by the
    model checker's message-based decomposition (DESIGN.md §2): only the
    focus class branches, everything else collapses to one canonical order."""

    def __init__(self, focus_kinds: Tuple[str, ...]):
        self.focus = set(focus_kinds)

    def nonfocus_channels(self, net: Network) -> List[Tuple[int, int]]:
        return [c for c in net.nonempty_channels()
                if net.channels[c][0].msg.kind not in self.focus]

    def step(self, net: Network) -> bool:
        nf = self.nonfocus_channels(net)
        if nf:
            net.deliver_from(nf[0])
            return True
        chans = net.nonempty_channels()
        if not chans:
            return False
        net.deliver_from(chans[0])
        return True
