"""Phaser topologies compiled to static TPU collective schedules.

The data-plane adaptation of the paper (DESIGN.md §2): the SCSL/SNSL signal
flow becomes a *static schedule* of ``lax.ppermute`` rounds executed inside
``shard_map`` over a mesh axis. Three interchangeable gradient-sync
schedules:

* ``phaser_scsl``        — the paper-faithful topology: reduce up the SCSL
                           signal edges to the head, then diffuse down the
                           SNSL (broadcast). Single-port model: every device
                           receives at most one message per round, exactly
                           like the protocol's FIFO channels.
* ``recursive_doubling`` — the paper's *creation* exchange [2] reused as an
                           all-reduce: log2(n) XOR-partner rounds.
* ``halving_doubling``   — beyond-paper bandwidth-optimal variant:
                           recursive-halving reduce-scatter + recursive-
                           doubling all-gather (2·(n-1)/n data volume).
* ``xla_psum``           — XLA's native all-reduce (baseline).

Schedules are derived once (host side, from the deterministic skip-list
oracle) and are traced into the compiled step; topology changes (elastic
add/delete) swap the schedule at the next re-lower — the "lazy" phase of the
paper's two-phase structural protocol.
"""
from __future__ import annotations

import functools
import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from .skiplist import HEAD, SkipList


# ---------------------------------------------------------------------------
# Schedule derivation (host side, pure Python).
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class Schedule:
    """A sequence of ppermute rounds. ``rounds[r]`` = tuple of (src, dst)
    pairs, each a partial permutation (distinct srcs, distinct dsts)."""

    n: int
    rounds: Tuple[Tuple[Tuple[int, int], ...], ...]
    kind: str = "generic"

    @property
    def depth(self) -> int:
        return len(self.rounds)

    @property
    def messages(self) -> int:
        return sum(len(r) for r in self.rounds)

    def check(self) -> None:
        for r in self.rounds:
            srcs = [s for s, _ in r]
            dsts = [d for _, d in r]
            assert len(set(srcs)) == len(srcs), f"src collision in {r}"
            assert len(set(dsts)) == len(dsts), f"dst collision in {r}"
            assert all(0 <= s < self.n and 0 <= d < self.n
                       for s, d in r)


def _fold_head(sl: SkipList) -> Tuple[Dict[int, int], int]:
    """Map the virtual HEAD onto the lowest participant key (the designated
    head-signaler of the paper is a real task in the data plane)."""
    keys = sl.keys()
    assert keys, "empty topology"
    root = keys[0]
    parent = {}
    for k in keys:
        p = sl.parent(k)
        if k == root:
            continue
        parent[k] = root if p == HEAD else p
    return parent, root


def scsl_reduce_schedule(sl: SkipList, ranks: Sequence[int]) -> Schedule:
    """Single-port greedy schedule for the SCSL reduction (children before
    parent; one receive per device per round)."""
    parent, root = _fold_head(sl)
    rank_of = {k: i for i, k in enumerate(ranks)}
    children: Dict[int, List[int]] = {k: [] for k in list(parent) + [root]}
    for c, p in parent.items():
        children.setdefault(p, []).append(c)
    # critical-path weight: height of subtree below each node
    weight: Dict[int, int] = {}

    def w(k: int) -> int:
        if k not in weight:
            weight[k] = 1 + max((w(c) for c in children.get(k, [])),
                                default=0)
        return weight[k]

    for k in children:
        w(k)

    unsent = set(parent)                      # root never sends
    done_round: Dict[int, int] = {}           # node -> round it sent in
    rounds: List[Tuple[Tuple[int, int], ...]] = []
    r = 0
    while unsent:
        eligible: Dict[int, List[int]] = {}
        for k in unsent:
            if all(c in done_round and done_round[c] < r
                   for c in children.get(k, [])):
                eligible.setdefault(parent[k], []).append(k)
        this_round: List[Tuple[int, int]] = []
        for p, cands in eligible.items():
            # heaviest subtree first: keeps the critical path moving
            k = max(cands, key=lambda c: (weight[c], -c))
            this_round.append((rank_of[k], rank_of[p]))
            done_round[k] = r
            unsent.discard(k)
        assert this_round, "schedule stalled (cycle in signal edges?)"
        rounds.append(tuple(sorted(this_round)))
        r += 1
    sched = Schedule(len(ranks), tuple(rounds), kind="scsl_reduce")
    sched.check()
    return sched


def snsl_broadcast_schedule(sl: SkipList, ranks: Sequence[int]) -> Schedule:
    """Broadcast from the head down the notification edges (reverse SCSL
    edge direction; single-port: one send per holder per round)."""
    parent, root = _fold_head(sl)
    rank_of = {k: i for i, k in enumerate(ranks)}
    children: Dict[int, List[int]] = {}
    for c, p in parent.items():
        children.setdefault(p, []).append(c)
    # deeper subtrees notified first
    weight: Dict[int, int] = {}

    def w(k: int) -> int:
        if k not in weight:
            weight[k] = 1 + max((w(c) for c in children.get(k, [])),
                                default=0)
        return weight[k]

    have = {root}
    todo = set(parent)
    rounds: List[Tuple[Tuple[int, int], ...]] = []
    while todo:
        this_round: List[Tuple[int, int]] = []
        used_senders = set()
        for h in sorted(have):
            if h in used_senders:
                continue
            cands = [c for c in children.get(h, []) if c in todo]
            if not cands:
                continue
            c = max(cands, key=lambda x: (w(x), -x))
            this_round.append((rank_of[h], rank_of[c]))
            used_senders.add(h)
            todo.discard(c)
        assert this_round, "broadcast stalled"
        have |= {ranks[d] for _, d in this_round}
        rounds.append(tuple(sorted(this_round)))
    sched = Schedule(len(ranks), tuple(rounds), kind="snsl_broadcast")
    sched.check()
    return sched


def recursive_doubling_schedule(n: int) -> Schedule:
    """log2(n) XOR-exchange rounds (the paper's creation algorithm [2] as an
    all-reduce). Requires power-of-two n (mesh axes always are)."""
    assert n & (n - 1) == 0, f"recursive doubling needs power-of-2 n, got {n}"
    rounds = []
    r = 0
    while (1 << r) < n:
        stride = 1 << r
        rounds.append(tuple(sorted((i, i ^ stride) for i in range(n))))
        r += 1
    sched = Schedule(n, tuple(rounds), kind="recursive_doubling")
    sched.check()
    return sched


# ---------------------------------------------------------------------------
# JAX executors (run inside shard_map over ``axis_name``).
# ---------------------------------------------------------------------------
def _dst_mask(n: int, round_pairs: Sequence[Tuple[int, int]]):
    m = np.zeros((n,), dtype=np.bool_)
    for _, d in round_pairs:
        m[d] = True
    return m


def scsl_allreduce(x: jax.Array, axis_name: str, up: Schedule,
                   down: Schedule) -> jax.Array:
    """All-reduce(+) along ``axis_name`` with the phaser SCSL/SNSL schedules:
    reduce up the signal-collection edges, broadcast down the notification
    edges. Correct for any x dtype supporting +."""
    n = up.n
    idx = lax.axis_index(axis_name)
    acc = x
    for pairs in up.rounds:
        recv = jnp.asarray(_dst_mask(n, pairs))[idx]
        y = lax.ppermute(acc, axis_name, perm=list(pairs))
        acc = acc + jnp.where(recv, y, jnp.zeros_like(y))
    # acc at the root now holds the total; diffuse it down
    out = acc
    for pairs in down.rounds:
        recv = jnp.asarray(_dst_mask(n, pairs))[idx]
        y = lax.ppermute(out, axis_name, perm=list(pairs))
        out = jnp.where(recv, y, out)
    return out


def recursive_doubling_allreduce(x: jax.Array, axis_name: str,
                                 sched: Schedule) -> jax.Array:
    acc = x
    for pairs in sched.rounds:
        y = lax.ppermute(acc, axis_name, perm=list(pairs))
        acc = acc + y
    return acc


def halving_doubling_allreduce(x: jax.Array, axis_name: str,
                               n: int) -> jax.Array:
    """Bandwidth-optimal all-reduce: recursive-halving reduce-scatter then
    recursive-doubling all-gather. Transfers 2·(n-1)/n·|x| per device versus
    log2(n)·|x| for plain recursive doubling. Requires |x| divisible by n
    (callers pad); power-of-two n."""
    assert n & (n - 1) == 0
    flat = x.reshape(-1)
    orig_size = flat.shape[0]
    pad = (-orig_size) % n
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    size = flat.shape[0]
    idx = lax.axis_index(axis_name)
    # reduce-scatter: after round r each device owns a 1/2^(r+1) slice
    acc = flat
    stride = n // 2
    width = size
    while stride >= 1:
        pairs = [(i, i ^ stride) for i in range(n)]
        keep_low = (idx // stride) % 2 == 0     # low-half keeper this round
        half = width // 2
        low = lax.dynamic_slice(acc, (0,), (half,))
        high = lax.dynamic_slice(acc, (half,), (half,))
        tosend = jnp.where(keep_low, high, low)
        keep = jnp.where(keep_low, low, high)
        got = lax.ppermute(tosend, axis_name, perm=pairs)
        acc = keep + got
        width = half
        stride //= 2
    # all-gather back up (doubling)
    stride = 1
    while stride < n:
        pairs = [(i, i ^ stride) for i in range(n)]
        got = lax.ppermute(acc, axis_name, perm=pairs)
        keep_low = (idx // stride) % 2 == 0
        acc = jnp.where(keep_low,
                        jnp.concatenate([acc, got]),
                        jnp.concatenate([got, acc]))
        stride *= 2
    return acc[:orig_size].reshape(x.shape)


ALLREDUCE_KINDS = ("xla_psum", "phaser_scsl", "recursive_doubling",
                   "halving_doubling")


@dataclass
class PhaserCollective:
    """Bundle: phaser topology over a mesh axis + selected schedule.

    ``kind``:
      xla_psum | phaser_scsl | recursive_doubling | halving_doubling

    ``keys``: the participant keys of the phaser topology. Defaults to
    ``range(n)`` (a fresh team); an elastic runtime passes the *live* key
    set after churn, so the schedule is re-derived from the exact skip
    list the protocol actors converged to (heights are a deterministic
    function of the key, so survivors keep their lanes). Mesh rank i
    executes the role of ``sorted(keys)[i]``.
    """

    n: int
    axis_name: str
    kind: str = "xla_psum"
    p: float = 0.5
    seed: int = 0
    keys: Optional[Tuple[int, ...]] = None
    up: Optional[Schedule] = None
    down: Optional[Schedule] = None
    rd: Optional[Schedule] = None

    def __post_init__(self):
        assert self.kind in ALLREDUCE_KINDS, self.kind
        if self.keys is None:
            self.keys = tuple(range(self.n))
        else:
            self.keys = tuple(sorted(self.keys))
        assert len(self.keys) == self.n, (self.n, self.keys)
        if self.kind == "phaser_scsl":
            sl = SkipList.build(self.keys, p=self.p, seed=self.seed)
            self.up = scsl_reduce_schedule(sl, list(self.keys))
            self.down = snsl_broadcast_schedule(sl, list(self.keys))
        elif self.kind == "recursive_doubling":
            self.rd = recursive_doubling_schedule(self.n)
        elif self.kind == "halving_doubling":
            assert self.n & (self.n - 1) == 0, \
                f"halving doubling needs power-of-2 n, got {self.n}"

    def all_reduce(self, x: jax.Array) -> jax.Array:
        if self.kind == "xla_psum":
            return lax.psum(x, self.axis_name)
        if self.kind == "phaser_scsl":
            return scsl_allreduce(x, self.axis_name, self.up, self.down)
        if self.kind == "recursive_doubling":
            return recursive_doubling_allreduce(x, self.axis_name, self.rd)
        if self.kind == "halving_doubling":
            return halving_doubling_allreduce(x, self.axis_name, self.n)
        raise ValueError(self.kind)

    def pmean(self, x: jax.Array) -> jax.Array:
        return self.all_reduce(x) / self.n

    # --- introspection / roofline ------------------------------------------
    def stats(self) -> Dict[str, int]:
        if self.kind == "phaser_scsl":
            return {"rounds": self.up.depth + self.down.depth,
                    "messages": self.up.messages + self.down.messages}
        if self.kind == "recursive_doubling":
            return {"rounds": self.rd.depth, "messages": self.rd.messages}
        if self.kind == "halving_doubling":
            lg = int(math.log2(self.n))
            return {"rounds": 2 * lg, "messages": 2 * lg * self.n}
        return {"rounds": 1, "messages": self.n}

    # --- host-side execution -----------------------------------------------
    def simulate_allreduce(self, xs: Sequence[np.ndarray]) -> List[np.ndarray]:
        """Execute the schedule on host numpy values, one per mesh rank.

        This is the data plane of the *simulated* cluster (the same role
        ``lax.ppermute`` plays on a real mesh): the elastic trainer uses
        it to sync per-worker gradients through the exact per-epoch
        schedule, and tests use it to prove every schedule computes the
        same sum as a direct reduction.
        """
        assert len(xs) == self.n, (len(xs), self.n)
        vals = [np.asarray(x, dtype=np.float64) for x in xs]
        if self.kind == "xla_psum":
            total = sum(vals)
            return [total.copy() for _ in range(self.n)]
        if self.kind == "phaser_scsl":
            acc = [v.copy() for v in vals]
            for pairs in self.up.rounds:        # reduce up the SCSL edges
                incoming = {d: acc[s] for s, d in pairs}
                acc = [acc[i] + incoming[i] if i in incoming else acc[i]
                       for i in range(self.n)]
            out = acc
            for pairs in self.down.rounds:      # broadcast down the SNSL
                incoming = {d: out[s] for s, d in pairs}
                out = [incoming.get(i, out[i]) for i in range(self.n)]
            return out
        if self.kind == "recursive_doubling":
            acc = [v.copy() for v in vals]
            for pairs in self.rd.rounds:
                incoming = {d: acc[s] for s, d in pairs}
                acc = [acc[i] + incoming[i] for i in range(self.n)]
            return acc
        if self.kind == "halving_doubling":
            # mirror halving_doubling_allreduce round for round:
            # recursive-halving reduce-scatter, then doubling all-gather
            n = self.n
            shape = vals[0].shape
            flat = [v.ravel() for v in vals]
            orig = flat[0].size
            pad = (-orig) % n
            acc = [np.concatenate([f, np.zeros((pad,))]) if pad
                   else f.copy() for f in flat]
            width = acc[0].size
            stride = n // 2
            while stride >= 1:
                half = width // 2
                nxt = []
                for i in range(n):
                    j = i ^ stride
                    keep_low = (i // stride) % 2 == 0
                    keep = acc[i][:half] if keep_low else acc[i][half:]
                    sent = (acc[j][half:] if (j // stride) % 2 == 0
                            else acc[j][:half])
                    nxt.append(keep + sent)
                acc = nxt
                width = half
                stride //= 2
            stride = 1
            while stride < n:
                nxt = []
                for i in range(n):
                    j = i ^ stride
                    keep_low = (i // stride) % 2 == 0
                    nxt.append(np.concatenate([acc[i], acc[j]]) if keep_low
                               else np.concatenate([acc[j], acc[i]]))
                acc = nxt
                stride *= 2
            return [a[:orig].reshape(shape) for a in acc]
        raise ValueError(self.kind)

    def schedule_fingerprint(self) -> Tuple:
        """Hashable identity of the compiled schedule: changes exactly
        when the topology (live keys / kind) changes — the re-lower key
        for the elastic runtime's epoch swap."""
        if self.kind == "phaser_scsl":
            return (self.kind, self.keys, self.up.rounds, self.down.rounds)
        if self.kind == "recursive_doubling":
            return (self.kind, self.keys, self.rd.rounds)
        return (self.kind, self.keys)

    def matches_oracle(self) -> bool:
        """Re-derive the schedule from a fresh deterministic skip-list
        oracle over ``keys`` and compare structurally (the elastic
        epoch-swap correctness check)."""
        if self.kind != "phaser_scsl":
            return True
        sl = SkipList.build(self.keys, p=self.p, seed=self.seed)
        return (self.up == scsl_reduce_schedule(sl, list(self.keys))
                and self.down == snsl_broadcast_schedule(sl,
                                                         list(self.keys)))
