"""Phaser topologies compiled to static TPU collective schedules.

The data-plane adaptation of the paper (DESIGN.md §2): the SCSL/SNSL signal
flow becomes a *static schedule* of ``lax.ppermute`` rounds executed inside
``shard_map`` over a mesh axis. Three interchangeable gradient-sync
schedules:

* ``phaser_scsl``        — the paper-faithful topology: reduce up the SCSL
                           signal edges to the head, then diffuse down the
                           SNSL (broadcast). Single-port model: every device
                           receives at most one message per round, exactly
                           like the protocol's FIFO channels.
* ``recursive_doubling`` — the paper's *creation* exchange [2] reused as an
                           all-reduce: log2(n) XOR-partner rounds.
* ``halving_doubling``   — beyond-paper bandwidth-optimal variant:
                           recursive-halving reduce-scatter + recursive-
                           doubling all-gather (2·(n-1)/n data volume).
* ``xla_psum``           — XLA's native all-reduce (baseline).

Schedules are derived once (host side, from the deterministic skip-list
oracle) and are traced into the compiled step; topology changes (elastic
add/delete) swap the schedule at the next re-lower — the "lazy" phase of the
paper's two-phase structural protocol.

Every kind is valid for **any** team size: non-power-of-two teams use the
elimination derivations (PR 2) — extras fold into their hypercube images
before the XOR exchange (recursive doubling), or run a vector-halving 2-1
elimination pre-phase (halving doubling) — mirroring the creation
exchange's fold in ``core/creation.py``. A ``Schedule`` therefore carries
a per-round op: ``"add"`` rounds accumulate at the destination, ``"copy"``
rounds overwrite (the broadcast/hydration direction). The device-resident
execution engine (``collective_exec/``) compiles these schedules into
``shard_map`` programs with a fused Pallas bucket-combine kernel.
"""
from __future__ import annotations

import functools
import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from .skiplist import HEAD, SkipList


# ---------------------------------------------------------------------------
# Schedule derivation (host side, pure Python).
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class Schedule:
    """A sequence of ppermute rounds. ``rounds[r]`` = tuple of (src, dst)
    pairs, each a partial permutation (distinct srcs, distinct dsts).

    ``ops[r]`` is the destination combine for round ``r``: ``"add"``
    (reduce into the accumulator) or ``"copy"`` (overwrite — the
    broadcast/hydration direction). An empty ``ops`` means every round
    is ``"add"`` (the pre-existing reduce-only schedules)."""

    n: int
    rounds: Tuple[Tuple[Tuple[int, int], ...], ...]
    kind: str = "generic"
    ops: Tuple[str, ...] = ()

    def op(self, r: int) -> str:
        return self.ops[r] if self.ops else "add"

    @property
    def depth(self) -> int:
        return len(self.rounds)

    @property
    def messages(self) -> int:
        return sum(len(r) for r in self.rounds)

    def check(self) -> None:
        assert not self.ops or len(self.ops) == len(self.rounds), \
            (len(self.ops), len(self.rounds))
        assert all(op in ("add", "copy") for op in self.ops), self.ops
        for r in self.rounds:
            srcs = [s for s, _ in r]
            dsts = [d for _, d in r]
            assert len(set(srcs)) == len(srcs), f"src collision in {r}"
            assert len(set(dsts)) == len(dsts), f"dst collision in {r}"
            assert all(0 <= s < self.n and 0 <= d < self.n
                       for s, d in r)


def _fold_head(sl: SkipList) -> Tuple[Dict[int, int], int]:
    """Map the virtual HEAD onto the lowest participant key (the designated
    head-signaler of the paper is a real task in the data plane)."""
    keys = sl.keys()
    assert keys, "empty topology"
    root = keys[0]
    parent = {}
    for k in keys:
        p = sl.parent(k)
        if k == root:
            continue
        parent[k] = root if p == HEAD else p
    return parent, root


def scsl_reduce_schedule(sl: SkipList, ranks: Sequence[int]) -> Schedule:
    """Single-port greedy schedule for the SCSL reduction (children before
    parent; one receive per device per round)."""
    parent, root = _fold_head(sl)
    rank_of = {k: i for i, k in enumerate(ranks)}
    children: Dict[int, List[int]] = {k: [] for k in list(parent) + [root]}
    for c, p in parent.items():
        children.setdefault(p, []).append(c)
    # critical-path weight: height of subtree below each node
    weight: Dict[int, int] = {}

    def w(k: int) -> int:
        if k not in weight:
            weight[k] = 1 + max((w(c) for c in children.get(k, [])),
                                default=0)
        return weight[k]

    for k in children:
        w(k)

    unsent = set(parent)                      # root never sends
    done_round: Dict[int, int] = {}           # node -> round it sent in
    rounds: List[Tuple[Tuple[int, int], ...]] = []
    r = 0
    while unsent:
        eligible: Dict[int, List[int]] = {}
        for k in unsent:
            if all(c in done_round and done_round[c] < r
                   for c in children.get(k, [])):
                eligible.setdefault(parent[k], []).append(k)
        this_round: List[Tuple[int, int]] = []
        for p, cands in eligible.items():
            # heaviest subtree first: keeps the critical path moving
            k = max(cands, key=lambda c: (weight[c], -c))
            this_round.append((rank_of[k], rank_of[p]))
            done_round[k] = r
            unsent.discard(k)
        assert this_round, "schedule stalled (cycle in signal edges?)"
        rounds.append(tuple(sorted(this_round)))
        r += 1
    sched = Schedule(len(ranks), tuple(rounds), kind="scsl_reduce")
    sched.check()
    return sched


def snsl_broadcast_schedule(sl: SkipList, ranks: Sequence[int]) -> Schedule:
    """Broadcast from the head down the notification edges (reverse SCSL
    edge direction; single-port: one send per holder per round)."""
    parent, root = _fold_head(sl)
    rank_of = {k: i for i, k in enumerate(ranks)}
    children: Dict[int, List[int]] = {}
    for c, p in parent.items():
        children.setdefault(p, []).append(c)
    # deeper subtrees notified first
    weight: Dict[int, int] = {}

    def w(k: int) -> int:
        if k not in weight:
            weight[k] = 1 + max((w(c) for c in children.get(k, [])),
                                default=0)
        return weight[k]

    have = {root}
    todo = set(parent)
    rounds: List[Tuple[Tuple[int, int], ...]] = []
    while todo:
        this_round: List[Tuple[int, int]] = []
        used_senders = set()
        for h in sorted(have):
            if h in used_senders:
                continue
            cands = [c for c in children.get(h, []) if c in todo]
            if not cands:
                continue
            c = max(cands, key=lambda x: (w(x), -x))
            this_round.append((rank_of[h], rank_of[c]))
            used_senders.add(h)
            todo.discard(c)
        assert this_round, "broadcast stalled"
        have |= {ranks[d] for _, d in this_round}
        rounds.append(tuple(sorted(this_round)))
    sched = Schedule(len(ranks), tuple(rounds), kind="snsl_broadcast",
                     ops=("copy",) * len(rounds))
    sched.check()
    return sched


def recursive_doubling_schedule(n: int) -> Schedule:
    """XOR-exchange all-reduce rounds (the paper's creation algorithm [2]).

    Power-of-two teams run the pure hypercube exchange. Any other team
    size gets the rank-elimination derivation (the whole-buffer member of
    the Rabenseifner-Träff elimination family, the same fold the creation
    exchange uses in ``core/creation.py``): the ``r = n - 2^k`` extras
    fold their contribution into their hypercube images (one ``add``
    round), the 2^k core runs the XOR exchange, and one final ``copy``
    round re-hydrates the extras with the total. Latency is
    ``log2(2^k) + 2`` rounds instead of falling back to ``phaser_scsl``.
    """
    assert n >= 1, n
    k = 1 << (n.bit_length() - 1)           # largest power of two <= n
    r = n - k
    rounds: List[Tuple[Tuple[int, int], ...]] = []
    ops: List[str] = []
    if r:
        rounds.append(tuple(sorted((k + i, i) for i in range(r))))
        ops.append("add")
    stride = 1
    while stride < k:
        rounds.append(tuple(sorted((i, i ^ stride) for i in range(k))))
        ops.append("add")
        stride *= 2
    if r:
        rounds.append(tuple(sorted((i, k + i) for i in range(r))))
        ops.append("copy")
    sched = Schedule(n, tuple(rounds), kind="recursive_doubling",
                     ops=tuple(ops))
    sched.check()
    return sched


# ---------------------------------------------------------------------------
# JAX executors (run inside shard_map over ``axis_name``).
# ---------------------------------------------------------------------------
def _dst_mask(n: int, round_pairs: Sequence[Tuple[int, int]]):
    m = np.zeros((n,), dtype=np.bool_)
    for _, d in round_pairs:
        m[d] = True
    return m


def schedule_allreduce(x: jax.Array, axis_name: str, sched: Schedule, *,
                       combine: Optional[callable] = None) -> jax.Array:
    """Execute any round ``Schedule`` along ``axis_name``: per round, the
    destinations of the partial permutation either accumulate (``add``)
    or overwrite (``copy``) the incoming value; everyone else keeps their
    accumulator. ``combine(acc, incoming, gate, op) -> acc`` overrides the
    per-round combine — the execution engine passes the fused Pallas
    bucket-combine kernel here; the default is plain masked jnp."""
    idx = lax.axis_index(axis_name)
    acc = x
    for r, pairs in enumerate(sched.rounds):
        gate = jnp.asarray(_dst_mask(sched.n, pairs))[idx]
        y = lax.ppermute(acc, axis_name, perm=list(pairs))
        if combine is not None:
            acc = combine(acc, y, gate, sched.op(r))
        elif sched.op(r) == "add":
            acc = acc + jnp.where(gate, y, jnp.zeros_like(y))
        else:
            acc = jnp.where(gate, y, acc)
    return acc


def scsl_allreduce(x: jax.Array, axis_name: str, up: Schedule,
                   down: Schedule, *,
                   combine: Optional[callable] = None) -> jax.Array:
    """All-reduce(+) along ``axis_name`` with the phaser SCSL/SNSL schedules:
    reduce up the signal-collection edges, broadcast down the notification
    edges. Correct for any x dtype supporting +."""
    uni = Schedule(up.n, up.rounds + down.rounds, kind="phaser_scsl",
                   ops=("add",) * up.depth + ("copy",) * down.depth)
    return schedule_allreduce(x, axis_name, uni, combine=combine)


def halving_doubling_allreduce(x: jax.Array, axis_name: str,
                               n: int) -> jax.Array:
    """Bandwidth-optimal all-reduce: recursive-halving reduce-scatter then
    recursive-doubling all-gather over the 2^k core (2·(2^k-1)/2^k data
    volume versus log2(n)·|x| for plain recursive doubling).

    Any team size: the ``r = n - 2^k`` extras are retired by a
    vector-halving **2-1 elimination** pre-phase (Rabenseifner-Träff
    elimination family): extra and core image swap opposite halves and
    each reduces the half it kept (two half-sized messages), the extra
    returns its reduced half (one more half-sized message), and after the
    core finishes, one full-sized copy re-hydrates the extras."""
    if n == 1:
        return x
    k = 1 << (n.bit_length() - 1)           # largest power of two <= n
    r = n - k
    flat = x.reshape(-1)
    orig_size = flat.shape[0]
    pad = (-orig_size) % (2 * k)            # even halves at every depth
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    size = flat.shape[0]
    idx = lax.axis_index(axis_name)
    acc = flat
    if r:
        # 2-1 elimination: extra k+i <-> core i swap opposite halves.
        half = size // 2
        lo = lax.dynamic_slice(acc, (0,), (half,))
        hi = lax.dynamic_slice(acc, (half,), (half,))
        is_extra = idx >= k
        has_extra = idx < r
        pairs1 = ([(k + i, i) for i in range(r)]
                  + [(i, k + i) for i in range(r)])
        send1 = jnp.where(is_extra, lo, hi)
        got1 = lax.ppermute(send1, axis_name, perm=pairs1)
        lo = jnp.where(has_extra, lo + got1, lo)    # core reduces low half
        hi = jnp.where(is_extra, hi + got1, hi)     # extra reduces high half
        got2 = lax.ppermute(hi, axis_name,
                            perm=[(k + i, i) for i in range(r)])
        hi = jnp.where(has_extra, got2, hi)         # extra hands it back
        acc = jnp.concatenate([lo, hi])
    # reduce-scatter among the core: after each round a device owns half
    stride = k // 2
    width = size
    while stride >= 1:
        pairs = [(i, i ^ stride) for i in range(k)]
        keep_low = (idx // stride) % 2 == 0     # low-half keeper this round
        half = width // 2
        low = lax.dynamic_slice(acc, (0,), (half,))
        high = lax.dynamic_slice(acc, (half,), (half,))
        tosend = jnp.where(keep_low, high, low)
        keep = jnp.where(keep_low, low, high)
        got = lax.ppermute(tosend, axis_name, perm=pairs)
        acc = keep + got
        width = half
        stride //= 2
    # all-gather back up (doubling)
    stride = 1
    while stride < k:
        pairs = [(i, i ^ stride) for i in range(k)]
        got = lax.ppermute(acc, axis_name, perm=pairs)
        keep_low = (idx // stride) % 2 == 0
        acc = jnp.where(keep_low,
                        jnp.concatenate([acc, got]),
                        jnp.concatenate([got, acc]))
        stride *= 2
    if r:
        # re-hydrate the eliminated extras with the full result
        got3 = lax.ppermute(acc, axis_name,
                            perm=[(i, k + i) for i in range(r)])
        acc = jnp.where(idx >= k, got3, acc)
    return acc[:orig_size].reshape(x.shape)


def simulate_schedule(sched: Schedule, xs: Sequence[np.ndarray]
                      ) -> List[np.ndarray]:
    """Host-side reference execution of a round schedule (one value per
    rank) — the exact mirror of ``schedule_allreduce``."""
    assert len(xs) == sched.n, (len(xs), sched.n)
    vals = [np.asarray(x, dtype=np.float64) for x in xs]
    for r, pairs in enumerate(sched.rounds):
        incoming = {d: vals[s] for s, d in pairs}
        if sched.op(r) == "add":
            vals = [vals[i] + incoming[i] if i in incoming else vals[i]
                    for i in range(sched.n)]
        else:
            vals = [incoming.get(i, vals[i]) for i in range(sched.n)]
    return vals


ALLREDUCE_KINDS = ("xla_psum", "phaser_scsl", "recursive_doubling",
                   "halving_doubling")


@dataclass
class PhaserCollective:
    """Bundle: phaser topology over a mesh axis + selected schedule.

    ``kind``:
      xla_psum | phaser_scsl | recursive_doubling | halving_doubling

    ``keys``: the participant keys of the phaser topology. Defaults to
    ``range(n)`` (a fresh team); an elastic runtime passes the *live* key
    set after churn, so the schedule is re-derived from the exact skip
    list the protocol actors converged to (heights are a deterministic
    function of the key, so survivors keep their lanes). Mesh rank i
    executes the role of ``sorted(keys)[i]``.

    ``leaf_keys``: demoted (straggler) keys pinned to height 1 — leaves
    of the SCSL reduce tree with the fewest dependents. Part of the
    topology identity: the oracle, the fingerprint and the program-cache
    key all carry it.
    """

    n: int
    axis_name: str
    kind: str = "xla_psum"
    p: float = 0.5
    seed: int = 0
    keys: Optional[Tuple[int, ...]] = None
    leaf_keys: Tuple[int, ...] = ()
    up: Optional[Schedule] = None
    down: Optional[Schedule] = None
    rd: Optional[Schedule] = None

    def __post_init__(self):
        assert self.kind in ALLREDUCE_KINDS, self.kind
        if self.keys is None:
            self.keys = tuple(range(self.n))
        else:
            self.keys = tuple(sorted(self.keys))
        assert len(self.keys) == self.n, (self.n, self.keys)
        self.leaf_keys = tuple(sorted(set(self.leaf_keys)
                                      & set(self.keys)))
        if self.kind == "phaser_scsl":
            sl = SkipList.build(self.keys, p=self.p, seed=self.seed,
                                leaf_keys=self.leaf_keys)
            self.up = scsl_reduce_schedule(sl, list(self.keys))
            self.down = snsl_broadcast_schedule(sl, list(self.keys))
        elif self.kind == "recursive_doubling":
            self.rd = recursive_doubling_schedule(self.n)

    def unified_schedule(self) -> Optional[Schedule]:
        """The single round schedule the execution engine compiles:
        reduce-up + copy-down for ``phaser_scsl``, the (possibly
        elimination-extended) XOR exchange for ``recursive_doubling``.
        ``None`` for the kinds that are not whole-buffer round schedules
        (``xla_psum`` is native; ``halving_doubling`` is segment-level)."""
        if self.kind == "phaser_scsl":
            return Schedule(self.n, self.up.rounds + self.down.rounds,
                            kind="phaser_scsl",
                            ops=("add",) * self.up.depth
                            + ("copy",) * self.down.depth)
        if self.kind == "recursive_doubling":
            return self.rd
        return None

    def all_reduce(self, x: jax.Array, *,
                   combine: Optional[callable] = None) -> jax.Array:
        if self.kind == "xla_psum":
            return lax.psum(x, self.axis_name)
        if self.kind == "halving_doubling":
            return halving_doubling_allreduce(x, self.axis_name, self.n)
        return schedule_allreduce(x, self.axis_name,
                                  self.unified_schedule(), combine=combine)

    def pmean(self, x: jax.Array) -> jax.Array:
        return self.all_reduce(x) / self.n

    # --- introspection / roofline ------------------------------------------
    def stats(self) -> Dict[str, int]:
        if self.kind == "phaser_scsl":
            return {"rounds": self.up.depth + self.down.depth,
                    "messages": self.up.messages + self.down.messages}
        if self.kind == "recursive_doubling":
            return {"rounds": self.rd.depth, "messages": self.rd.messages}
        if self.kind == "halving_doubling":
            k = 1 << (self.n.bit_length() - 1)
            r = self.n - k
            lg = int(math.log2(k)) if k > 1 else 0
            # core: lg rounds each way; elimination: 2 pre + 1 hydrate
            return {"rounds": 2 * lg + (3 if r else 0),
                    "messages": 2 * lg * k + 4 * r}
        return {"rounds": 1, "messages": self.n}

    # --- host-side execution -----------------------------------------------
    def simulate_allreduce(self, xs: Sequence[np.ndarray]) -> List[np.ndarray]:
        """Execute the schedule on host numpy values, one per mesh rank.

        This is the data plane of the *simulated* cluster (the same role
        ``lax.ppermute`` plays on a real mesh): the elastic trainer uses
        it to sync per-worker gradients through the exact per-epoch
        schedule, and tests use it to prove every schedule computes the
        same sum as a direct reduction.
        """
        assert len(xs) == self.n, (len(xs), self.n)
        vals = [np.asarray(x, dtype=np.float64) for x in xs]
        if self.kind == "xla_psum":
            total = sum(vals)
            return [total.copy() for _ in range(self.n)]
        if self.kind in ("phaser_scsl", "recursive_doubling"):
            return simulate_schedule(self.unified_schedule(), vals)
        if self.kind == "halving_doubling":
            # mirror halving_doubling_allreduce round for round: 2-1
            # elimination pre-phase (non-pow2), recursive-halving
            # reduce-scatter, doubling all-gather, extra re-hydration
            n = self.n
            if n == 1:
                return [v.copy() for v in vals]
            k = 1 << (n.bit_length() - 1)
            r = n - k
            shape = vals[0].shape
            flat = [v.ravel() for v in vals]
            orig = flat[0].size
            pad = (-orig) % (2 * k)
            acc = [np.concatenate([f, np.zeros((pad,))]) if pad
                   else f.copy() for f in flat]
            size = acc[0].size
            if r:
                half = size // 2
                nxt = [a.copy() for a in acc]
                for i in range(r):
                    e = k + i
                    nxt[i][:half] = acc[i][:half] + acc[e][:half]
                    nxt[e][half:] = acc[e][half:] + acc[i][half:]
                acc = nxt
                for i in range(r):              # extra returns its half
                    acc[i][half:] = acc[k + i][half:]
            width = size
            stride = k // 2
            while stride >= 1:
                half = width // 2
                nxt = []
                for i in range(n):
                    keep_low = (i // stride) % 2 == 0
                    keep = acc[i][:half] if keep_low else acc[i][half:]
                    if i < k:                   # extras idle (masked out)
                        j = i ^ stride
                        sent = (acc[j][half:] if (j // stride) % 2 == 0
                                else acc[j][:half])
                    else:
                        sent = np.zeros((half,))
                    nxt.append(keep + sent)
                acc = nxt
                width = half
                stride //= 2
            stride = 1
            while stride < k:
                nxt = []
                for i in range(n):
                    keep_low = (i // stride) % 2 == 0
                    got = (acc[i ^ stride] if i < k
                           else np.zeros_like(acc[i]))
                    nxt.append(np.concatenate([acc[i], got]) if keep_low
                               else np.concatenate([got, acc[i]]))
                acc = nxt
                stride *= 2
            for i in range(r):                  # hydrate the extras
                acc[k + i] = acc[i].copy()
            return [a[:orig].reshape(shape) for a in acc]
        raise ValueError(self.kind)

    def schedule_fingerprint(self) -> Tuple:
        """Hashable identity of the compiled schedule: changes exactly
        when the topology (live keys / kind) changes — the re-lower key
        for the elastic runtime's epoch swap."""
        if self.kind == "phaser_scsl":
            return (self.kind, self.keys, self.leaf_keys,
                    self.up.rounds, self.down.rounds)
        if self.kind == "recursive_doubling":
            return (self.kind, self.keys, self.rd.rounds, self.rd.ops)
        return (self.kind, self.keys)

    def matches_oracle(self) -> bool:
        """Re-derive the schedule from a fresh deterministic skip-list
        oracle over ``keys`` (demoted keys pinned to leaves) and compare
        structurally (the elastic epoch-swap correctness check)."""
        if self.kind != "phaser_scsl":
            return True
        sl = SkipList.build(self.keys, p=self.p, seed=self.seed,
                            leaf_keys=self.leaf_keys)
        return (self.up == scsl_reduce_schedule(sl, list(self.keys))
                and self.down == snsl_broadcast_schedule(sl,
                                                         list(self.keys)))
