"""Deterministic skip lists augmented with signal edges (SCSL / SNSL).

This module is the *sequential* topology oracle: it computes the structure the
distributed protocol (``core/phaser.py``) converges to, supplies initial
topologies to ``core/creation.py``, and is compiled into static collective
schedules by ``core/collective.py``.

Determinism: node heights are drawn from a counter-based hash of
``(seed, phaser_id, key)`` so that every rank derives an identical structure
with no communication — a deliberate adaptation of the paper's probabilistic
skip list for the SPMD data plane (DESIGN.md §2). The geometric height
distribution (parameter ``p``) that the paper's complexity analysis assumes is
preserved.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

HEAD = -1  # sentinel key of the designated head (head-signaler / head-waiter)


def det_height(key: int, *, p: float = 0.5, max_height: int = 32,
               seed: int = 0, phaser_id: int = 0) -> int:
    """Geometric(p) height in [1, max_height] from a counter-based hash.

    Height h means the node is present on levels 0..h-1. A *demoted*
    key (straggler pinned to a leaf position) is handled one level up:
    ``SkipList``'s ``leaf_keys`` override forces height 1 without
    perturbing any other key's draw.
    """
    if key == HEAD:
        return max_height + 1  # head is taller than everything: every lane ends there
    digest = hashlib.sha256(
        f"{seed}:{phaser_id}:{key}".encode()).digest()
    # Use digest bits as a stream of Bernoulli(p) trials.
    h = 1
    bits = int.from_bytes(digest, "big")
    # 256 bits is far more than max_height trials even for small p.
    threshold = int(p * (1 << 16))
    while h < max_height:
        chunk = bits & 0xFFFF
        bits >>= 16
        if chunk >= threshold:
            break
        h += 1
    return h


@dataclass
class Node:
    key: int
    height: int
    # nxt[l] / prv[l]: neighbor keys on level l (None == end of lane).
    nxt: List[Optional[int]] = field(default_factory=list)
    prv: List[Optional[int]] = field(default_factory=list)

    def __post_init__(self):
        if not self.nxt:
            self.nxt = [None] * self.height
            self.prv = [None] * self.height

    @property
    def top(self) -> int:
        return self.height - 1


class SkipList:
    """Sorted-by-key skip list with a permanent HEAD sentinel.

    Signal-edge convention (SCSL): the *parent* of node x is its predecessor
    at x's top level; signals flow child -> parent, terminating at HEAD.
    The SNSL uses the same structure with edges reversed (parent -> children)
    for notification diffusion.
    """

    def __init__(self, *, p: float = 0.5, max_height: int = 32, seed: int = 0,
                 phaser_id: int = 0,
                 leaf_keys: Optional[Iterable[int]] = None):
        self.p = p
        self.max_height = max_height
        self.seed = seed
        self.phaser_id = phaser_id
        # demoted keys: pinned to height 1 (leaf of the SCSL reduce
        # tree — fewest dependents) regardless of their hash draw
        self.leaf_keys = frozenset(leaf_keys or ())
        self.nodes: Dict[int, Node] = {}
        head = Node(HEAD, max_height + 1)
        self.nodes[HEAD] = head

    # -- construction -----------------------------------------------------
    @classmethod
    def build(cls, keys: Iterable[int], **kw) -> "SkipList":
        sl = cls(**kw)
        for k in sorted(keys):
            sl.insert(k)
        return sl

    def height_of(self, key: int) -> int:
        if key in self.leaf_keys:
            return 1
        return det_height(key, p=self.p, max_height=self.max_height,
                          seed=self.seed, phaser_id=self.phaser_id)

    def insert(self, key: int, height: Optional[int] = None) -> Node:
        if key in self.nodes:
            raise KeyError(f"duplicate key {key}")
        h = height if height is not None else self.height_of(key)
        node = Node(key, h)
        self.nodes[key] = node
        preds = self._preds(key)
        for l in range(h):
            p = preds[l]
            pn = self.nodes[p]
            s = pn.nxt[l]
            node.prv[l] = p
            node.nxt[l] = s
            pn.nxt[l] = key
            if s is not None:
                self.nodes[s].prv[l] = key
        return node

    def insert_level0(self, key: int) -> Node:
        """Eager insertion: splice at level 0 only (paper's fast step)."""
        return self.insert(key, height=1)

    def promote(self, key: int, target_height: Optional[int] = None) -> None:
        """Lazy promotion: raise ``key`` level by level to its drawn height."""
        node = self.nodes[key]
        tgt = target_height if target_height is not None else self.height_of(key)
        while node.height < tgt:
            l = node.height  # level being joined
            # hand-over-hand walk left along level l-1 to find the level-l pred
            cur = node.prv[l - 1]
            while cur is not None and self.nodes[cur].height <= l:
                cur = self.nodes[cur].prv[l - 1]
            assert cur is not None  # HEAD is on every level
            pn = self.nodes[cur]
            s = pn.nxt[l]
            node.nxt.append(s)
            node.prv.append(cur)
            node.height += 1
            pn.nxt[l] = key
            if s is not None:
                self.nodes[s].prv[l] = key

    def delete(self, key: int) -> None:
        """Level-by-level unlink, top down (paper's deletion)."""
        node = self.nodes[key]
        for l in reversed(range(node.height)):
            p, s = node.prv[l], node.nxt[l]
            if p is not None:
                self.nodes[p].nxt[l] = s
            if s is not None:
                self.nodes[s].prv[l] = p
        del self.nodes[key]

    def _preds(self, key: int) -> List[int]:
        """Predecessor key at every level for an insertion at ``key``."""
        preds = [HEAD] * (self.max_height + 1)
        cur = self.nodes[HEAD]
        for l in reversed(range(self.max_height + 1)):
            while True:
                nk = cur.nxt[l] if l < cur.height else None
                if nk is not None and nk < key:
                    cur = self.nodes[nk]
                else:
                    break
            preds[l] = cur.key
        return preds

    # -- signal-edge topology ---------------------------------------------
    def parent(self, key: int) -> Optional[int]:
        """Signal edge: predecessor at the node's top level (None for HEAD)."""
        if key == HEAD:
            return None
        n = self.nodes[key]
        return n.prv[n.top]

    def children(self, key: int) -> List[int]:
        """All nodes whose signal edge points at ``key`` (deterministic order:
        by (level, position))."""
        out = []
        n = self.nodes[key]
        for l in range(n.height):
            s = n.nxt[l]
            if s is not None and self.nodes[s].top == l:
                # every maximal run of top==l nodes chains leftward into us
                out.append(s)
        return out

    def collection_edges(self) -> List[Tuple[int, int]]:
        """(child, parent) signal edges of the SCSL."""
        return [(k, self.parent(k)) for k in self.keys()]

    def depth(self, key: int) -> int:
        """Hops from ``key`` to HEAD along signal edges (critical path)."""
        d = 0
        cur = key
        while cur != HEAD:
            cur = self.parent(cur)
            d += 1
        return d

    def max_depth(self) -> int:
        return max((self.depth(k) for k in self.keys()), default=0)

    # -- introspection ------------------------------------------------------
    def keys(self) -> List[int]:
        """Participant keys (excluding HEAD) in level-0 order."""
        out = []
        cur = self.nodes[HEAD].nxt[0]
        while cur is not None:
            out.append(cur)
            cur = self.nodes[cur].nxt[0]
        return out

    def level_chain(self, l: int) -> List[int]:
        """Keys present on lane ``l``, following nxt pointers from HEAD."""
        out = []
        cur = self.nodes[HEAD].nxt[l]
        while cur is not None:
            out.append(cur)
            cur = self.nodes[cur].nxt[l]
        return out

    def lanes(self) -> List[List[int]]:
        """Every lane chain, lane 0 first. Lane 0 is always present (it
        may be empty); higher lanes stop at the first empty one."""
        out = []
        l = 0
        while True:
            lane = self.level_chain(l)
            if not lane and l > 0:
                break
            out.append(lane)
            l += 1
        return out

    def fingerprint(self) -> str:
        """Stable digest of the full topology (per-key heights + every
        lane chain + the demotion set). Two parties that derived the
        same structure — e.g. every process of the partitioned control
        plane at an epoch boundary — agree on this string; that is the
        cross-process agreement check of the multi-host runtime."""
        payload = repr((sorted((k, self.nodes[k].height)
                               for k in self.keys()),
                        self.lanes(),
                        sorted(self.leaf_keys))).encode()
        return hashlib.sha256(payload).hexdigest()[:16]

    # -- partitioned (PGAS) view --------------------------------------------
    def partition(self, owner_of) -> Dict[int, "PartitionView"]:
        """Split the global structure into per-owner views: one logical
        skip list over partitioned per-process state (the global-view
        surface of arXiv:2112.00068). ``owner_of`` maps a key (including
        HEAD) to its owning process id; each view carries full link
        state for its own keys and only boundary references to remote
        ones. The union of the views is exactly the global list."""
        get = owner_of if callable(owner_of) else owner_of.__getitem__
        nodes_by_owner: Dict[int, Dict[int, Tuple]] = {}
        for k in [HEAD] + self.keys():
            n = self.nodes[k]
            nodes_by_owner.setdefault(get(k), {})[k] = (
                n.height, tuple(n.nxt), tuple(n.prv))
        out = {}
        for o, nodes in sorted(nodes_by_owner.items()):
            local = set(nodes)
            boundary = sorted({r for (_, nx, pv) in nodes.values()
                               for r in (*nx, *pv)
                               if r is not None and r not in local})
            out[o] = PartitionView(owner=o,
                                   nodes=tuple(sorted(
                                       (k, h, nx, pv)
                                       for k, (h, nx, pv) in nodes.items())),
                                   boundary=tuple(boundary))
        return out

    def check_integrity(self) -> None:
        """Structural invariants (used by tests and the model checker)."""
        keys = self.keys()
        assert keys == sorted(keys), f"level-0 not sorted: {keys}"
        assert len(set(keys)) == len(keys), "duplicate on level 0"
        for k, n in self.nodes.items():
            assert len(n.nxt) == n.height and len(n.prv) == n.height
            for l in range(n.height):
                if k == HEAD and l >= self.max_height + 1:
                    continue
                s = n.nxt[l]
                if s is not None:
                    sn = self.nodes[s]
                    assert l < sn.height, (k, l, s)
                    assert sn.prv[l] == k, f"prv/nxt mismatch at {k}->{s} level {l}"
                    assert s > k or k == HEAD
        # lane l must link exactly the keys of height > l, in sorted order
        l = 0
        while True:
            expect = [k for k in keys if self.nodes[k].height > l]
            assert self.level_chain(l) == expect, f"lane {l} mislinked"
            if not expect:
                break
            l += 1

    def describe(self) -> str:
        lines = []
        hmax = max((self.nodes[k].height for k in self.keys()), default=1)
        for l in reversed(range(hmax)):
            row = [f"L{l}:"]
            for k in self.keys():
                row.append(f"{k:>4}" if self.nodes[k].height > l else "   .")
            lines.append(" ".join(row))
        return "\n".join(lines)


def _canon_links(height: int, nxt, prv) -> Tuple[int, Tuple, Tuple]:
    """Normalize a node's link state to exactly ``height`` levels (link
    lists from protocol actors may carry trailing lanes after partial
    unlinks; the comparison is over the lanes the node is on)."""
    nx = tuple((list(nxt) + [None] * height)[:height])
    pv = tuple((list(prv) + [None] * height)[:height])
    return height, nx, pv


@dataclass(frozen=True)
class PartitionView:
    """One owner's slice of the partitioned skip list.

    ``nodes``: sorted tuple of ``(key, height, nxt, prv)`` for every
    locally-owned key (HEAD included for its owner); ``boundary``: the
    remote keys local links point at. ``diff`` checks a process's live
    actor state against this oracle slice — the per-process half of the
    epoch-boundary verification."""

    owner: int
    nodes: Tuple[Tuple[int, int, Tuple, Tuple], ...]
    boundary: Tuple[int, ...]

    def keys(self) -> List[int]:
        return [k for k, _, _, _ in self.nodes]

    def diff(self, states: Dict[int, Tuple[int, Tuple, Tuple]]) -> List[str]:
        """Mismatches between this view and ``states`` (key ->
        (height, nxt, prv) extracted from the owner's actors). Empty
        list == the partition agrees with the oracle."""
        out = []
        want = {k: _canon_links(h, nx, pv) for k, h, nx, pv in self.nodes}
        for k in sorted(set(want) | set(states)):
            if k not in want:
                out.append(f"key {k}: present locally, absent in oracle")
            elif k not in states:
                out.append(f"key {k}: in oracle view, absent locally")
            else:
                got = _canon_links(*states[k])
                if got != want[k]:
                    out.append(f"key {k}: local {got} != oracle {want[k]}")
        return out
