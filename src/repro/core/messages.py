"""Message vocabulary for the distributed-phaser protocol.

The poster's Table 1 names eight message classes used during eager insertion
(TUS, TDS, MURS, MULS-1/2/3, AT, ENSP) without expanding the acronyms; we
define a concrete protocol with the same structure (DESIGN.md §10) and keep the
acronyms. Additional classes cover signaling (SIG), phase advance (ADV),
registration accounting (ENSP/DEREG deltas), deletion (UNL), neighbor updates
(PRV) and combine-set maintenance (CHILD_ADD / CHILD_DEL).

``lid`` selects the list: 0 = SCSL (signal collection), 1 = SNSL (signal
notification). Every message is a frozen dataclass so the model checker can
hash states.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple


@dataclass(frozen=True)
class Msg:
    """Base class. ``src``/``dst`` are participant ids (ranks)."""

    src: int
    dst: int

    @property
    def kind(self) -> str:
        return type(self).__name__

    def replace(self, **kw) -> "Msg":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Search phase of eager insertion (paper Fig. 2 steps 1-2).
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class TUS(Msg):
    """Traverse-Up-Search: ascend express lanes toward the insertion region."""

    key: int          # key (rank) of the node being inserted
    new_id: int       # id of the joining node
    lid: int = 0


@dataclass(frozen=True)
class TDS(Msg):
    """Traverse-Down-Search: descend toward the level-0 predecessor."""

    key: int
    level: int
    new_id: int
    lid: int = 0


# ---------------------------------------------------------------------------
# Splice phase ("fast single-link-modify", Fig. 2 steps 3-5).
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class MURS(Msg):
    """Modify-Right-Splice: ask predecessor ``dst`` to set next0 := new node.
    (In our flow the search terminates at the predecessor, which splices
    locally; MURS appears explicitly when the search initiator is already the
    predecessor's neighbor.)"""

    new_id: int
    lid: int = 0


@dataclass(frozen=True)
class MURS_ACK(Msg):
    """Predecessor's reply to the new node: old successor at level 0 plus the
    phase the new node first participates in (assigned by the predecessor —
    its lowest unclosed phase — which makes head accounting race-free)."""

    new_id: int
    succ: Optional[int]
    first_phase: int
    released: int
    lid: int = 0


@dataclass(frozen=True)
class AT(Msg):
    """Attach-Task: new node notifies its async parent that the eager insert
    finished and it is signal-capable."""

    new_id: int
    first_phase: int
    lid: int = 0


@dataclass(frozen=True)
class ENSP(Msg):
    """Enable-Next-Signal-Propagation: activates the new node's signal edge
    and carries its +1 registration delta toward the head (routed eagerly
    along parent edges, so it precedes the node's first SIG on every shared
    FIFO channel)."""

    phase: int
    delta: int
    lid: int = 0


# ---------------------------------------------------------------------------
# Lazy promotion ("lazy multi-link-modify", hand-over-hand).
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class MULS1(Msg):
    """Step 1: ask candidate predecessor ``dst`` to splice ``new_id`` in at
    ``level``. A node not present on ``level`` forwards the walk left
    (hand-over-hand)."""

    level: int
    new_id: int
    lid: int = 0


@dataclass(frozen=True)
class MULS2(Msg):
    """Step 2: predecessor grants the splice; carries its old successor at
    that level (None == tail)."""

    level: int
    succ: Optional[int]
    lid: int = 0


@dataclass(frozen=True)
class MULS3(Msg):
    """Step 3: new node confirms; predecessor commits next_level := new and
    releases its hand-over-hand latch for the level. ``commit=False`` aborts
    (the walk found a closer predecessor spliced concurrently)."""

    level: int
    new_id: int
    commit: bool = True
    lid: int = 0


# ---------------------------------------------------------------------------
# Deletion (level-by-level unlink, top down).
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class UNL(Msg):
    """Ask predecessor ``dst`` at ``level`` to bypass the departing node."""

    level: int
    node: int
    succ: Optional[int]
    lid: int = 0


@dataclass(frozen=True)
class UNL_ACK(Msg):
    level: int
    node: int
    lid: int = 0


@dataclass(frozen=True)
class DEREG(Msg):
    """-1 registration delta effective from ``phase`` (flows toward head)."""

    phase: int
    delta: int
    lid: int = 0


# ---------------------------------------------------------------------------
# Neighbor / combine-set maintenance.
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class NXT(Msg):
    """'Your nxt pointer at ``level`` is now ``nxt``.' Used by the level-0
    unlink repair: a splice that landed at a departing node after its UNL
    snapshot was sent is handed over to the predecessor (structure only;
    the accounting moves via the new node's own re-parent handshake)."""

    level: int
    nxt: int
    lid: int = 0


@dataclass(frozen=True)
class PRV(Msg):
    """'Your prv pointer at ``level`` is now ``prv``.' If the receiver's top
    level equals ``level`` its signal-edge parent changed: it re-parents
    effective max(``effective``, closed+1)."""

    level: int
    prv: int
    effective: int
    lid: int = 0


@dataclass(frozen=True)
class CHILD_ADD(Msg):
    """Receiver gains a combine-set child from ``from_phase``. The child is
    ``child`` if set, else ``src`` (departed relays forward the request
    toward their own parent, so src may be a relay). SCSL receivers reply
    CHILD_ADD_ACK with the granted start phase; SNSL receivers adopt the
    child immediately and send a catch-up ADV."""

    from_phase: int
    child: Optional[int] = None
    lid: int = 0


@dataclass(frozen=True)
class CHILD_ADD_ACK(Msg):
    """Re-parent grant (SCSL handshake). The granting parent accepted the
    child from ``granted`` = max(requested, parent.closed+1): phases below
    the grant stay with the child's old parent, whose book is still open.
    This preserves the chain invariant (an open interval covering phase k
    implies its parent has not closed k) that makes the head's
    report-gated release race-free against in-flight registration
    deltas."""

    granted: int
    lid: int = 0


@dataclass(frozen=True)
class CHILD_DEL(Msg):
    """Receiver loses ``src`` as a combine-set child from ``from_phase``."""

    from_phase: int
    lid: int = 0


# ---------------------------------------------------------------------------
# Synchronization traffic.
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class SIG(Msg):
    """Partial signal count for ``phase`` flowing toward the head-signaler."""

    phase: int
    count: int
    closing: bool = True  # True: sender's once-per-phase aggregate report;
    #                       False: pass-through relay (not in anyone's books)
    lid: int = 0


@dataclass(frozen=True)
class ADV(Msg):
    """Phase-advance notification diffusing through the SNSL. Carries the
    highest released phase (monotone), so a single ADV catches a node up."""

    phase: int
    lid: int = 1


ALL_KINDS: Tuple[str, ...] = (
    "TUS", "TDS", "MURS", "MURS_ACK", "AT", "ENSP",
    "MULS1", "MULS2", "MULS3", "UNL", "UNL_ACK", "DEREG",
    "PRV", "NXT", "CHILD_ADD", "CHILD_ADD_ACK", "CHILD_DEL", "SIG", "ADV",
)

STRUCTURAL_KINDS: Tuple[str, ...] = (
    "TUS", "TDS", "MURS", "MURS_ACK", "AT", "ENSP",
    "MULS1", "MULS2", "MULS3", "UNL", "UNL_ACK", "DEREG",
    "PRV", "NXT", "CHILD_ADD", "CHILD_ADD_ACK", "CHILD_DEL",
)

SYNC_KINDS: Tuple[str, ...] = ("SIG", "ADV")
