"""Explicit-state model checking of the distributed-phaser protocol.

Reproduces the paper's §4 methodology natively (SPIN is unavailable offline;
DESIGN.md §2): bounded explicit-state exploration over message-delivery
interleavings, with the paper's key idea — **message-based decomposition** of
the state space. A run designates a *focus* message class; deliveries of
non-focus messages are collapsed to one canonical order (they commute with
respect to the checked properties once their own class has been verified),
while deliveries of focus-class messages branch exhaustively. Running one
pass per message class (Table 1: TUS, TDS, MURS, MULS-1/2/3, AT, ENSP)
yields complete coverage of each handler's interleavings at a fraction of
the joint state space — the same engineering the paper used to get SPIN to
complete.

Checked properties (DESIGN.md §2):
  P1 structure   — level-0 chain is exactly the live membership, sorted;
                   every lane l links exactly the keys with height > l.
  P2 conservation— no signal lost or double-counted (head over-collection
                   asserts inline; final count checked at quiescence).
  P3 safety      — phase k is released only when every task registered for
                   k has signaled k (checked at the release instant).
  P4 liveness    — every maximal path quiesces (no deadlock) and reaches
                   the expected final phase.
  P5 promotion   — at quiescence every node reached its drawn height.
"""
from __future__ import annotations

import copy
import hashlib
import itertools
from dataclasses import dataclass, field
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple

from . import messages as M
from .phaser import DistPhaser, PhaserActor, SIG_WAIT, SCSL, SNSL
from .runtime import Network
from .skiplist import HEAD

Scenario = Callable[[], Tuple[DistPhaser, dict]]


# ---------------------------------------------------------------------------
# State canonicalization
# ---------------------------------------------------------------------------
def _list_key(st) -> tuple:
    return (
        st.height, tuple(st.nxt), tuple(st.prv), st.member, st.joined,
        st.departed,
        tuple(sorted((c, tuple(tuple(iv) for iv in ivs))
                     for c, ivs in st.books.items())),
        tuple(tuple(iv) for iv in st.adv), st.closed,
        tuple(sorted(st.buf.items())),
        tuple(sorted((k, tuple(sorted(v))) for k, v in st.reported.items())),
        tuple(sorted(st.selfsig)), st.first_phase, st.dereg_phase,
        tuple(sorted(st.latch.items())),
        tuple(sorted((l, tuple(q)) for l, q in st.latch_q.items())),
        tuple(sorted((l, tuple(q)) for l, q in st.defer_q.items())),
        tuple(sorted((l, tuple(repr(u) for u in q))
                     for l, q in st.unl_park.items())),
        tuple(repr(x) for x in st.join_defer),
        st.released, st.dropping, st.unlink_level, st.unlink_waiting,
        st.unl_sent_succ, st.unl0_sent, tuple(st.splice_defer),
        st.final_childdel_sent,
        st.target_height, st.rp_pending, st.rp_queue,
    )


def _actor_key(a: PhaserActor) -> tuple:
    return (a.rank, a.mode, a.sig_next, a.wait_next, a.presig,
            a.pending_drop, _list_key(a.sc), _list_key(a.sn),
            a.expected_base, tuple(sorted(a.deltas.items())),
            a.head_released)


def state_digest(ph: DistPhaser) -> bytes:
    chans = tuple(sorted(
        (c, tuple(repr(e.msg) for e in q))
        for c, q in ph.net.channels.items() if q))
    actors = tuple(_actor_key(a) for _, a in sorted(ph.actors.items()))
    blob = repr((chans, actors, tuple(ph.release_log))).encode()
    return hashlib.blake2b(blob, digest_size=16).digest()


# ---------------------------------------------------------------------------
# Safety monitors
# ---------------------------------------------------------------------------
class PropertyViolation(AssertionError):
    pass


def release_monitor(ph: DistPhaser, k: int) -> None:
    """P3: at the instant the head releases phase k, every task registered
    for k (eager insert complete, first_phase <= k < dereg bound) must have
    signaled k."""
    for r, a in ph.actors.items():
        if r == HEAD or not a.sc.member or not a.sc.joined:
            continue
        st = a.sc
        active = (st.first_phase <= k
                  and (st.dereg_phase is None or k < st.dereg_phase))
        if active and k not in st.selfsig:
            raise PropertyViolation(
                f"P3: phase {k} released but task {r} "
                f"(first={st.first_phase}, dereg={st.dereg_phase}) "
                f"has not signaled it")


def check_transient(ph: DistPhaser) -> None:
    """Invariants that must hold in *every* reachable state."""
    head_rel = ph.actors[HEAD].head_released
    for r, a in ph.actors.items():
        if r == HEAD:
            continue
        if a.sn.member and a.sn.released > head_rel:
            raise PropertyViolation(
                f"P3(w): waiter {r} released {a.sn.released} > head "
                f"{head_rel}")


def check_quiescent(ph: DistPhaser, expect: dict) -> None:
    """Invariants at idle states: structure (P1), liveness targets (P4),
    promotion completion (P5)."""
    ph.check_quiescent_invariants()  # P1 across both lists
    if "final_phase" in expect:
        got = ph.actors[HEAD].head_released
        if got != expect["final_phase"]:
            raise PropertyViolation(
                f"P4: quiesced at released={got}, expected "
                f"{expect['final_phase']}")
    for r, a in ph.actors.items():
        if r == HEAD:
            continue
        for st in (a.sc, a.sn):
            if st.member and st.joined and not st.departed \
                    and not st.dropping:
                if st.height != st.target_height:
                    raise PropertyViolation(
                        f"P5: {r} lid={st.lid} height {st.height} != "
                        f"target {st.target_height}")
    # P2 at quiescence (conservation): no negative buffers anywhere; the
    # head must hold no residual counts for phases it already released (a
    # residual means a signal was double-counted or a registration delta
    # was lost); no node may hold a stuck count for a phase it closed.
    head = ph.actors[HEAD]
    for k, cnt in head.sc.buf.items():
        if cnt > 0 and k <= head.head_released:
            raise PropertyViolation(
                f"P2: head holds {cnt} residual count(s) for released "
                f"phase {k} (lost registration delta or double count)")
    for r, a in ph.actors.items():
        for st in (a.sc, a.sn):
            for ph_k, cnt in st.buf.items():
                if cnt < 0:
                    raise PropertyViolation(f"P2: negative buffer at {r}")
                if r != HEAD and st.lid == SCSL and cnt > 0 \
                        and ph_k <= st.closed:
                    raise PropertyViolation(
                        f"P2: {r} holds stuck count for closed phase {ph_k}")


# ---------------------------------------------------------------------------
# The checker
# ---------------------------------------------------------------------------
@dataclass
class CheckStats:
    focus: str
    states: int = 0
    transitions: int = 0
    quiescent: int = 0
    truncated: bool = False
    violations: List[str] = field(default_factory=list)


def _focus_channels(net: Network, focus: frozenset) -> List[tuple]:
    return [c for c in net.nonempty_channels()
            if net.channels[c][0].msg.kind in focus]


def _nonfocus_channels(net: Network, focus: frozenset) -> List[tuple]:
    return [c for c in net.nonempty_channels()
            if net.channels[c][0].msg.kind not in focus]


def _drain_nonfocus(ph: DistPhaser, focus: frozenset) -> None:
    """Deliver non-focus channel heads in canonical (sorted) order until
    every channel head is focus-class. Monitors run on the way."""
    while True:
        nf = _nonfocus_channels(ph.net, focus)
        if not nf:
            return
        ph.net.deliver_from(nf[0])
        check_transient(ph)


def check(scenario: Scenario, focus_kinds: Sequence[str], *,
          max_states: int = 200_000) -> CheckStats:
    """Exhaustively explore interleavings of ``focus_kinds`` deliveries (all
    other messages delivered in canonical order between branch points)."""
    focus = frozenset(focus_kinds)
    stats = CheckStats(focus="+".join(sorted(focus_kinds)))
    root, expect = scenario()
    root.release_monitor = release_monitor
    stack = [root]
    visited = set()
    while stack:
        ph = stack.pop()
        try:
            _drain_nonfocus(ph, focus)
        except PropertyViolation as e:
            stats.violations.append(str(e))
            continue
        d = state_digest(ph)
        if d in visited:
            continue
        visited.add(d)
        stats.states += 1
        if stats.states >= max_states:
            stats.truncated = True
            break
        chans = _focus_channels(ph.net, focus)
        if not chans:
            assert ph.net.idle()
            stats.quiescent += 1
            try:
                check_quiescent(ph, expect)
            except PropertyViolation as e:
                stats.violations.append(str(e))
            continue
        for c in chans:
            child = copy.deepcopy(ph)
            try:
                child.net.deliver_from(c)
                check_transient(child)
            except PropertyViolation as e:
                stats.violations.append(str(e))
                continue
            stats.transitions += 1
            stack.append(child)
    return stats


def check_decomposed(scenario: Scenario, *, classes: Optional[Sequence[
        Sequence[str]]] = None, max_states: int = 200_000) -> List[CheckStats]:
    """The paper's Table-1 run: one exploration per message class."""
    if classes is None:
        classes = [("TUS",), ("TDS",), ("MURS", "MURS_ACK"),
                   ("MULS1",), ("MULS2",), ("MULS3",),
                   ("AT",), ("ENSP",), ("SIG",), ("ADV",),
                   ("PRV", "CHILD_ADD", "CHILD_ADD_ACK", "CHILD_DEL"),
                   ("UNL", "UNL_ACK", "DEREG")]
    return [check(scenario, cls, max_states=max_states) for cls in classes]


def check_full(scenario: Scenario, *, max_states: int = 200_000) -> CheckStats:
    """Straightforward joint exploration (what made SPIN run out of memory
    in the paper) — used by benchmarks to demonstrate the blowup."""
    return check(scenario, list(M.ALL_KINDS), max_states=max_states)


# ---------------------------------------------------------------------------
# Scenarios (paper Fig. 2 and friends)
# ---------------------------------------------------------------------------
def scenario_eager_insert(n: int = 3, new_id: int = 10, parent: int = 0,
                          signals: int = 1, seed: int = 0) -> Scenario:
    """Paper Fig. 2: a team of n, task ``parent`` asyncs ``new_id`` in while
    every member signals ``signals`` phases concurrently."""

    def make():
        ph = DistPhaser(n, seed=seed)
        ph.async_add(parent, new_id)
        for k in range(signals):
            for r in range(n):
                ph.signal(r)
        # the new task signals as soon as it can (pre-join buffering)
        for k in range(signals):
            ph.signal(new_id)
        return ph, {"final_phase": signals - 1}

    return make


def scenario_delete(n: int = 4, victim: int = 2, signals: int = 1,
                    seed: int = 0) -> Scenario:
    """Concurrent deletion + signaling."""

    def make():
        ph = DistPhaser(n, seed=seed)
        for r in range(n):
            if r != victim:
                ph.signal(r)
        ph.drop(victim)
        return ph, {"final_phase": signals - 1 if signals else -1}

    return make


def scenario_insert_delete(n: int = 3, seed: int = 0) -> Scenario:
    """Simultaneous add + drop + signal traffic."""

    def make():
        ph = DistPhaser(n, seed=seed)
        ph.async_add(0, 10)
        ph.drop(n - 1)
        for r in range(n - 1):
            ph.signal(r)
        ph.signal(10)
        return ph, {"final_phase": 0}

    return make


def scenario_double_insert(n: int = 3, seed: int = 0) -> Scenario:
    """Two concurrent insertions (C=2 lazy-promotion group)."""

    def make():
        ph = DistPhaser(n, seed=seed)
        ph.async_add(0, 10)
        ph.async_add(1, 11)
        for r in range(n):
            ph.signal(r)
        ph.signal(10)
        ph.signal(11)
        return ph, {"final_phase": 0}

    return make
