"""Phaser creation: recursive-doubling collective build of the SCSL/SNSL.

The paper builds the skip lists at phaser-creation time with the log(n)
recursive-doubling algorithm of Egecioglu, Koc & Laub [2] *without
wrap-around*: in round r (r = 0..ceil(log2 n)-1) every task i exchanges its
accumulated knowledge with its hypercube neighbor i XOR 2^r (when that
neighbor exists; no wrap-around). After ceil(log2 n) rounds every task knows
the (key, height) table of the whole team and derives its own links locally
— zero additional communication, identical structure on every rank.

This module simulates that exchange faithfully (message/round accounting
included) and verifies convergence to the sequential oracle
(``skiplist.SkipList``). The data-plane analog — the same exchange pattern
as a ppermute schedule — lives in ``core/collective.py`` as
``recursive_doubling_schedule``.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

from .skiplist import HEAD, SkipList, det_height


@dataclass
class CreationStats:
    n: int
    rounds: int
    messages: int
    bytes_exchanged: int  # table entries exchanged (8B keys + 1B heights)


def recursive_doubling_build(
    keys: List[int], *, p: float = 0.5, max_height: int = 32, seed: int = 0,
    phaser_id: int = 0,
) -> Tuple[Dict[int, SkipList], CreationStats]:
    """Run the log-n recursive-doubling exchange among ``keys``.

    Returns ({rank: locally derived SkipList}, stats). Every local structure
    is identical (asserted by tests) and equals the sequential oracle.
    """
    n = len(keys)
    order = sorted(keys)
    # knowledge[i] = set of (key, height) pairs task at position i knows
    heights = {k: det_height(k, p=p, max_height=max_height, seed=seed,
                             phaser_id=phaser_id) for k in order}
    knowledge: List[Dict[int, int]] = [{k: heights[k]} for k in order]

    # Non-power-of-2 teams: fold the ``extras`` (positions >= m, the largest
    # power of two <= n) into their hypercube images, run the pure XOR
    # exchange on the 2^k core, then unfold — the standard no-wrap-around
    # completion of recursive doubling (adds <= 2 rounds, stays O(log n)).
    messages = 0
    entries = 0
    rounds = 0
    m = 1 << (n.bit_length() - 1)   # largest power of two <= n
    extras = n - m
    if extras:
        rounds += 1
        for i in range(m, n):
            messages += 1
            entries += len(knowledge[i])
            knowledge[i - m].update(knowledge[i])
    core_rounds = int(math.log2(m)) if m > 1 else 0
    for r in range(core_rounds):
        stride = 1 << r
        rounds += 1
        updates: List[Optional[Dict[int, int]]] = [None] * m
        for i in range(m):
            j = i ^ stride
            messages += 1          # i -> j (each direction counted once)
            entries += len(knowledge[i])
            merged = dict(knowledge[j])
            merged.update(knowledge[i])
            updates[j] = merged
        for i in range(m):
            if updates[i] is not None:
                knowledge[i] = updates[i]
    if extras:
        rounds += 1
        for i in range(m, n):
            messages += 1
            entries += len(knowledge[i - m])
            knowledge[i] = dict(knowledge[i - m])

    # Each rank derives the full structure locally from its table.
    locals_: Dict[int, SkipList] = {}
    for i, k in enumerate(order):
        assert len(knowledge[i]) == n, (
            f"rank {k} knows {len(knowledge[i])}/{n} after {rounds} rounds")
        sl = SkipList(p=p, max_height=max_height, seed=seed,
                      phaser_id=phaser_id)
        for kk in sorted(knowledge[i]):
            sl.insert(kk, height=knowledge[i][kk])
        locals_[k] = sl
    stats = CreationStats(n=n, rounds=rounds, messages=messages,
                          bytes_exchanged=entries * 9)
    return locals_, stats


def verify_creation(n: int, **kw) -> CreationStats:
    """Build collectively, check all ranks converge to the oracle."""
    keys = list(range(n))
    locals_, stats = recursive_doubling_build(keys, **kw)
    oracle = SkipList.build(keys, **kw)
    oracle_edges = oracle.collection_edges()
    for rank, sl in locals_.items():
        assert sl.collection_edges() == oracle_edges, f"rank {rank} diverged"
        sl.check_integrity()
    return stats
