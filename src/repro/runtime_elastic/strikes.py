"""Escalating straggler policy, shared across membership granularities.

The split-phase slack argument (paper §5) says a slow participant only
hurts once its subtree gates someone else's combining path, so the
response escalates instead of evicting on first offense:

  strike 1                -> "straggle"  (recorded, no structural op)
  strike ``demote_after`` -> "demote"    (pin to a leaf of the SCSL
                                          reduce tree: fewest dependents)
  strike ``evict_after``  -> "evict"     (the deletion/fail path)
  recovery                -> "recover"   (re-promote to drawn height)

``ElasticPhaserRuntime.record_step_times`` applies it to single-host
workers; the multi-process coordinator (``runtime_dist``) applies the
same policy to whole hosts — eviction of a process is the paper's
deletion at host granularity.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, Iterable, List, Optional


@dataclass(frozen=True)
class StrikeAction:
    worker: int
    action: str  # "straggle" | "demote" | "evict" | "recover"


class StrikeEscalation:
    """Strike bookkeeping + escalation decisions.

    ``observe`` walks the live set against the step times and invokes
    ``on_action`` *inline* as each decision is made — an eviction may
    shrink ``live`` before the next participant is considered, exactly
    like the historical in-loop behavior. Strike counts persist across
    calls on the instance (``strikes`` may be handed a shared dict)."""

    def __init__(self, *, slack: float = 3.0, demote_after: int = 2,
                 evict_after: int = 3,
                 strikes: Optional[Dict[int, int]] = None,
                 metrics=None):
        self.slack = slack
        self.demote_after = demote_after
        self.evict_after = evict_after
        self.strikes: Dict[int, int] = strikes if strikes is not None else {}
        if metrics is None:
            from ..obs.metrics import default_registry
            metrics = default_registry()
        self.metrics = metrics

    def forget(self, worker: int) -> None:
        self.strikes.pop(worker, None)

    def observe(self, live, times: Dict[int, float], *,
                demoted: Iterable[int] = (),
                on_action: Optional[Callable[[StrikeAction], None]] = None,
                compile_step: bool = False,
                waits: Optional[Dict[int, float]] = None
                ) -> List[StrikeAction]:
        """One step's observation. ``live`` and ``demoted`` are read
        live (the callback may mutate them); returns every action
        emitted, in order. A ``compile_step`` (the first step after a
        boundary re-lower) is recorded in the metrics but exempt from
        strike accounting: compile/warmup skew is not straggling.

        ``waits`` (optional) is the watermark layer's per-participant
        blocked-on-WAIT seconds for the step window: time spent waiting
        on *peers* is subtracted before the slack test, so a host that
        is slow because someone else gated it is a victim, not a
        culprit — attribution, not just magnitude."""
        wait_of = (lambda w: 0.0) if waits is None else \
            (lambda w: max(0.0, waits.get(w, 0.0)))

        def eff(w: int, t: float) -> float:
            return max(0.0, t - min(wait_of(w), t))

        live_times = [eff(w, times[w]) for w in live if w in times]
        if not live_times:
            return []
        med = sorted(live_times)[len(live_times) // 2]
        for t in live_times:
            self.metrics.observe("strikes.step_seconds", t)
        self.metrics.set("strikes.step_median_s", med)
        if compile_step:
            self.metrics.inc("strikes.compile_steps")
            return []
        out: List[StrikeAction] = []

        def emit(worker: int, action: str) -> None:
            act = StrikeAction(worker, action)
            out.append(act)
            self.metrics.inc(f"strikes.{action}")
            if on_action is not None:
                on_action(act)

        for w in sorted(live):
            t = times.get(w)
            if t is not None:
                t = eff(w, t)
            if t is not None and t > self.slack * med:
                self.strikes[w] = self.strikes.get(w, 0) + 1
                emit(w, "straggle")
                if self.strikes[w] >= self.evict_after and len(live) > 1:
                    emit(w, "evict")
                elif self.strikes[w] >= self.demote_after:
                    emit(w, "demote")
            else:
                if self.strikes.get(w, 0) and w in demoted:
                    emit(w, "recover")
                self.strikes[w] = 0
        return out
