from .elastic_phaser import ElasticPhaserRuntime, Epoch, WorkerEvent
from .membership import ElasticController
from .strikes import StrikeAction, StrikeEscalation

__all__ = ["ElasticController", "ElasticPhaserRuntime", "Epoch",
           "StrikeAction", "StrikeEscalation", "WorkerEvent"]
