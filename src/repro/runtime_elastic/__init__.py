from .elastic_phaser import ElasticPhaserRuntime, Epoch, WorkerEvent
from .membership import ElasticController

__all__ = ["ElasticController", "ElasticPhaserRuntime", "Epoch",
           "WorkerEvent"]
