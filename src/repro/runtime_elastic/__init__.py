from .membership import ElasticController, WorkerEvent

__all__ = ["ElasticController", "WorkerEvent"]
