"""Elastic membership: the paper's dynamic phaser protocol driving the
data-plane worker group.

The mapping (DESIGN.md §2-3):

* each data-parallel worker is a phaser participant in SIG_WAIT mode;
* one training step == one phaser phase: a worker signals when its
  gradient contribution is ready; the optimizer step is released when the
  phase advances (all live signalers signaled);
* JOIN  == paper's eager insertion: the joining worker is admitted
  immediately (its first_phase is assigned by the protocol) — O(1) on the
  data plane. The topology-optimal collective schedule is re-derived
  LAZILY at the next phase boundary (the paper's hand-over-hand
  promotion, lifted to epoch granularity — see elastic_phaser.py);
* LEAVE/FAIL == deletion: DEREG lowers the phase expectation so the phase
  can still complete without the failed worker;
* STRAGGLER quorum == split-phase: with signal(), fast workers proceed
  into the next step's compute before wait()ing — the phaser's fuzzy
  barrier gives the slack window.

``ElasticController`` is the stable worker-group facade kept for existing
callers; the epoch machinery itself lives in ``ElasticPhaserRuntime``
(this class *is* one, plus a membership mask and the legacy naming).
"""
from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np

from .elastic_phaser import ElasticPhaserRuntime, Epoch, WorkerEvent
from ..core.collective import PhaserCollective

__all__ = ["ElasticController", "ElasticPhaserRuntime", "Epoch",
           "WorkerEvent"]


class ElasticController(ElasticPhaserRuntime):
    """Host-side controller coordinating the worker group with a real
    distributed-phaser instance (legacy facade over the epoch runtime)."""

    def __init__(self, n_workers: int, *, seed: int = 0,
                 kind: str = "phaser_scsl"):
        super().__init__(n_workers, seed=seed, kind=kind)
        self.n = n_workers
        self.mask = np.ones((n_workers,), bool)

    # ------------------------------------------------------------ topology
    def collective(self, kind: Optional[str] = None) -> PhaserCollective:
        """Current-epoch collective schedule for the data axis. Passing a
        ``kind`` overrides the epoch's preferred schedule (derived over
        the same live keys; every kind covers any team size via the
        elimination derivations)."""
        ep = self.epoch
        kind = self._kind_for(len(ep.live), kind)
        if kind == ep.kind:
            return super().collective()
        return PhaserCollective(len(ep.live), self.axis_name, kind=kind,
                                seed=self.seed, keys=ep.live)

    def loss_scale(self) -> float:
        """Re-weighting when the live set shrank mid-epoch (masked mean)."""
        return self.mask.sum() / max(len(self.mask), 1)

    # -------------------------------------------------------------- events
    def request_join(self, parent: Optional[int] = None, *,
                     step: Optional[int] = None, **kw) -> int:
        wid = super().request_join(parent, step=step, **kw)
        self._grow_mask(wid)
        self.mask[wid] = True
        return wid

    def request_leave(self, worker: int, *, fail: bool = False,
                      step: Optional[int] = None) -> None:
        super().request_leave(worker, fail=fail, step=step)
        if worker < len(self.mask):
            self.mask[worker] = False

    def join(self, step: int, parent: Optional[int] = None) -> int:
        """Eager admission of a new worker (paper Fig. 2)."""
        return self.request_join(parent, step=step)

    def leave(self, step: int, worker: int, *, fail: bool = False) -> None:
        """Deletion (graceful) or failure (detected by missed heartbeat)."""
        self.request_leave(worker, fail=fail, step=step)

    def _grow_mask(self, wid: int) -> None:
        if wid >= len(self.mask):
            m = np.zeros((wid + 1,), bool)
            m[:len(self.mask)] = self.mask
            self.mask = m

    # ------------------------------------------------------------ stepping
    def step_barrier(self, step: int,
                     signals: Optional[Dict[int, bool]] = None) -> int:
        """One training-step phase: live workers signal, phase advances,
        pending membership changes land as a new epoch at the boundary."""
        return self.advance(step=step)

    # ---------------------------------------------------------- inspection
    @property
    def schedule_epoch(self) -> int:
        """Number of lazy schedule re-derivations that have landed."""
        return self.epoch.index

    def stats(self) -> Dict:
        st = super().stats()
        st["schedule_epoch"] = self.schedule_epoch
        return st
