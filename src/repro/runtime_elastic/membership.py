"""Elastic membership: the paper's dynamic phaser protocol driving the
data-plane worker group.

The mapping (DESIGN.md §2):

* each data-parallel worker is a phaser participant in SIG_WAIT mode;
* one training step == one phaser phase: a worker signals when its
  gradient contribution is ready; the optimizer step is released when the
  phase advances (all live signalers signaled);
* JOIN  == paper's eager insertion: the joining worker is admitted at the
  next phase boundary (its first_phase is assigned by the protocol) and
  the membership mask flips — O(1) on the data plane. The topology-optimal
  collective schedule is re-derived LAZILY (the paper's hand-over-hand
  promotion): re-lowering happens in the background while training
  continues on the masked schedule;
* LEAVE/FAIL == deletion: DEREG lowers the phase expectation so the phase
  can still complete without the failed worker; its mask entry flips off;
* STRAGGLER quorum == split-phase: with signal(), fast workers proceed
  into the next step's compute before wait()ing — the phaser's fuzzy
  barrier gives the slack window.

The controller runs the *actual protocol actors* (core/phaser.py), so its
decisions inherit the model-checked correctness properties.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Set, Tuple

import numpy as np

from ..core.phaser import SIG_WAIT, DistPhaser
from ..core.collective import PhaserCollective
from ..core.runtime import FifoScheduler


@dataclass
class WorkerEvent:
    step: int
    kind: str        # "join" | "leave" | "fail" | "straggle"
    worker: int


class ElasticController:
    """Host-side controller coordinating the worker group with a real
    distributed-phaser instance."""

    def __init__(self, n_workers: int, *, seed: int = 0):
        self.n = n_workers
        self.ph = DistPhaser(n_workers, seed=seed)
        self.live: Set[int] = set(range(n_workers))
        self.next_worker_id = n_workers
        self.events: List[WorkerEvent] = []
        self.mask = np.ones((n_workers,), bool)
        self.schedule_epoch = 0      # bumped when lazy re-derivation lands
        self._pending_lazy = False

    # ------------------------------------------------------------ topology
    def collective(self, kind: str = "phaser_scsl") -> PhaserCollective:
        """Current-topology collective schedule for the data axis."""
        return PhaserCollective(len(self.live), "data", kind=kind)

    def loss_scale(self) -> float:
        """Re-weighting when the live set shrank mid-epoch (masked mean)."""
        return self.mask.sum() / max(len(self.mask), 1)

    # -------------------------------------------------------------- events
    def join(self, step: int, parent: Optional[int] = None) -> int:
        """Eager admission of a new worker (paper Fig. 2)."""
        wid = self.next_worker_id
        self.next_worker_id += 1
        parent = parent if parent is not None else min(self.live)
        self.ph.async_add(parent, wid, SIG_WAIT)
        self.ph.run(FifoScheduler())        # drive to quiescence
        self.live.add(wid)
        self._grow_mask(wid)
        self.mask[wid] = True
        self.events.append(WorkerEvent(step, "join", wid))
        self._pending_lazy = True           # schedule re-derivation queued
        return wid

    def leave(self, step: int, worker: int, *, fail: bool = False) -> None:
        """Deletion (graceful) or failure (detected by missed heartbeat):
        either way the phaser DEREG lowers the expectation so the current
        phase completes without the worker."""
        assert worker in self.live
        self.ph.drop(worker)
        self.ph.run(FifoScheduler())
        self.live.discard(worker)
        self.mask[worker] = False
        self.events.append(WorkerEvent(step, "fail" if fail else "leave",
                                       worker))
        self._pending_lazy = True

    def _grow_mask(self, wid: int) -> None:
        if wid >= len(self.mask):
            m = np.zeros((wid + 1,), bool)
            m[:len(self.mask)] = self.mask
            self.mask = m

    # ------------------------------------------------------------ stepping
    def step_barrier(self, step: int,
                     signals: Optional[Dict[int, bool]] = None) -> int:
        """One training-step phase: live workers signal, phase advances.
        ``signals``: worker -> did it produce a gradient this step (False
        simulates a straggler that still signals count-0 via split-phase
        semantics; the phaser itself requires the signal, the QUORUM
        decision is the caller's)."""
        for w in sorted(self.live):
            self.ph.signal(w)
        self.ph.run(FifoScheduler())
        released = self.ph.released()
        # lazy re-derivation lands at a phase boundary
        if self._pending_lazy:
            self.schedule_epoch += 1
            self._pending_lazy = False
        return released

    # -------------------------------------------------------- stragglers
    def record_step_times(self, step: int, times: Dict[int, float], *,
                          slack: float = 3.0,
                          evict_after: int = 3) -> List[int]:
        """Straggler policy on top of the split-phase slack: a worker
        slower than ``slack``x the live median accumulates a strike;
        ``evict_after`` consecutive strikes converts it to a deletion
        (the phaser DEREG keeps the phase completing without it, exactly
        the fail path). Returns workers evicted this step."""
        if not hasattr(self, "_strikes"):
            self._strikes: Dict[int, int] = {}
        live_times = [times[w] for w in self.live if w in times]
        if not live_times:
            return []
        med = sorted(live_times)[len(live_times) // 2]
        evicted = []
        for w in list(self.live):
            t = times.get(w)
            if t is not None and t > slack * med:
                self._strikes[w] = self._strikes.get(w, 0) + 1
                self.events.append(WorkerEvent(step, "straggle", w))
                if self._strikes[w] >= evict_after and len(self.live) > 1:
                    self.leave(step, w, fail=True)
                    evicted.append(w)
            else:
                self._strikes[w] = 0
        return evicted

    # ---------------------------------------------------------- inspection
    def stats(self) -> Dict:
        return {
            "live": sorted(self.live),
            "phase": self.ph.released(),
            "schedule_epoch": self.schedule_epoch,
            "messages": dict(self.ph.net.sent),
            "critical_path": self.ph.net.max_depth,
        }
