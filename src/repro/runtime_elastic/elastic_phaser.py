"""ElasticPhaserRuntime: membership epochs driven through the real phaser.

This is the unified control plane the paper's two-phase structural
protocol implies (DESIGN.md §3): join/leave requests enter the live
protocol actors as *eager level-0 splices* (the paper's fast path), the
next phase advance marks the **epoch boundary**, and at each boundary the
deterministic skip-list oracle re-derives the topology and swaps the
compiled collective schedule for the following epoch (the paper's *lazy*
hand-over-hand promotion, lifted to the data plane: re-derivation is
deferred to a phase boundary so no in-flight step ever observes a
half-swapped schedule).

Lifecycle of one epoch:

  epoch e: [phase k ........ phase k']      schedule_e  (compiled, static)
      |                          |
      |   request_join/leave --> eager splice on SCSL/SNSL actors
      |   (protocol runs to quiescence; phases keep completing)
      |                          |
      +--- advance() at k': membership changed since e started?
                               -> derive oracle over live keys
                               -> build schedule_{e+1}, fire on_epoch
                               -> epoch e+1 begins at phase k'+1

Everything the data plane consumes (the collective schedule, the live
set, the loss re-weighting mask) is versioned by the epoch index, so a
trainer/server re-lowers exactly once per boundary and is otherwise
static — the paper's O(log n) synchronization cost is preserved across
churn because the *protocol* absorbs the structural work, not the step.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Set, Tuple

from ..core.collective import (ALLREDUCE_KINDS, PhaserCollective,
                               scsl_reduce_schedule, snsl_broadcast_schedule)
from ..core.phaser import SCSL, SNSL, SIG_WAIT, DistPhaser
from ..core.runtime import FifoScheduler, Scheduler
from ..core.skiplist import HEAD, SkipList
from .strikes import StrikeAction, StrikeEscalation


@dataclass
class WorkerEvent:
    step: int
    # "join" | "leave" | "fail" | "straggle" | "demote" | "repromote"
    kind: str
    worker: int


@dataclass(frozen=True)
class Epoch:
    """One membership epoch: a maximal run of phases with a fixed live
    set, executing one compiled collective schedule."""

    index: int
    phase_start: int                 # first phase this epoch's schedule gates
    live: Tuple[int, ...]            # sorted live keys
    kind: str                        # schedule actually compiled (post-fallback)
    collective: Optional[PhaserCollective]   # None iff live is empty

    @property
    def n(self) -> int:
        return len(self.live)

    def stats(self) -> Dict[str, int]:
        if self.collective is None:
            return {"rounds": 0, "messages": 0}
        return self.collective.stats()


class ElasticPhaserRuntime:
    """Drives membership epochs through the protocol actors.

    ``kind`` is the per-epoch gradient-sync schedule. Every kind is valid
    for any team size (non-power-of-two teams get the elimination
    derivations in ``core/collective.py``), so an epoch's kind equals the
    preference — the historical fallback to ``phaser_scsl`` is gone.
    """

    def __init__(self, n_workers: int, *, seed: int = 0,
                 kind: str = "phaser_scsl",
                 scheduler: Optional[Callable[[], Scheduler]] = None,
                 axis_name: str = "data"):
        assert kind in ALLREDUCE_KINDS, kind
        self.seed = seed
        self.kind = kind
        self.axis_name = axis_name
        self._make_scheduler = scheduler or FifoScheduler
        self.ph = DistPhaser(n_workers, seed=seed)
        self.live: Set[int] = set(range(n_workers))
        self.next_worker_id = n_workers
        self.events: List[WorkerEvent] = []
        self._dirty = False              # membership changed since last boundary
        self._step = 0                   # caller-side step counter (for events)
        self.epochs: List[Epoch] = [self._derive_epoch(0, 0)]
        self._on_epoch: List[Callable[[Epoch, Epoch], None]] = []
        self._strikes: Dict[int, int] = {}
        # first observed step after a program (re)compile pays
        # compile+warmup: record_step_times exempts it from strikes.
        # Armed by bind_program_cache and at boundaries with re-lower
        # hooks; a control-only runtime never compiles, so never tags.
        self._compile_pending = False

    # ------------------------------------------------------------- epochs
    @property
    def epoch(self) -> Epoch:
        return self.epochs[-1]

    @property
    def epoch_index(self) -> int:
        return self.epoch.index

    @property
    def pending_churn(self) -> bool:
        """Membership changed since the current epoch was derived; the
        next ``advance()`` will land it as a new epoch."""
        return self._dirty

    def on_epoch(self, fn: Callable[[Epoch, Epoch], None]) -> None:
        """Register a boundary hook ``fn(old_epoch, new_epoch)`` — the
        data plane's re-lower trigger."""
        self._on_epoch.append(fn)

    def bind_program_cache(self, cache) -> None:
        """Attach an epoch-aware program cache (anything with
        ``.get(collective)``, e.g. ``collective_exec.ProgramCache``): the
        current epoch's program is compiled now, and every boundary
        compiles (or re-uses) the next epoch's program right at the phase
        advance — the data plane swaps executables instead of
        re-simulating the schedule on host. The cache's own extra key
        (overlap mode, bucket groups, microbatches) rides its entries,
        so overlapped programs swap at boundaries exactly like eager
        ones: the runtime only hands over the epoch's collective."""
        def hook(old: Epoch, new: Epoch) -> None:
            if new.collective is not None:
                cache.get(new.collective)
        self.on_epoch(hook)
        if self.epoch.collective is not None:
            cache.get(self.epoch.collective)
        self._compile_pending = True

    def _kind_for(self, n: int, kind: Optional[str] = None) -> str:
        """The schedule kind an epoch of ``n`` members compiles. Since
        the elimination derivations (PR 2) every kind covers every team
        size, so this is the preference itself; the hook is kept for
        callers that pass explicit overrides."""
        return kind if kind is not None else self.kind

    def _derive_epoch(self, index: int, phase_start: int) -> Epoch:
        keys = tuple(sorted(self.live))
        if not keys:
            return Epoch(index, phase_start, keys, self.kind, None)
        k = self._kind_for(len(keys))
        pc = PhaserCollective(len(keys), self.axis_name, kind=k,
                              seed=self.seed, keys=keys,
                              leaf_keys=tuple(sorted(self.ph.demoted
                                                     & self.live)))
        return Epoch(index, phase_start, keys, k, pc)

    # ------------------------------------------------------------- churn
    def request_join(self, parent: Optional[int] = None,
                     *, step: Optional[int] = None,
                     mode: str = SIG_WAIT) -> int:
        """Eager admission (paper Fig. 2): level-0 splice now, schedule
        swap at the next boundary. Returns the new worker id; it is a
        live signaler from this moment on."""
        wid = self.next_worker_id
        self.next_worker_id += 1
        if parent is None:
            parent = min(self.live) if self.live else HEAD
        self.ph.async_add(parent, wid, mode)
        self.ph.run(self._make_scheduler())     # splice + lazy promotion
        self.live.add(wid)
        self.events.append(WorkerEvent(self._at(step), "join", wid))
        self._dirty = True
        return wid

    def request_leave(self, worker: int, *, fail: bool = False,
                      step: Optional[int] = None) -> None:
        """Deletion (graceful) or failure: the phaser DEREG lowers the
        expectation so the in-flight phase completes without the worker;
        level-by-level unlink runs to quiescence."""
        assert worker in self.live, (worker, sorted(self.live))
        self.ph.drop(worker)
        self.ph.run(self._make_scheduler())
        self.live.discard(worker)
        self._strikes.pop(worker, None)
        self.events.append(WorkerEvent(self._at(step),
                                       "fail" if fail else "leave", worker))
        self._dirty = True

    def request_demote(self, worker: int, *,
                       step: Optional[int] = None) -> None:
        """Straggler demotion: the worker keeps signaling but is pinned
        to a leaf of the SCSL reduce tree (fewest dependents). Eager on
        the protocol (partial top-down unlink, run to quiescence); the
        schedule re-derives at the next boundary like any churn."""
        assert worker in self.live, (worker, sorted(self.live))
        if worker in self.ph.demoted:
            return
        self.ph.demote(worker)
        self.ph.run(self._make_scheduler())
        self.events.append(WorkerEvent(self._at(step), "demote", worker))
        self._dirty = True

    def request_repromote(self, worker: int, *,
                          step: Optional[int] = None) -> None:
        """Reverse a demotion once the worker keeps pace again."""
        if worker not in self.live or worker not in self.ph.demoted:
            return
        self.ph.repromote(worker)
        self.ph.run(self._make_scheduler())
        self.events.append(WorkerEvent(self._at(step), "repromote", worker))
        self._dirty = True

    @property
    def demoted(self) -> Set[int]:
        return set(self.ph.demoted)

    def _at(self, step: Optional[int]) -> int:
        return self._step if step is None else step

    # ----------------------------------------------------------- stepping
    def advance(self, *, step: Optional[int] = None) -> int:
        """One phase: every live signaler signals, the protocol runs to
        quiescence, and — if membership changed during the closing epoch —
        the boundary derives the next epoch's schedule. Returns the head's
        released phase."""
        for w in sorted(self.live):
            a = self.ph.actors[w]
            if a.sc.member and not a.sc.dropping:
                self.ph.signal(w)
        self.ph.run(self._make_scheduler())
        released = self.ph.released()
        if self._dirty:
            old = self.epoch
            new = self._derive_epoch(old.index + 1, released + 1)
            self.epochs.append(new)
            self._dirty = False
            if self._on_epoch:
                self._compile_pending = True   # boundary hooks re-lower
            for fn in self._on_epoch:
                fn(old, new)
        if step is not None:
            self._step = step
        self._step += 1
        return released

    # ----------------------------------------------------------- topology
    def collective(self) -> PhaserCollective:
        assert self.epoch.collective is not None, "empty team"
        return self.epoch.collective

    def epoch_key(self) -> Optional[Dict]:
        """JSON-serializable identity of the current epoch's collective
        — the (member_set, kind, seed, p) part of the program-cache key
        that checkpoints persist (the consumer appends its own overlap
        config). None for an empty team."""
        pc = self.epoch.collective
        if pc is None:
            return None
        return {"member_set": list(pc.keys), "kind": pc.kind,
                "seed": pc.seed, "p": pc.p, "axis": pc.axis_name,
                "leaf_keys": list(pc.leaf_keys)}

    def oracle(self) -> SkipList:
        """Deterministic skip list over the live keys (demoted keys at
        height 1) — what the protocol actors must have converged to at
        quiescence."""
        return SkipList.build(sorted(self.live), p=self.ph.p,
                              max_height=self.ph.max_height, seed=self.seed,
                              leaf_keys=self.ph.demoted)

    def protocol_topology(self, lid: int = SCSL) -> List[List[int]]:
        """Lane-by-lane chains extracted from the live protocol actors
        (lane 0 first). The ground truth the oracle is checked against."""
        lanes: List[List[int]] = []
        l = 0
        while True:
            st = self.ph.actors[HEAD].st(lid)
            cur = st.nxt[l] if l < len(st.nxt) else None
            lane = []
            while cur is not None:
                lane.append(cur)
                nst = self.ph.actors[cur].st(lid)
                cur = nst.nxt[l] if l < nst.height else None
            if not lane and l > 0:
                break
            lanes.append(lane)
            l += 1
        return lanes

    def verify_epoch(self) -> None:
        """Prove the current epoch against the protocol state:

        1. the actors' converged lanes == the deterministic oracle's lanes
           (both SCSL and SNSL), and
        2. the compiled schedule == the schedule re-derived from a fresh
           oracle over the live keys.

        Called at quiescence (after ``advance``); raises AssertionError on
        any divergence."""
        assert self.ph.net.idle(), "verify_epoch requires quiescence"
        sl = self.oracle()
        want = [lane for lane in sl.lanes() if lane] or [[]]
        for lid in (SCSL, SNSL):
            got = self.protocol_topology(lid)
            got = [lane for lane in got if lane] or [[]]
            assert got == want, \
                f"lid={lid}: protocol lanes {got} != oracle lanes {want}"
        ep = self.epoch
        assert ep.live == tuple(sorted(self.live))
        if ep.collective is not None:
            assert ep.collective.matches_oracle(), \
                f"epoch {ep.index}: schedule does not match oracle"
            if ep.kind == "phaser_scsl":
                up = scsl_reduce_schedule(sl, list(ep.live))
                down = snsl_broadcast_schedule(sl, list(ep.live))
                assert ep.collective.up == up
                assert ep.collective.down == down

    # --------------------------------------------------------- stragglers
    def record_step_times(self, step: int, times: Dict[int, float], *,
                          slack: float = 3.0,
                          demote_after: int = 2,
                          evict_after: int = 3) -> List[int]:
        """Straggler policy on the split-phase slack (the shared
        ``StrikeEscalation``, which the multi-process runtime applies to
        whole hosts): a worker slower than ``slack``x the live median
        accumulates a strike. The response escalates — at
        ``demote_after`` consecutive strikes the worker is **demoted**
        to a leaf of the SCSL reduce tree (fewest dependents: its
        slowness stops gating anyone else's combining subtree) while it
        keeps contributing; only at ``evict_after`` strikes is it
        evicted (the fail path). A worker that recovers (strike reset)
        is re-promoted to its drawn height. Returns workers evicted
        this step."""
        esc = StrikeEscalation(slack=slack, demote_after=demote_after,
                               evict_after=evict_after,
                               strikes=self._strikes)
        evicted: List[int] = []

        def apply(act: StrikeAction) -> None:
            if act.action == "straggle":
                self.events.append(WorkerEvent(step, "straggle",
                                               act.worker))
            elif act.action == "evict":
                self.request_leave(act.worker, fail=True, step=step)
                evicted.append(act.worker)
            elif act.action == "demote":
                self.request_demote(act.worker, step=step)
            elif act.action == "recover":
                self.request_repromote(act.worker, step=step)

        compile_step = self._compile_pending
        self._compile_pending = False
        esc.observe(self.live, times, demoted=self.ph.demoted,
                    on_action=apply, compile_step=compile_step)
        return evicted

    # --------------------------------------------------------- inspection
    def loss_scale(self) -> float:
        """Re-weighting when the live set shrank mid-epoch: live fraction
        of the peak team size seen so far."""
        return len(self.live) / max(self.next_worker_id, 1)

    def stats(self) -> Dict:
        return {
            "live": sorted(self.live),
            "phase": self.ph.released(),
            "epoch": self.epoch.index,
            "epochs": len(self.epochs),
            "kind": self.epoch.kind,
            "schedule": self.epoch.stats(),
            "messages": dict(self.ph.net.sent),
            "critical_path": self.ph.net.max_depth,
        }
