"""Collective execution engine (DESIGN.md §4).

Four layers of evidence:

1. schedule properties — every kind derives a valid ``Schedule`` for
   EVERY team size 2..12 (the elimination derivations cover non-powers
   of two) and its host simulation equals the direct sum;
2. a hypothesis property sweep over (n, kind, keys, values) — skipped
   where the dev-only dependency is missing;
3. bucket layout round-trips the grad pytree exactly, with the alive
   flag riding the buffer — including the reverse-topological order and
   per-bucket readiness groups of the overlap pipeline (DESIGN.md §5);
4. program cache: LRU recency on hits and eviction, overlap config in
   the key, and the epoch-boundary swap ordering (the next epoch's
   program is compiled inside the boundary, never mid-phase);
5. numeric (subprocess, 8 host devices): the bucketed shard_map
   executor with the fused Pallas combine equals ``xla_psum`` for every
   kind at pow2 AND non-pow2 team sizes; the compiled gradient-sync
   program produces the same updated params as the psum program; and
   the pipelined (overlapped) program is BITWISE equal to the eager one
   across grow 4->6 / shrink 6->3 elastic epochs.
"""
import math
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.collective_exec import ProgramCache, make_layout
from repro.core.collective import (ALLREDUCE_KINDS, PhaserCollective,
                                   recursive_doubling_schedule)
from repro.runtime_elastic import ElasticPhaserRuntime


# ----------------------------- non-pow2 schedules (deterministic sweep)
def test_all_kinds_all_team_sizes_simulate_equals_sum():
    rng = np.random.default_rng(0)
    for n in range(2, 13):
        keys = tuple(sorted(rng.choice(200, size=n,
                                       replace=False).tolist()))
        for kind in ALLREDUCE_KINDS:
            pc = PhaserCollective(n, "data", kind=kind, keys=keys,
                                  seed=n % 4)
            sched = pc.unified_schedule()
            if sched is not None:
                sched.check()
            xs = [rng.normal(size=23).astype(np.float32)
                  for _ in range(n)]
            out = pc.simulate_allreduce(xs)
            want = np.sum(np.stack(xs), axis=0)
            for i, o in enumerate(out):
                np.testing.assert_allclose(
                    o, want, rtol=1e-5, atol=1e-5,
                    err_msg=f"{kind} n={n} rank={i}")


def test_recursive_doubling_non_pow2_uses_elimination_rounds():
    s = recursive_doubling_schedule(6)
    s.check()
    # fold extras (add), 2 XOR rounds over the 4-core, hydrate (copy)
    assert s.depth == 4
    assert s.ops[0] == "add" and s.ops[-1] == "copy"
    assert recursive_doubling_schedule(8).ops == ("add",) * 3


def test_elastic_epochs_keep_preferred_kind_non_pow2():
    for kind in ("recursive_doubling", "halving_doubling"):
        rt = ElasticPhaserRuntime(4, seed=0, kind=kind)
        rt.request_join()
        rt.advance()
        assert rt.epoch.n == 5 and rt.epoch.kind == kind
        rt.verify_epoch()


# --------------------------------------------- hypothesis property
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYP = True
except ImportError:
    HAVE_HYP = False


if HAVE_HYP:
    @given(st.integers(2, 12), st.sampled_from(ALLREDUCE_KINDS),
           st.integers(0, 10_000))
    @settings(max_examples=60, deadline=None)
    def test_any_team_size_any_kind_schedule_is_sound(n, kind, seed):
        rng = np.random.default_rng(seed)
        keys = tuple(sorted(rng.choice(500, size=n,
                                       replace=False).tolist()))
        pc = PhaserCollective(n, "data", kind=kind, keys=keys,
                              seed=seed % 7)
        sched = pc.unified_schedule()
        if sched is not None:
            sched.check()
        xs = [rng.normal(size=int(rng.integers(1, 40)))
              .astype(np.float32) for _ in range(n)]
        xs = [np.resize(x, xs[0].shape) for x in xs]   # equal shapes
        out = pc.simulate_allreduce(xs)
        want = np.sum(np.stack(xs), axis=0)
        for o in out:
            np.testing.assert_allclose(o, want, rtol=1e-5, atol=1e-5)


# ------------------------------------------------------- bucket layout
def test_bucket_layout_roundtrip():
    tree = {"a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "b": {"c": jnp.full((5,), 2.0, jnp.float32)}}
    lay = make_layout(tree)
    buf = lay.flatten(tree, 1.0)
    assert buf.shape == (lay.n_buckets, lay.bucket_elems)
    assert lay.bucket_elems % 128 == 0
    out, count = lay.unflatten(buf)
    assert float(count) == 1.0
    np.testing.assert_array_equal(np.asarray(out["a"]),
                                  np.asarray(tree["a"]))
    np.testing.assert_array_equal(np.asarray(out["b"]["c"]),
                                  np.asarray(tree["b"]["c"]))
    # padding is zeros: total mass is payload + flag
    assert np.isclose(float(buf.sum()),
                      float(tree["a"].sum() + tree["b"]["c"].sum() + 1.0))


def test_bucket_layout_multi_bucket_sizing():
    spec = {"x": jax.ShapeDtypeStruct((1000,), jnp.float32)}
    lay = make_layout(spec, bucket_elems=256)
    assert lay.n_buckets == math.ceil(1001 / 256)
    buf = lay.flatten({"x": jnp.ones((1000,), jnp.float32)}, 0.0)
    out, count = lay.unflatten(buf)
    assert float(count) == 0.0
    assert out["x"].shape == (1000,)


def test_bucket_layout_reverse_topo_readiness_groups():
    """Output-side leaves come first (their grads finalize first under
    backprop), embeddings last; contiguous readiness classes become
    bucket groups and the group views round-trip exactly."""
    from repro.models.registry import get_api, get_config
    api = get_api(get_config("smollm-135m").reduced())
    lay = make_layout(api.param_spec(), bucket_elems=1024)
    paths = ["/".join(str(getattr(p, "key", p)) for p in path)
             for path, _ in jax.tree_util.tree_flatten_with_path(
                 api.param_spec())[0]]
    order = [paths[i] for i in lay.perm]
    assert "final_norm" in order[0], order[0]          # loss side first
    assert "embed" in order[-1], order[-1]             # input side last
    assert lay.n_groups >= 3
    assert sum(lay.group_buckets) == lay.n_buckets
    assert lay.groups[0][0] == 0 and lay.groups[-1][1] == lay.n_buckets
    # per-group buffers == contiguous slices of the flat buffer, and
    # the round-trip (incl. contributor flag) is exact
    params = api.init_params(jax.random.key(0))
    bufs = lay.flatten_groups(params, 1.0)
    assert [b.shape[0] for b in bufs] == list(lay.group_buckets)
    flat = lay.flatten(params, 1.0)
    np.testing.assert_array_equal(
        np.asarray(flat), np.asarray(jnp.concatenate(bufs, 0)))
    tree, count = lay.unflatten_groups(bufs)
    assert float(count) == 1.0
    for a, b in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(tree)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_bucket_layout_block_groups_scan_slice_subgroups():
    """``block_groups=K`` splits the monolithic blocks group into K
    scan-row sub-groups, LAST rows first (the order the backward scan
    emits stacked gradients), deepening the overlap past 3 groups; the
    group views and the full buffer still round-trip exactly."""
    from repro.models.registry import get_api, get_config
    api = get_api(get_config("smollm-135m").reduced(n_layers=4))
    base = make_layout(api.param_spec(), bucket_elems=1024)
    lay = make_layout(api.param_spec(), bucket_elems=1024,
                      block_groups=4)
    assert base.n_groups == 3
    assert lay.n_groups == base.n_groups + 3      # blocks: 1 -> 4 groups
    # the block sub-groups cover descending row ranges of the scan axis
    rows = [r for r in lay.group_rows if r is not None]
    assert rows == [(3, 4), (2, 3), (1, 2), (0, 1)], rows
    # row-split groups repeat the same stacked-leaf range
    blk_groups = [lay.group_leaves[g] for g in range(lay.n_groups)
                  if lay.group_rows[g] is not None]
    assert len(set(blk_groups)) == 1
    params = api.init_params(jax.random.key(0))
    bufs = lay.flatten_groups(params, 1.0)
    assert [b.shape[0] for b in bufs] == list(lay.group_buckets)
    flat = lay.flatten(params, 1.0)
    np.testing.assert_array_equal(
        np.asarray(flat), np.asarray(jnp.concatenate(bufs, 0)))
    for tree, count in (lay.unflatten(flat),
                        lay.unflatten_groups(bufs)):
        assert float(count) == 1.0
        for a, b in zip(jax.tree_util.tree_leaves(params),
                        jax.tree_util.tree_leaves(tree)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # K > scan length clamps; K=1 is byte-identical to the base layout
    assert make_layout(api.param_spec(), bucket_elems=1024,
                       block_groups=64).n_groups == 3 + 3
    assert make_layout(api.param_spec(), bucket_elems=1024,
                       block_groups=1) == base


def test_bucket_layout_block_groups_hybrid_shared_leaves_unsplit():
    """Hybrid families carry loose class-1 leaves (shared attention)
    whose grads accumulate across the whole backward: they keep an
    UNSPLIT group after the scan-row sub-groups."""
    from repro.models.registry import get_api, get_config
    api = get_api(get_config("zamba2-7b").reduced())
    lay = make_layout(api.param_spec(), block_groups=2)
    rows = [r for r in lay.group_rows]
    assert (None, (1, 2), (0, 1)) == tuple(rows[:3]), rows
    params = api.init_params(jax.random.key(1))
    tree, count = lay.unflatten(lay.flatten(params, 1.0))
    assert float(count) == 1.0
    for a, b in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(tree)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_bucket_layout_tree_order_single_group():
    """order="tree" preserves the pre-overlap layout: identity perm,
    one readiness group spanning every bucket."""
    from repro.models.registry import get_api, get_config
    api = get_api(get_config("smollm-135m").reduced())
    lay = make_layout(api.param_spec(), bucket_elems=1024, order="tree")
    assert lay.perm == tuple(range(len(lay.sizes)))
    assert lay.n_groups == 1
    assert lay.flag_index == lay.payload       # flag right after leaves


# ------------------------------------------------------- program cache
def test_program_cache_hits_on_revisited_member_set():
    built = []

    def builder(pc):
        built.append((pc.keys, pc.kind))
        return ("program", pc.keys, pc.kind)

    cache = ProgramCache(builder)
    rt = ElasticPhaserRuntime(3, seed=0)
    rt.bind_program_cache(cache)            # epoch 0 compiles eagerly
    assert cache.stats() == {"entries": 1, "hits": 0, "misses": 1}
    w = rt.request_join()
    rt.advance()                            # (0,1,2,3): new program
    rt.request_leave(w)
    rt.advance()                            # back to (0,1,2): cache HIT
    assert cache.stats() == {"entries": 2, "hits": 1, "misses": 2}
    assert built == [((0, 1, 2), "phaser_scsl"),
                     ((0, 1, 2, 3), "phaser_scsl")]
    # the cached program is the current epoch's
    assert cache.get(rt.collective()) == ("program", (0, 1, 2),
                                          "phaser_scsl")


def test_program_cache_lru_eviction():
    cache = ProgramCache(lambda pc: object(), capacity=2)
    pcs = [PhaserCollective(2, "data", keys=(i, i + 1), kind="xla_psum")
           for i in range(3)]
    for pc in pcs:
        cache.get(pc)
    assert len(cache) == 2
    assert pcs[0] not in cache and pcs[2] in cache


def test_program_cache_lru_hit_refreshes_recency():
    """A cache HIT must move the entry to most-recently-used: after
    touching pc0 again, inserting a third entry evicts pc1, not pc0."""
    cache = ProgramCache(lambda pc: object(), capacity=2)
    pcs = [PhaserCollective(2, "data", keys=(i, i + 1), kind="xla_psum")
           for i in range(3)]
    cache.get(pcs[0])
    cache.get(pcs[1])
    cache.get(pcs[0])                      # HIT: pc0 becomes MRU
    cache.get(pcs[2])                      # evicts the LRU = pc1
    assert pcs[0] in cache and pcs[2] in cache
    assert pcs[1] not in cache
    assert cache.stats() == {"entries": 2, "hits": 1, "misses": 3}


def test_program_cache_extra_key_separates_overlap_configs():
    """An eager and a pipelined cache over the same member set hold
    DISTINCT entries: the overlap/microbatch config rides the key."""
    built = []
    pc = PhaserCollective(3, "data", keys=(0, 1, 2), kind="xla_psum")
    eager = ProgramCache(lambda c: built.append("eager") or "E",
                         extra_key=("eager", 1))
    pipe = ProgramCache(lambda c: built.append("pipelined") or "P",
                        extra_key=("pipelined", 2))
    assert eager.get(pc) == "E" and pipe.get(pc) == "P"
    assert built == ["eager", "pipelined"]
    assert eager.full_key(pc) != pipe.full_key(pc)
    assert eager.full_key(pc)[:4] == pipe.full_key(pc)[:4]
    # one shared cache would also keep them apart if keyed fully
    assert eager.get(pc) == "E"            # hit, not rebuilt
    assert built == ["eager", "pipelined"]


def test_epoch_boundary_swap_ordering():
    """The boundary's program swap is ordered: the next epoch's program
    is compiled inside ``advance()`` (via the bound cache's on_epoch
    hook) BEFORE the boundary returns, and hooks observe (old, new) in
    order — a consumer never runs a phase against a missing program."""
    events = []

    def builder(pc):
        events.append(("compile", pc.keys))
        return ("program", pc.keys)

    cache = ProgramCache(builder)
    rt = ElasticPhaserRuntime(3, seed=0)
    rt.bind_program_cache(cache)           # epoch 0 compiles eagerly
    rt.on_epoch(lambda old, new: events.append(
        ("boundary", old.live, new.live)))
    assert events == [("compile", (0, 1, 2))]
    w = rt.request_join()
    # churn is pending but the swap must NOT happen mid-phase
    assert rt.pending_churn and len(events) == 1
    rt.advance()
    # compile lands inside the boundary, before the follow-up hooks
    assert events[1] == ("compile", (0, 1, 2, w))
    assert events[2] == ("boundary", (0, 1, 2), (0, 1, 2, w))
    assert rt.collective() in cache        # ready before the next phase


# --------------------------- device numerics (subprocess: 8-dev mesh)
@pytest.mark.slow
def test_engine_matches_psum_on_mesh_all_kinds_non_pow2():
    """The bucketed shard_map executor (fused Pallas combine) equals
    xla_psum for every kind at n in {3, 5, 6, 8}, and the compiled
    gradient-sync program computes the same masked step as the psum
    program."""
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.collective_exec import build_allreduce_program, build_gradsync_program
from repro.core.collective import ALLREDUCE_KINDS, PhaserCollective

rng = np.random.default_rng(0)
for n in (3, 5, 6, 8):
    x = jnp.asarray(rng.normal(size=(n, 4, 33)).astype(np.float32))
    want = np.asarray(x).sum(0)
    for kind in ALLREDUCE_KINDS:
        pc = PhaserCollective(n, "data", kind=kind, seed=1)
        f = build_allreduce_program(pc, jax.ShapeDtypeStruct((4, 33), jnp.float32))
        got = np.asarray(f(x))
        for i in range(n):
            np.testing.assert_allclose(got[i], want, rtol=1e-4, atol=1e-4,
                                       err_msg=f"{kind} n={n} rank {i}")

from repro.models.registry import get_api, get_config
from repro.optim import AdamW
from repro.data.synthetic import make_batch
cfg = get_config("smollm-135m").reduced()
api = get_api(cfg)
opt = AdamW(lr=1e-3, warmup=2, total_steps=10)
params = api.init_params(jax.random.key(0))
opt_state = opt.init(params)
n = 6
bs = [make_batch(cfg.vocab_size, 2, 16, seed=100 + w, step=0) for w in range(n)]
batch = {k: jnp.asarray(np.stack([b[k] for b in bs])) for k in bs[0]}
alive = jnp.asarray([1, 1, 1, 1, 1, 0], jnp.float32)
prog = build_gradsync_program(
    api, opt, PhaserCollective(n, "data", kind="recursive_doubling"),
    stacked=True)
ref = build_gradsync_program(
    api, opt, PhaserCollective(n, "data", kind="xla_psum"), stacked=True)
p1, o1, m1 = prog.step(params, opt_state, batch, alive)
p2, o2, m2 = ref.step(params, opt_state, batch, alive)
r1, r2 = prog.reduce_metrics(m1), ref.reduce_metrics(m2)
np.testing.assert_allclose(float(r1["loss"]), float(r2["loss"]), rtol=1e-5)
assert float(r1["alive"]) == 5.0
for a, b in zip(jax.tree_util.tree_leaves(p1),
                jax.tree_util.tree_leaves(p2)):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-4, atol=2e-5)
print("OK")
"""
    import os
    out = subprocess.run([sys.executable, "-c", code],
                         capture_output=True, text=True,
                         env={**os.environ, "PYTHONPATH": "src"},
                         cwd=os.path.dirname(os.path.dirname(__file__)),
                         timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout


@pytest.mark.slow
def test_overlapped_program_bitwise_equals_eager_across_elastic_epochs():
    """The overlap acceptance gate (DESIGN.md §5): the pipelined
    program (reverse-topo bucket groups, double-buffered rounds,
    microbatch streams) produces BITWISE-equal loss+params vs the eager
    program at every step across grow 4->6 / shrink 6->3 elastic
    epochs, and both match the xla_psum baseline within f32 tolerance."""
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.collective_exec import ProgramCache, build_gradsync_program
from repro.core.collective import PhaserCollective
from repro.data.synthetic import make_batch
from repro.models.registry import get_api, get_config
from repro.optim import AdamW
from repro.runtime_elastic import ElasticPhaserRuntime

cfg = get_config("smollm-135m").reduced()
api = get_api(cfg)
opt = AdamW(lr=3e-3, warmup=2, total_steps=12)
M = 2                                     # microbatches per worker
mk = lambda overlap, kind: ProgramCache(
    lambda pc: build_gradsync_program(
        api, opt, PhaserCollective(pc.n, pc.axis_name, kind=kind,
                                   keys=pc.keys, seed=pc.seed),
        stacked=True, overlap=overlap, microbatches=M,
        bucket_elems=1024, block_groups=2),
    extra_key=(overlap, M, 2))
pipe = mk("pipelined", "recursive_doubling")
eager = mk("eager", "recursive_doubling")
psum = mk("eager", "xla_psum")

rt = ElasticPhaserRuntime(4, seed=0, kind="recursive_doubling")
rt.bind_program_cache(pipe)
p0 = api.init_params(jax.random.key(0))
state = {n: (p0, opt.init(p0)) for n in ("pipe", "eager", "psum")}

for step in range(12):
    if step == 4:
        rt.request_join(); rt.request_join()          # grow 4 -> 6
    if step == 8:
        for w in sorted(rt.live)[-3:]:
            rt.request_leave(w)                       # shrink 6 -> 3
    team = list(rt.epoch.live)
    alive = jnp.asarray([1.0 if w in rt.live else 0.0 for w in team],
                        jnp.float32)
    bs = [make_batch(cfg.vocab_size, 4, 16, seed=50 + w, step=step)
          for w in team]
    batch = {k: jnp.asarray(np.stack([b[k] for b in bs]))
             for k in bs[0]}
    pc = rt.collective()
    losses = {}
    for name, cache in (("pipe", pipe), ("eager", eager),
                        ("psum", psum)):
        prog = cache.get(pc)
        p, o = state[name]
        p, o, m = prog.step(p, o, batch, alive)
        state[name] = (p, o)
        losses[name] = float(prog.reduce_metrics(m)["loss"])
    # pipelined vs eager: bitwise (atol=0)
    assert losses["pipe"] == losses["eager"], (step, losses)
    for a, b in zip(jax.tree_util.tree_leaves(state["pipe"][0]),
                    jax.tree_util.tree_leaves(state["eager"][0])):
        assert (np.asarray(a) == np.asarray(b)).all(), step
    # both vs psum: f32 tolerance
    np.testing.assert_allclose(losses["pipe"], losses["psum"],
                               rtol=1e-5, atol=1e-6)
    for a, b in zip(jax.tree_util.tree_leaves(state["pipe"][0]),
                    jax.tree_util.tree_leaves(state["psum"][0])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-5)
    rt.advance(step=step)
assert len(rt.epochs) == 3, len(rt.epochs)
for cache in (pipe, eager, psum):
    assert cache.stats()["misses"] == 3    # one program per member set
g = pipe.get(rt.collective())
# block_groups=2 splits the stacked-blocks group into 2 scan-row
# sub-groups: the pipelined overlap runs deeper than the 3 classes
assert g.meta["overlap"] == 1 and g.meta["bucket_groups"] >= 4
print("OK")
"""
    import os
    out = subprocess.run([sys.executable, "-c", code],
                         capture_output=True, text=True,
                         env={**os.environ, "PYTHONPATH": "src"},
                         cwd=os.path.dirname(os.path.dirname(__file__)),
                         timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout
