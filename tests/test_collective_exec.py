"""Collective execution engine (DESIGN.md §4).

Four layers of evidence:

1. schedule properties — every kind derives a valid ``Schedule`` for
   EVERY team size 2..12 (the elimination derivations cover non-powers
   of two) and its host simulation equals the direct sum;
2. a hypothesis property sweep over (n, kind, keys, values) — skipped
   where the dev-only dependency is missing;
3. bucket layout round-trips the grad pytree exactly, with the alive
   flag riding the buffer;
4. numeric (subprocess, 8 host devices): the bucketed shard_map
   executor with the fused Pallas combine equals ``xla_psum`` for every
   kind at pow2 AND non-pow2 team sizes, and the compiled gradient-sync
   program produces the same updated params as the psum program.
"""
import math
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.collective_exec import ProgramCache, make_layout
from repro.core.collective import (ALLREDUCE_KINDS, PhaserCollective,
                                   recursive_doubling_schedule)
from repro.runtime_elastic import ElasticPhaserRuntime


# ----------------------------- non-pow2 schedules (deterministic sweep)
def test_all_kinds_all_team_sizes_simulate_equals_sum():
    rng = np.random.default_rng(0)
    for n in range(2, 13):
        keys = tuple(sorted(rng.choice(200, size=n,
                                       replace=False).tolist()))
        for kind in ALLREDUCE_KINDS:
            pc = PhaserCollective(n, "data", kind=kind, keys=keys,
                                  seed=n % 4)
            sched = pc.unified_schedule()
            if sched is not None:
                sched.check()
            xs = [rng.normal(size=23).astype(np.float32)
                  for _ in range(n)]
            out = pc.simulate_allreduce(xs)
            want = np.sum(np.stack(xs), axis=0)
            for i, o in enumerate(out):
                np.testing.assert_allclose(
                    o, want, rtol=1e-5, atol=1e-5,
                    err_msg=f"{kind} n={n} rank={i}")


def test_recursive_doubling_non_pow2_uses_elimination_rounds():
    s = recursive_doubling_schedule(6)
    s.check()
    # fold extras (add), 2 XOR rounds over the 4-core, hydrate (copy)
    assert s.depth == 4
    assert s.ops[0] == "add" and s.ops[-1] == "copy"
    assert recursive_doubling_schedule(8).ops == ("add",) * 3


def test_elastic_epochs_keep_preferred_kind_non_pow2():
    for kind in ("recursive_doubling", "halving_doubling"):
        rt = ElasticPhaserRuntime(4, seed=0, kind=kind)
        rt.request_join()
        rt.advance()
        assert rt.epoch.n == 5 and rt.epoch.kind == kind
        rt.verify_epoch()


# --------------------------------------------- hypothesis property
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYP = True
except ImportError:
    HAVE_HYP = False


if HAVE_HYP:
    @given(st.integers(2, 12), st.sampled_from(ALLREDUCE_KINDS),
           st.integers(0, 10_000))
    @settings(max_examples=60, deadline=None)
    def test_any_team_size_any_kind_schedule_is_sound(n, kind, seed):
        rng = np.random.default_rng(seed)
        keys = tuple(sorted(rng.choice(500, size=n,
                                       replace=False).tolist()))
        pc = PhaserCollective(n, "data", kind=kind, keys=keys,
                              seed=seed % 7)
        sched = pc.unified_schedule()
        if sched is not None:
            sched.check()
        xs = [rng.normal(size=int(rng.integers(1, 40)))
              .astype(np.float32) for _ in range(n)]
        xs = [np.resize(x, xs[0].shape) for x in xs]   # equal shapes
        out = pc.simulate_allreduce(xs)
        want = np.sum(np.stack(xs), axis=0)
        for o in out:
            np.testing.assert_allclose(o, want, rtol=1e-5, atol=1e-5)


# ------------------------------------------------------- bucket layout
def test_bucket_layout_roundtrip():
    tree = {"a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "b": {"c": jnp.full((5,), 2.0, jnp.float32)}}
    lay = make_layout(tree)
    buf = lay.flatten(tree, 1.0)
    assert buf.shape == (lay.n_buckets, lay.bucket_elems)
    assert lay.bucket_elems % 128 == 0
    out, count = lay.unflatten(buf)
    assert float(count) == 1.0
    np.testing.assert_array_equal(np.asarray(out["a"]),
                                  np.asarray(tree["a"]))
    np.testing.assert_array_equal(np.asarray(out["b"]["c"]),
                                  np.asarray(tree["b"]["c"]))
    # padding is zeros: total mass is payload + flag
    assert np.isclose(float(buf.sum()),
                      float(tree["a"].sum() + tree["b"]["c"].sum() + 1.0))


def test_bucket_layout_multi_bucket_sizing():
    spec = {"x": jax.ShapeDtypeStruct((1000,), jnp.float32)}
    lay = make_layout(spec, bucket_elems=256)
    assert lay.n_buckets == math.ceil(1001 / 256)
    buf = lay.flatten({"x": jnp.ones((1000,), jnp.float32)}, 0.0)
    out, count = lay.unflatten(buf)
    assert float(count) == 0.0
    assert out["x"].shape == (1000,)


# ------------------------------------------------------- program cache
def test_program_cache_hits_on_revisited_member_set():
    built = []

    def builder(pc):
        built.append((pc.keys, pc.kind))
        return ("program", pc.keys, pc.kind)

    cache = ProgramCache(builder)
    rt = ElasticPhaserRuntime(3, seed=0)
    rt.bind_program_cache(cache)            # epoch 0 compiles eagerly
    assert cache.stats() == {"entries": 1, "hits": 0, "misses": 1}
    w = rt.request_join()
    rt.advance()                            # (0,1,2,3): new program
    rt.request_leave(w)
    rt.advance()                            # back to (0,1,2): cache HIT
    assert cache.stats() == {"entries": 2, "hits": 1, "misses": 2}
    assert built == [((0, 1, 2), "phaser_scsl"),
                     ((0, 1, 2, 3), "phaser_scsl")]
    # the cached program is the current epoch's
    assert cache.get(rt.collective()) == ("program", (0, 1, 2),
                                          "phaser_scsl")


def test_program_cache_lru_eviction():
    cache = ProgramCache(lambda pc: object(), capacity=2)
    pcs = [PhaserCollective(2, "data", keys=(i, i + 1), kind="xla_psum")
           for i in range(3)]
    for pc in pcs:
        cache.get(pc)
    assert len(cache) == 2
    assert pcs[0] not in cache and pcs[2] in cache


# --------------------------- device numerics (subprocess: 8-dev mesh)
@pytest.mark.slow
def test_engine_matches_psum_on_mesh_all_kinds_non_pow2():
    """The bucketed shard_map executor (fused Pallas combine) equals
    xla_psum for every kind at n in {3, 5, 6, 8}, and the compiled
    gradient-sync program computes the same masked step as the psum
    program."""
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.collective_exec import build_allreduce_program, build_gradsync_program
from repro.core.collective import ALLREDUCE_KINDS, PhaserCollective

rng = np.random.default_rng(0)
for n in (3, 5, 6, 8):
    x = jnp.asarray(rng.normal(size=(n, 4, 33)).astype(np.float32))
    want = np.asarray(x).sum(0)
    for kind in ALLREDUCE_KINDS:
        pc = PhaserCollective(n, "data", kind=kind, seed=1)
        f = build_allreduce_program(pc, jax.ShapeDtypeStruct((4, 33), jnp.float32))
        got = np.asarray(f(x))
        for i in range(n):
            np.testing.assert_allclose(got[i], want, rtol=1e-4, atol=1e-4,
                                       err_msg=f"{kind} n={n} rank {i}")

from repro.models.registry import get_api, get_config
from repro.optim import AdamW
from repro.data.synthetic import make_batch
cfg = get_config("smollm-135m").reduced()
api = get_api(cfg)
opt = AdamW(lr=1e-3, warmup=2, total_steps=10)
params = api.init_params(jax.random.key(0))
opt_state = opt.init(params)
n = 6
bs = [make_batch(cfg.vocab_size, 2, 16, seed=100 + w, step=0) for w in range(n)]
batch = {k: jnp.asarray(np.stack([b[k] for b in bs])) for k in bs[0]}
alive = jnp.asarray([1, 1, 1, 1, 1, 0], jnp.float32)
prog = build_gradsync_program(
    api, opt, PhaserCollective(n, "data", kind="recursive_doubling"),
    stacked=True)
ref = build_gradsync_program(
    api, opt, PhaserCollective(n, "data", kind="xla_psum"), stacked=True)
p1, o1, m1 = prog.step(params, opt_state, batch, alive)
p2, o2, m2 = ref.step(params, opt_state, batch, alive)
r1, r2 = prog.reduce_metrics(m1), ref.reduce_metrics(m2)
np.testing.assert_allclose(float(r1["loss"]), float(r2["loss"]), rtol=1e-5)
assert float(r1["alive"]) == 5.0
for a, b in zip(jax.tree_util.tree_leaves(p1),
                jax.tree_util.tree_leaves(p2)):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-4, atol=2e-5)
print("OK")
"""
    import os
    out = subprocess.run([sys.executable, "-c", code],
                         capture_output=True, text=True,
                         env={**os.environ, "PYTHONPATH": "src"},
                         cwd=os.path.dirname(os.path.dirname(__file__)),
                         timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout
