"""Behavioural tests for the distributed-phaser protocol (control plane)."""
import random

import pytest

from repro.core.phaser import (DistPhaser, SIG_MODE, SIG_WAIT, WAIT_MODE,
                               SCSL, SNSL)
from repro.core.runtime import FifoScheduler, RandomScheduler
from repro.core.skiplist import HEAD

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYP = True
except ImportError:  # pragma: no cover
    HAVE_HYP = False


def test_basic_phases():
    ph = DistPhaser(8, seed=1)
    for k in range(5):
        assert ph.next() == k
    ph.check_quiescent_invariants()
    # every waiter caught up
    for r in range(8):
        assert ph.released(r) == 4


def test_modes_sig_only_wait_only():
    modes = {0: SIG_MODE, 1: WAIT_MODE, 2: SIG_WAIT, 3: SIG_WAIT}
    ph = DistPhaser(4, modes=modes, seed=3)
    rel = ph.next()                      # signalers: 0, 2, 3
    assert rel == 0
    assert ph.released(1) == 0           # wait-only task is notified


def test_no_premature_release():
    ph = DistPhaser(4, seed=2)
    for r in (0, 1, 2):
        ph.signal(r)
    ph.run()
    assert ph.released() == -1           # 3 hasn't signaled
    ph.signal(3)
    ph.run()
    assert ph.released() == 0


def test_split_phase_pipelining():
    """Fuzzy barrier: a task may run several signals ahead."""
    ph = DistPhaser(3, seed=5)
    for _ in range(4):
        ph.signal(0)                     # task 0 races ahead 4 phases
    ph.run()
    assert ph.released() == -1
    for _ in range(4):
        ph.signal(1)
        ph.signal(2)
    ph.run()
    assert ph.released() == 3


def test_dynamic_add_participates_next_phase():
    ph = DistPhaser(3, seed=7)
    ph.next()
    ph.async_add(0, 99)
    ph.run()
    a = ph.actors[99]
    assert a.sc.joined and a.sn.joined
    assert a.sc.first_phase == 1
    # now phase 1 needs all four signals
    for r in (0, 1, 2):
        ph.signal(r)
    ph.run()
    assert ph.released() == 0
    ph.signal(99)
    ph.run()
    assert ph.released() == 1
    ph.check_quiescent_invariants()


def test_add_signals_before_join_complete():
    """Pre-join signals are buffered and applied to the task's first phase."""
    ph = DistPhaser(2, seed=11)
    ph.async_add(0, 50)
    ph.signal(50)                        # insert still in flight
    ph.signal(0)
    ph.signal(1)
    ph.run()
    assert ph.released() == 0
    ph.check_quiescent_invariants()


def test_drop_reduces_expectation():
    ph = DistPhaser(4, seed=13)
    ph.drop(2)
    for r in (0, 1, 3):
        ph.signal(r)
    ph.run()
    assert ph.released() == 0
    ph.check_quiescent_invariants()
    assert ph.actors[2].sc.departed


def test_drop_tall_node_preserves_lanes():
    # drop the tallest participant: lanes must re-link around it
    ph = DistPhaser(16, seed=17)
    tallest = max(range(16), key=lambda r: ph.actors[r].sc.height)
    ph.drop(tallest)
    ph.run()
    ph.check_quiescent_invariants()
    rest = [r for r in range(16) if r != tallest]
    for r in rest:
        ph.signal(r)
    ph.run()
    assert ph.released() == 0


def test_many_phases_after_churn():
    ph = DistPhaser(6, seed=19)
    ph.next()
    ph.async_add(1, 100)
    ph.async_add(2, 101)
    ph.run()
    ph.drop(0)
    ph.run()
    members = [r for r in (1, 2, 3, 4, 5, 100, 101)]
    for k in range(1, 6):
        for r in members:
            ph.signal(r)
        ph.run()
        assert ph.released() == k
    ph.check_quiescent_invariants()


def test_insertion_matches_oracle_topology():
    """After add + promotion quiescence, the distributed links equal the
    sequential oracle built over the same key set."""
    ph = DistPhaser(8, seed=23)
    ph.async_add(3, 64)
    ph.run()
    oracle = ph.oracle(list(range(8)) + [64])
    for k in list(range(8)) + [64]:
        st = ph.actors[k].st(SCSL)
        node = oracle.nodes[k]
        assert st.height == node.height, k
        assert st.nxt == node.nxt, k
        assert st.prv == node.prv, k


@pytest.mark.parametrize("seed", range(20))
def test_random_churn_stress(seed):
    rng = random.Random(seed)
    n = rng.randint(2, 8)
    ph = DistPhaser(n, seed=seed)
    next_id, alive = 100, set(range(n))
    for rnd in range(5):
        op = rng.random()
        if op < 0.4 and len(alive) > 1:
            parent = rng.choice(sorted(alive))
            ph.async_add(parent, next_id)
            alive.add(next_id)
            next_id += 1
        elif op < 0.6 and len(alive) > 2:
            victim = rng.choice(sorted(alive))
            ph.drop(victim)
            alive.discard(victim)
        for r in sorted(alive):
            a = ph.actors[r]
            if a.sc.member and not a.sc.dropping and not a.pending_drop:
                ph.signal(r)
        ph.run(RandomScheduler(seed * 31 + rnd))
    ph.check_quiescent_invariants()


def test_signal_critical_path_logarithmic():
    depths = {}
    for n in (8, 32, 128, 512):
        ph = DistPhaser(n, seed=1)
        ph.net.reset_stats()
        for r in range(n):
            ph.signal(r)
        ph.run()
        assert ph.released() == 0
        depths[n] = ph.net.max_depth
    assert depths[512] <= depths[8] + 40   # additive growth, not multiplicative
    assert depths[512] <= 60


if HAVE_HYP:
    @settings(max_examples=25, deadline=None)
    @given(st.integers(2, 10), st.integers(0, 1000), st.integers(1, 4))
    def test_property_phases_advance(n, seed, phases):
        ph = DistPhaser(n, seed=seed)
        for k in range(phases):
            assert ph.next(scheduler=RandomScheduler(seed + k)) == k
        ph.check_quiescent_invariants()
