"""ElasticPhaserRuntime: epoch/schedule-swap lifecycle (DESIGN.md §3).

Three layers of evidence that the elastic control plane is sound:

1. deterministic scripted churn — every epoch's compiled schedule matches
   the deterministic skip-list oracle AND the converged protocol actors;
2. a hypothesis property sweep over arbitrary join/leave/step sequences
   (skipped where the dev-only dependency is missing);
3. numeric: the per-epoch ``phaser_scsl`` all-reduce equals ``xla_psum``
   on a real 8-device host mesh as the team grows and shrinks
   (subprocess: device count is init-locked).
"""
import subprocess
import sys

import numpy as np
import pytest

from repro.core.collective import ALLREDUCE_KINDS, PhaserCollective
from repro.runtime_elastic import ElasticPhaserRuntime


# ------------------------------------------------------ epoch semantics
def test_epoch_boundary_semantics():
    rt = ElasticPhaserRuntime(4, seed=0)
    assert rt.epoch.index == 0 and rt.epoch.live == (0, 1, 2, 3)
    w = rt.request_join()
    # eager on the control plane, lazy on the data plane:
    assert w in rt.live and rt.epoch.live == (0, 1, 2, 3)
    assert rt.advance() == 0
    assert rt.epoch.index == 1 and rt.epoch.live == (0, 1, 2, 3, 4)
    rt.verify_epoch()
    rt.request_leave(w, fail=True)
    rt.request_leave(1)
    assert rt.epoch.live == (0, 1, 2, 3, 4)      # still the old epoch
    assert rt.advance() == 1
    assert rt.epoch.index == 2 and rt.epoch.live == (0, 2, 3)
    rt.verify_epoch()
    # no churn -> no new epoch
    assert rt.advance() == 2
    assert rt.epoch.index == 2
    kinds = [e.kind for e in rt.events]
    assert kinds == ["join", "fail", "leave"]


def test_epoch_phase_starts_are_monotone_and_gapless():
    rt = ElasticPhaserRuntime(3, seed=1)
    rt.advance()
    rt.request_join()
    rt.advance()
    rt.advance()
    rt.request_leave(0)
    rt.advance()
    starts = [e.phase_start for e in rt.epochs]
    assert starts == sorted(starts)
    assert all(b > a for a, b in zip(starts, starts[1:]))


def test_on_epoch_hook_fires_with_old_and_new():
    rt = ElasticPhaserRuntime(4, seed=0)
    seen = []
    rt.on_epoch(lambda old, new: seen.append((old.index, new.index,
                                              old.live, new.live)))
    rt.request_join()
    rt.advance()
    rt.advance()                      # no churn: hook must not fire
    assert seen == [(0, 1, (0, 1, 2, 3), (0, 1, 2, 3, 4))]


def test_kind_kept_for_non_pow2_teams():
    """Since the elimination derivations every kind covers every team
    size: a non-pow2 epoch keeps the preferred schedule (the historical
    fallback to phaser_scsl is gone)."""
    rt = ElasticPhaserRuntime(4, seed=0, kind="recursive_doubling")
    assert rt.epoch.kind == "recursive_doubling"
    rt.request_join()
    rt.advance()
    assert rt.epoch.n == 5 and rt.epoch.kind == "recursive_doubling"
    assert rt.epoch.collective.rd.ops[-1] == "copy"   # elimination form
    for _ in range(3):
        rt.request_join()
    rt.advance()
    assert rt.epoch.n == 8 and rt.epoch.kind == "recursive_doubling"
    assert rt.epoch.collective.rd.ops == ("add",) * 3  # pure hypercube
    rt.verify_epoch()


def test_scripted_churn_epochs_match_oracle():
    """Deterministic mini-sweep (runs everywhere; the hypothesis version
    below explores the same space adversarially)."""
    for seed in range(8):
        rng = np.random.default_rng(seed)
        rt = ElasticPhaserRuntime(int(rng.integers(2, 6)), seed=seed % 3)
        for _ in range(12):
            op = rng.integers(0, 3)
            if op == 0:
                parent = (int(rng.choice(sorted(rt.live)))
                          if rt.live and rng.integers(0, 2) else None)
                rt.request_join(parent)
            elif op == 1 and len(rt.live) > 1:
                rt.request_leave(int(rng.choice(sorted(rt.live))),
                                 fail=bool(rng.integers(0, 2)))
            else:
                rt.advance()
        rt.advance()
        rt.verify_epoch()
        for ep in rt.epochs:
            if ep.collective is not None:
                assert ep.collective.matches_oracle(), (seed, ep.index)


# ------------------------------------------------- hypothesis property
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYP = True
except ImportError:
    HAVE_HYP = False


if HAVE_HYP:
    @given(st.integers(2, 6), st.integers(0, 10_000),
           st.lists(st.sampled_from(["join", "leave", "step"]),
                    max_size=16))
    @settings(max_examples=40, deadline=None)
    def test_any_churn_sequence_epochs_match_oracle(n, seed, ops):
        rng = np.random.default_rng(seed)
        rt = ElasticPhaserRuntime(n, seed=seed % 5)
        for op in ops:
            if op == "join":
                rt.request_join()
            elif op == "leave" and len(rt.live) > 1:
                rt.request_leave(int(rng.choice(sorted(rt.live))))
            else:
                rt.advance()
        rt.advance()
        rt.verify_epoch()
        for ep in rt.epochs:
            if ep.collective is not None:
                assert ep.collective.matches_oracle(), ep.index
        starts = [e.phase_start for e in rt.epochs]
        assert starts == sorted(starts)


# --------------------------------------------------- schedule numerics
def test_simulate_allreduce_matches_direct_sum():
    rng = np.random.default_rng(0)
    for kind in ALLREDUCE_KINDS:
        for keys in [(0, 1, 2, 3), (1, 3, 5, 9), (0, 2, 3, 5, 7, 11),
                     (4, 7, 9)]:
            n = len(keys)
            pc = PhaserCollective(n, "data", kind=kind, keys=keys, seed=3)
            xs = [rng.normal(size=17).astype(np.float32) for _ in range(n)]
            out = pc.simulate_allreduce(xs)
            want = np.sum(np.stack(xs, 0), axis=0)
            for o in out:
                np.testing.assert_allclose(o, want, rtol=1e-5, atol=1e-5)


def test_collective_keys_change_schedule():
    a = PhaserCollective(4, "data", kind="phaser_scsl", seed=0)
    b = PhaserCollective(4, "data", kind="phaser_scsl", seed=0,
                         keys=(0, 1, 2, 5))
    assert a.schedule_fingerprint() != b.schedule_fingerprint()
    assert a.matches_oracle() and b.matches_oracle()


@pytest.mark.slow
def test_phaser_allreduce_matches_psum_under_churn_subprocess():
    """Grow 4 -> 6, shrink 6 -> 3: each epoch's compiled schedule computes
    the same all-reduce as XLA's psum on a real host mesh."""
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map
from repro.runtime_elastic import ElasticPhaserRuntime

rt = ElasticPhaserRuntime(4, seed=0, kind="phaser_scsl")
rt.request_join(); rt.request_join()
rt.advance()
ep_grow = rt.epoch
for w in sorted(rt.live)[-3:]:
    rt.request_leave(w)
rt.advance()
ep_shrink = rt.epoch
assert ep_grow.n == 6 and ep_shrink.n == 3, (ep_grow.n, ep_shrink.n)
for ep in (rt.epochs[0], ep_grow, ep_shrink):
    rtN = ep.n
    pc = ep.collective
    mesh = Mesh(np.array(jax.devices()[:rtN]), ("data",))
    x = jnp.arange(rtN * 5, dtype=jnp.float32).reshape(rtN, 5) * 0.25 + 1
    f = shard_map(pc.all_reduce, mesh=mesh, in_specs=P("data"),
                  out_specs=P("data"))
    want = jnp.broadcast_to(x.sum(0), (rtN, 5))
    assert jnp.allclose(f(x), want), ep.index
    # and the host simulation agrees with the mesh execution
    sim = pc.simulate_allreduce([np.asarray(x[i]) for i in range(rtN)])
    for i in range(rtN):
        np.testing.assert_allclose(sim[i], np.asarray(want[i]), rtol=1e-6)
print("OK")
"""
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env={**__import__("os").environ,
                                          "PYTHONPATH": "src"},
                         cwd=__import__("os").path.dirname(
                             __import__("os").path.dirname(__file__)),
                         timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout


# ----------------------------------------------------- serve phase gate
def test_serve_engine_phase_gated_refill():
    jax = pytest.importorskip("jax")
    import numpy as np
    from repro.models.registry import get_api, get_config
    from repro.serve.engine import Request, ServeEngine

    cfg = get_config("smollm-135m").reduced()
    api = get_api(cfg)
    params = api.init_params(jax.random.key(0))
    eng = ServeEngine(api, params, batch=2, window=32)
    reqs = [Request(rid=i, prompt=np.array([1 + i, 2, 3], np.int32),
                    max_new=3) for i in range(4)]
    for r in reqs:
        eng.submit(r)
    assert eng.epoch == 0
    done = eng.run_until_drained()
    assert [r.rid for r in done] == [0, 1, 2, 3]
    assert all(r.done and len(r.out) == 3 for r in reqs)
    # every admit and retire landed as an epoch at a phase boundary:
    # 4 joins + 4 leaves, batched per boundary -> at least 4 epochs
    assert eng.epoch >= 4
    kinds = [e.kind for e in eng.gate.events]
    assert kinds.count("join") == 4 and kinds.count("leave") == 4
    eng.gate.verify_epoch()
    assert eng.gate.epoch.live == ()         # drained team is empty


def test_serve_engine_one_token_requests_still_land_epochs():
    """A request that finishes during its own admission (max_new=1, so
    the prefill's token is the whole generation) joins and leaves inside
    ``_admit`` — the boundary advance must still land that churn as an
    epoch instead of leaving the gate dirty."""
    jax = pytest.importorskip("jax")
    import numpy as np
    from repro.models.registry import get_api, get_config
    from repro.serve.engine import Request, ServeEngine

    cfg = get_config("smollm-135m").reduced()
    api = get_api(cfg)
    params = api.init_params(jax.random.key(0))
    eng = ServeEngine(api, params, batch=2, window=32)
    reqs = [Request(rid=i, prompt=np.array([1 + i, 2], np.int32),
                    max_new=1) for i in range(3)]
    for r in reqs:
        eng.submit(r)
    done = eng.run_until_drained()
    assert [r.rid for r in done] == [0, 1, 2]
    assert all(len(r.out) == 1 for r in reqs)
    assert not eng.gate.pending_churn
    assert eng.epoch >= 1
    eng.gate.verify_epoch()
    assert eng.gate.epoch.live == ()


def test_halving_doubling_accepts_non_pow2():
    """Shrink-to-3-style teams run the elimination pre-phase instead of
    being rejected (or falling back)."""
    pc = PhaserCollective(3, "data", kind="halving_doubling")
    xs = [np.full((5,), float(i + 1)) for i in range(3)]
    out = pc.simulate_allreduce(xs)
    for o in out:
        np.testing.assert_allclose(o, np.full((5,), 6.0))
    st = pc.stats()
    assert st["rounds"] == 2 + 3          # 1 core round each way + elim


def test_train_loop_resume_replays_elastic_churn(tmp_path):
    """A resumed run reconstructs the runtime by replaying the churn
    schedule up to the restored step: live set and epoch index match the
    pre-crash run instead of silently reverting to the initial team."""
    jax = pytest.importorskip("jax")
    from repro.checkpoint import CheckpointManager
    from repro.data import SyntheticLM
    from repro.models.registry import get_api, get_config
    from repro.optim import AdamW
    from repro.train.loop import TrainLoop

    cfg = get_config("smollm-135m").reduced()
    api = get_api(cfg)

    def fresh(d):
        return TrainLoop(api=api,
                         opt=AdamW(lr=1e-3, warmup=2, total_steps=8),
                         data=SyntheticLM(cfg.vocab_size, 2, 16, seed=3),
                         ckpt=CheckpointManager(str(d), async_write=False),
                         ckpt_every=4, log_every=10,
                         runtime=ElasticPhaserRuntime(3, seed=0),
                         elastic_events={1: [("join", None)],
                                         2: [("fail", None)]})

    a = fresh(tmp_path)
    a.run(4)                                  # "crash" after the ckpt @ 4
    pre_live, pre_epoch = sorted(a.runtime.live), a.runtime.epoch.index

    b = fresh(tmp_path)
    b.run(8, resume=True)
    assert sorted(b.runtime.live) == pre_live == [0, 1, 2]
    assert b.runtime.epoch.index >= pre_epoch == 2
    b.runtime.verify_epoch()


def test_controller_collective_kind_override_keeps_kind():
    from repro.runtime_elastic import ElasticController

    c = ElasticController(4, seed=0, kind="recursive_doubling")
    c.join(0)
    c.step_barrier(0)                       # epoch of 5: not a pow2 team
    assert c.epoch.kind == "recursive_doubling"   # elimination, no fallback
    # explicit overrides derive over the same live keys, any kind
    pc = c.collective("halving_doubling")
    assert pc.kind == "halving_doubling" and pc.n == 5
    pc = c.collective("phaser_scsl")
    assert pc.kind == "phaser_scsl" and pc.keys == c.epoch.live
