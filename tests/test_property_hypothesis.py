"""Hypothesis property tests on system invariants."""
import math

import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="dev-only dependency (requirements-dev.txt); property tier "
           "skipped where it is not installed")
from hypothesis import given, settings, strategies as st

from repro.core.phaser import SIG_WAIT, DistPhaser, HEAD
from repro.core.runtime import RandomScheduler
from repro.core.skiplist import SkipList, det_height
from repro.data.synthetic import make_batch


# ---------------------------------------------------------------- skiplist
@given(st.lists(st.integers(0, 10_000), min_size=1, max_size=60,
                unique=True),
       st.integers(0, 2**31 - 1))
@settings(max_examples=60, deadline=None)
def test_skiplist_insert_integrity(keys, seed):
    sl = SkipList.build(keys, seed=seed)
    sl.check_integrity()
    assert sl.keys() == sorted(keys)


@given(st.lists(st.integers(0, 10_000), min_size=2, max_size=40,
                unique=True),
       st.data())
@settings(max_examples=40, deadline=None)
def test_skiplist_delete_integrity(keys, data):
    sl = SkipList.build(keys, seed=7)
    victims = data.draw(st.lists(st.sampled_from(keys), unique=True,
                                 max_size=len(keys) - 1))
    for v in victims:
        sl.delete(v)
        sl.check_integrity()
    assert sl.keys() == sorted(set(keys) - set(victims))


@given(st.integers(0, 100_000))
@settings(max_examples=100, deadline=None)
def test_det_height_deterministic_and_bounded(key):
    h1 = det_height(key, seed=3)
    h2 = det_height(key, seed=3)
    assert h1 == h2
    assert 1 <= h1 <= 32


def test_det_height_geometric_distribution():
    hs = [det_height(k, seed=0) for k in range(20_000)]
    frac_ge2 = sum(h >= 2 for h in hs) / len(hs)
    frac_ge3 = sum(h >= 3 for h in hs) / len(hs)
    assert abs(frac_ge2 - 0.5) < 0.02          # p = 0.5
    assert abs(frac_ge3 - 0.25) < 0.02


# ----------------------------------------------------------------- phaser
@given(st.integers(2, 10), st.integers(0, 10_000), st.integers(1, 4))
@settings(max_examples=30, deadline=None)
def test_phases_advance_under_random_interleaving(n, seed, phases):
    ph = DistPhaser(n, seed=seed % 7)
    sched = RandomScheduler(seed)
    for k in range(phases):
        assert ph.next(scheduler=sched) == k
    ph.check_quiescent_invariants()


@given(st.integers(3, 8), st.integers(0, 1_000_000))
@settings(max_examples=30, deadline=None)
def test_churn_under_random_interleaving(n, seed):
    """Add + drop + signal under adversarial delivery: the phase always
    completes exactly, structure converges to the live set."""
    rng = np.random.default_rng(seed)
    ph = DistPhaser(n, seed=1)
    sched = RandomScheduler(seed)
    ph.async_add(int(rng.integers(0, n)), n + 5)
    victim = int(rng.integers(1, n))
    ph.drop(victim)
    for r in range(n):
        if r != victim:
            ph.signal(r)
    ph.signal(n + 5)
    ph.run(sched)
    assert ph.released() == 0
    ph.check_quiescent_invariants()
    # conservation: head holds no residue for released phases
    head = ph.actors[HEAD]
    assert not any(k <= head.head_released and v > 0
                   for k, v in head.sc.buf.items())


# ------------------------------------------------------------------- data
@given(st.integers(0, 1000), st.integers(0, 50))
@settings(max_examples=20, deadline=None)
def test_synthetic_data_deterministic(seed, step):
    a = make_batch(256, 4, 32, seed=seed, step=step)
    b = make_batch(256, 4, 32, seed=seed, step=step)
    assert np.array_equal(a["tokens"], b["tokens"])
    assert np.array_equal(a["targets"], b["targets"])
    # next-token alignment
    assert np.array_equal(a["tokens"][:, 1:], a["targets"][:, :-1])
