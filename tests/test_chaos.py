"""Fault-tolerant control plane (DESIGN.md §13).

Tier-1 drives the deterministic chaos fabric (``FaultyInprocFabric``)
and simulated crash-stops: unilateral eviction after ``kill_host``, the
generation fence black-holing the dead incarnation's frames, seeded
fault-injection determinism (same seed -> identical fingerprints AND
identical fault counters), a seed-sweep property tier over
boot/churn/advance under chaos, and deterministic-clock unit tests of
the phi-accrual detector's suspect -> confirm -> declare machine and
the jittered bounded backoff.

The slow tier crosses real process boundaries: a SIGKILLed
``SocketCluster`` worker is declared dead by heartbeat silence and
evicted non-cooperatively while the survivors keep advancing, and an
orphaned worker (its coordinator gone silent) exits cleanly with its
span shard flushed to disk instead of hanging forever.
"""
from __future__ import annotations

import json
import os
import random
import subprocess
import sys

import pytest

from repro.runtime_dist import (ChaosConfig, DistCoordinator, InprocCluster,
                                PhiDetector, backoff)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def coordinator(n, *, chaos=None, **kw):
    return DistCoordinator(InprocCluster(chaos=chaos), n,
                           seed=kw.pop("seed", 0), **kw)


# ------------------------------------------------------------------ backoff
def test_backoff_is_bounded_exponential_with_jitter():
    base, cap = 0.25, 2.0
    bare = [backoff(a, base, cap) for a in range(1, 10)]
    assert bare[0] == base
    assert all(b2 >= b1 for b1, b2 in zip(bare, bare[1:]))   # monotone
    assert bare[-1] == cap                                   # capped
    rng = random.Random(0)
    for a in range(1, 10):
        d = backoff(a, base, cap, rng)
        assert bare[min(a, 9) - 1] <= d <= bare[min(a, 9) - 1] * 1.5 + 1e-9
    # same seed -> same jitter sequence (retries are reproducible)
    seq = [backoff(a, base, cap, random.Random(7)) for a in (3, 3, 3)]
    assert seq[0] == seq[1] == seq[2]


# ----------------------------------------------------------- phi detector
def test_phi_detector_suspect_confirm_declare():
    """Deterministic clock: a silent host is suspected first, declared
    dead only when BOTH the adaptive phi test and the hard silence
    floor hold, and an ack during suspicion recovers it."""
    det = PhiDetector(interval=0.5, timeout=4.0, phi_suspect=4.0,
                      phi_dead=8.0, window=8)
    det.touch(1, t=0.0)
    t = 0.0
    while t < 3.0:                      # healthy acks every 0.5s
        t += 0.5
        det.on_ack(1, t=t)
    assert det.poll(now=t) == [] and det.state[1] == det.ALIVE
    # silence begins: phi crosses suspect quickly, but the hard floor
    # (timeout=4s) must ALSO pass before declaration
    assert det.poll(now=t + 2.5) == []
    assert det.state[1] == det.SUSPECT
    assert det.poll(now=t + 3.9) == []          # phi huge, floor not met
    newly = det.poll(now=t + 4.1)
    assert newly == [1] and det.state[1] == det.DEAD
    assert det.declared[1]["silence"] == pytest.approx(4.1)
    # declared is edge-triggered and sticky; late acks are ignored
    assert det.poll(now=t + 10.0) == []
    det.on_ack(1, t=t + 10.0)
    assert det.state[1] == det.DEAD


def test_phi_detector_ack_during_suspicion_recovers():
    det = PhiDetector(interval=0.5, timeout=4.0)
    det.touch(2, t=0.0)
    det.on_ack(2, t=0.5)
    det.poll(now=3.0)
    assert det.state[2] == det.SUSPECT
    det.on_ack(2, t=3.1)                # confirm failed: back to alive
    assert det.state[2] == det.ALIVE
    det.remove(2)                       # cooperative departure
    assert det.poll(now=100.0) == [] and 2 not in det.state


# ------------------------------------------------- inproc crash recovery
def test_inproc_kill_host_recovers_unilaterally():
    """A crash-stop host cannot answer the cooperative unlink dance:
    the coordinator evicts it unilaterally, every survivor re-seeds its
    shard from the surviving oracle under a bumped generation, and
    phases keep advancing with fingerprint-agreed epochs."""
    rt = coordinator(4)
    rt.advance(step=0)
    fps = [rt.epoch.fingerprint]
    rt.cluster.kill_host(2)                   # no protocol, no goodbye
    for s in range(1, 4):
        rt.advance(step=s)                    # auto-recovers, then phases
    assert 2 not in rt.live and sorted(rt.live) == [0, 1, 3]
    assert rt.epoch.live == (0, 1, 3)
    fps.append(rt.epoch.fingerprint)
    assert fps[0] != fps[1]                   # structure identity changed
    assert [e.kind for e in rt.events] == ["dead"]
    assert rt.gen >= 1                        # incarnation fence bumped
    # the dead pid is black-holed at every survivor's network edge
    nets = [rt.shard.net] + [a.shard.net
                             for a in rt.cluster.agents.values()]
    for net in nets:
        assert 2 in net.dropped
    rt.close()


def test_inproc_kill_during_epoch_with_pending_churn():
    """A crash racing an in-flight join: the join still lands, the dead
    host is evicted, and both changes appear in fingerprint-distinct
    epochs."""
    rt = coordinator(3)
    rt.request_join(step=0)
    rt.cluster.kill_host(1)
    rt.advance(step=0)
    assert 1 not in rt.live and 3 in rt.live
    for s in range(1, 4):
        rt.advance(step=s)
    assert rt.epoch.live == (0, 2, 3)
    assert len({e.fingerprint for e in rt.epochs}) == len(rt.epochs)
    kinds = [e.kind for e in rt.events]
    assert "dead" in kinds and "join" in kinds
    rt.close()


# ------------------------------------------------------- chaos determinism
def _churn_run(seed, *, obs=False):
    """One seeded chaos run: boot 4, join, advance, kill, advance."""
    rt = coordinator(4, chaos=ChaosConfig(seed=seed, p_drop=0.0, p_dup=0.0,
                                          p_delay=0.4, delay_ticks=3),
                     obs=obs)
    rt.advance(step=0)
    rt.request_join(step=1)
    rt.advance(step=1)
    rt.cluster.kill_host(1)
    for s in range(2, 6):
        rt.advance(step=s)
    fps = [e.fingerprint for e in rt.epochs]
    faults = rt.cluster.fault_counters()
    released = rt.shard.released()
    out = (fps, faults, released, sorted(rt.live),
           rt.obs.summary() if obs else None)
    rt.close()
    return out


def test_chaos_fabric_is_deterministic_per_seed():
    a = _churn_run(11)
    b = _churn_run(11)
    assert a == b                        # fingerprints AND fault counters
    c = _churn_run(12)
    assert c[2] == a[2] and c[3] == a[3]   # same protocol outcome...
    assert c[1] != a[1] or c[0] == a[0]    # ...different injected faults


def test_chaos_blackhole_accounting_and_hop_bound():
    """Under chaos + a crash, with obs on: frames reaped at the fabric
    (dead destination) are counted and span-closed, the lost shard's
    records are tolerated, and the O(log P) per-signal hop assertion
    still runs (and passes) at every advance."""
    fps, faults, released, live, summary = _churn_run(5, obs=True)
    assert live == [0, 2, 3, 4] and released >= 4
    assert faults.get("delayed", 0) > 0          # chaos actually fired
    assert summary["hop_checks"] >= 5            # T2a ran every advance
    assert summary["spans"] > 0


@pytest.mark.parametrize("seed", [1, 2, 3, 4, 5, 6])
def test_chaos_seed_sweep_property(seed):
    """Property tier: for arbitrary fault-injection seeds, a fixed
    boot/churn/kill/advance schedule must preserve every invariant —
    strictly monotone phase releases, unique fingerprints per epoch,
    the dead host evicted exactly once, and quiescence reached."""
    rng = random.Random(seed)
    chaos = ChaosConfig(seed=seed, p_drop=0.0, p_dup=0.0,
                        p_delay=0.2 + 0.6 * rng.random(),
                        delay_ticks=1 + rng.randrange(5))
    rt = coordinator(4, chaos=chaos)
    releases = []
    victim = rng.choice([1, 2, 3])
    kill_at = rng.randrange(1, 4)
    for s in range(6):
        if s == kill_at:
            rt.cluster.kill_host(victim)
        if s == 2 and victim != 3:
            rt.request_join(step=s)
        releases.append(rt.advance(step=s))
    assert releases == sorted(releases)            # no out-of-order phase
    assert all(b > a for a, b in zip(releases, releases[1:]))
    assert victim not in rt.live
    assert [e.kind for e in rt.events].count("dead") == 1
    assert len({e.fingerprint for e in rt.epochs}) == len(rt.epochs)
    rt.close()


# ------------------------------------------------------- slow: real sockets
@pytest.mark.slow
def test_socket_kill9_detected_and_evicted():
    """SIGKILL a worker OS process mid-epoch: heartbeat silence drives
    suspect -> confirm -> declare, the coordinator evicts unilaterally,
    and the survivors keep advancing with agreed fingerprints."""
    code = """
import os, time
os.chdir({root!r})
from repro.runtime_dist import DistCoordinator, SocketCluster

cl = SocketCluster(control_only=True, hb_interval=0.1, failure_timeout=2.0)
rt = DistCoordinator(cl, 3, seed=0)
rt.advance(step=0)
cl.kill_pid(1)                             # SIGKILL, no cleanup
t0 = time.monotonic()
for s in range(1, 5):
    rt.advance(step=s)                     # detect + evict + keep going
dt = time.monotonic() - t0
assert sorted(rt.live) == [0, 2], rt.live
assert rt.epoch.live == (0, 2)
assert "dead" in [e.kind for e in rt.events]
assert len({{e.fingerprint for e in rt.epochs}}) == len(rt.epochs)
assert dt < 60.0, dt
rt.close()
print("OK")
""".format(root=REPO)
    out = subprocess.run([sys.executable, "-c", code],
                         capture_output=True, text=True,
                         env={**os.environ, "PYTHONPATH":
                              os.path.join(REPO, "src")},
                         cwd=REPO, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK" in out.stdout


@pytest.mark.slow
def test_socket_chaos_converges_with_kill():
    """Chaos on the real socket fabric (seeded drops/dups of command
    and heartbeat frames, delayed envelope channels) plus a SIGKILL:
    idempotent command replay keeps RPCs exactly-once, the phaser
    protocol's per-channel FIFO survives, and training-control phases
    converge over the survivors."""
    code = """
import os
os.chdir({root!r})
from repro.runtime_dist import ChaosConfig, DistCoordinator, SocketCluster

chaos = ChaosConfig(seed=7, p_drop=0.15, p_dup=0.10, p_delay=0.30,
                    max_delay=0.02)
cl = SocketCluster(control_only=True, hb_interval=0.1, failure_timeout=3.0,
                   chaos=chaos)
rt = DistCoordinator(cl, 3, seed=0)
for s in range(3):
    rt.advance(step=s)
rt.request_join(step=3)
rt.advance(step=3)
assert rt.epoch.live == (0, 1, 2, 3)
cl.kill_pid(2)
for s in range(4, 8):
    rt.advance(step=s)
assert 2 not in rt.live
faults = cl.fault_counters()
assert sum(faults.values()) > 0, faults     # chaos actually fired
assert len({{e.fingerprint for e in rt.epochs}}) == len(rt.epochs)
rt.close()
print("OK")
""".format(root=REPO)
    out = subprocess.run([sys.executable, "-c", code],
                         capture_output=True, text=True,
                         env={**os.environ, "PYTHONPATH":
                              os.path.join(REPO, "src")},
                         cwd=REPO, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK" in out.stdout


@pytest.mark.slow
def test_orphaned_worker_exits_and_flushes_spans():
    """Regression: a worker whose coordinator dies must not hang
    forever on a silent socket. After ``orphan_timeout`` of heartbeat
    silence it flushes its span shard to disk and exits with code 2."""
    code = """
import os
os.chdir({root!r})
from repro.runtime_dist import SocketCluster

cl = SocketCluster(control_only=True, hb_interval=0.1, failure_timeout=1.0,
                   orphan_timeout=2.0)
cl.add_host(0, {{"pid": 0, "n": 1, "seed": 0, "control_only": True}})
p = cl.procs[0]
cl._hb_stop.set()                   # simulate coordinator crash: silence
cl._hb_thread.join(timeout=5)
cl.ep.close()
rc = p.wait(timeout=30)
assert rc == 2, rc
span_file = os.path.join(cl.dir, "worker0.spans.jsonl")
assert os.path.exists(span_file), span_file
print("OK")
""".format(root=REPO)
    out = subprocess.run([sys.executable, "-c", code],
                         capture_output=True, text=True,
                         env={**os.environ, "PYTHONPATH":
                              os.path.join(REPO, "src")},
                         cwd=REPO, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK" in out.stdout


@pytest.mark.slow
def test_train_cli_kill_event_finite_loss(tmp_path):
    """End to end through the CLI: a 3-process socket data-plane run
    with a SIGKILL mid-run detects, evicts, and finishes with finite
    loss; the exported span log passes the offline checker including
    the failure op."""
    spans = str(tmp_path / "run.trace.json")
    env = {**os.environ,
           "PYTHONPATH": os.path.join(REPO, "src"),
           "XLA_FLAGS": "--xla_force_host_platform_device_count=3",
           "JAX_PLATFORMS": "cpu"}
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.train",
         "--reduced", "--layers", "2", "--steps", "8", "--batch", "4",
         "--seq", "32", "--processes", "3",
         "--fabric", "socket", "--heartbeat-interval", "0.2",
         "--failure-timeout", "3", "--elastic", "kill:2@4",
         "--trace", spans],
        capture_output=True, text=True, env=env, cwd=REPO, timeout=900)
    assert out.returncode == 0, (out.stdout[-2000:], out.stderr[-3000:])
    assert "nan" not in out.stdout.lower().split("loss")[-1][:40]
    span_log = spans[:-5] + ".spans.jsonl"
    chk = subprocess.run(
        [sys.executable, "-m", "repro.obs.check", span_log,
         "--hosts", "3", "--require-ops", "signal,failure"],
        capture_output=True, text=True, env=env, cwd=REPO, timeout=300)
    assert chk.returncode == 0, (chk.stdout[-2000:], chk.stderr[-2000:])
