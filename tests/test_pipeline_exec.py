"""Point-to-point phaser modes + pipeline subsystem (DESIGN.md §6).

Layers of evidence:

1. SIG/WAIT mode semantics on the real actors: signal accumulation
   (producers run ahead without blocking), waiters never gate phase
   completion, and the converged SCSL/SNSL equal the MODE-FILTERED
   skip-list oracle;
2. hypothesis properties: on randomized stage graphs, randomized
   (S, M, v) interleaved schedules, and randomized valid op
   interleavings — including straggler demotion/repromotion of edge
   participants MID-program — the protocol's observed release order
   equals the host counter oracle (``simulate_program``) — the p2p
   analogue of the collective ``simulate_schedule`` equivalence;
3. the 1F1B wave schedule and its interleaved virtual-stage
   generalization: dependency validity, the steady-state F/B
   alternation, the per-chunk in-flight bounds (ring contiguity), the
   factor-v bubble reduction, and ``verify_phase_order`` against real
   actors for (S, M, v) sweeps;
4. ProgramCache keying across 2-D configs: (stage map x interleave x
   member set x demotion leaf set) are distinct entries, revisits hit;
5. straggler demotion: leaf pinning in the oracle + schedule, the
   demote-then-evict escalation, re-promotion on recovery;
6. numeric (subprocess, 8 host devices, slow): the compiled 2-D
   pipeline programs — wave-synchronous AND interleaved — produce the
   same loss and params as the single-axis ``xla_psum`` engine across
   grow/shrink epochs.
"""
import subprocess
import sys

import numpy as np
import pytest

from repro.core.collective import PhaserCollective
from repro.core.p2p import (P2PPhaser, PipelinePhaserGraph,
                            simulate_program)
from repro.core.phaser import SIG_MODE, SIG_WAIT, WAIT_MODE
from repro.core.skiplist import SkipList
from repro.pipeline_exec import derive_1f1b, derive_interleaved, \
    pipeline_edges, verify_phase_order
from repro.runtime_elastic import ElasticPhaserRuntime


# ------------------------------------------------- SIG/WAIT semantics
def test_sig_wait_producer_consumer_accumulation():
    p = P2PPhaser({0: SIG_MODE, 1: WAIT_MODE}, seed=0)
    assert not p.wait(1, 0)
    p.signal(0, times=3)                  # unbounded run-ahead
    assert p.wait(1, 0) and p.wait(1, 2) and not p.wait(1, 3)
    p.verify_topology()


def test_waiters_never_gate_release():
    """A pure WAIT participant contributes no expectation: phases
    release on the signalers alone, and releases diffuse to it."""
    p = P2PPhaser({0: SIG_MODE, 1: SIG_MODE, 2: WAIT_MODE}, seed=1)
    p.signal(0, 2)
    assert p.released(2) == -1            # held by signaler 1, not by 2
    p.signal(1, 1)
    assert p.released(2) == 0
    assert p.pending(0) == 1              # one accumulated signal ahead
    p.verify_topology()


def test_sig_only_cannot_wait_and_wait_only_cannot_signal():
    p = P2PPhaser({0: SIG_MODE, 1: WAIT_MODE}, seed=0)
    with pytest.raises(AssertionError):
        p.signal(1)
    with pytest.raises(AssertionError):
        p.wait(0, 0)


def test_mode_filtered_oracle_after_dynamic_add():
    """New participants register with explicit modes; each list's
    converged structure is the oracle over ITS mode's key set."""
    p = P2PPhaser({0: SIG_WAIT, 1: SIG_MODE, 2: WAIT_MODE}, seed=2)
    p.add_participant(0, 3, SIG_MODE)
    p.add_participant(0, 4, WAIT_MODE)
    p.signal(0), p.signal(1), p.signal(3)
    assert p.released(2) == 0 and p.released(4) == 0
    assert sorted(p.signalers()) == [0, 1, 3]
    assert sorted(p.waiters()) == [0, 2, 4]
    p.verify_topology()


def test_graph_modes_aggregate():
    g = PipelinePhaserGraph(3, pipeline_edges(3), seed=0)
    assert g.mode_of(0) == SIG_WAIT       # signals fwd, waits on bwd
    assert g.mode_of(1) == SIG_WAIT
    assert g.mode_of(2) == SIG_WAIT
    g2 = PipelinePhaserGraph(2, [(0, 1)], seed=0)
    assert g2.mode_of(0) == SIG_MODE and g2.mode_of(1) == WAIT_MODE


# ------------------------------------------------- hypothesis property
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYP = True
except ImportError:
    HAVE_HYP = False


if HAVE_HYP:
    @given(st.integers(2, 5), st.integers(0, 10_000), st.integers(5, 40))
    @settings(max_examples=30, deadline=None)
    def test_random_stage_graph_release_order_matches_oracle(
            n, seed, n_ops):
        """Random directed stage graphs, random VALID op interleavings:
        the real actors' release order equals the counter oracle and
        every wait is satisfied exactly when the oracle says so."""
        rng = np.random.default_rng(seed)
        pairs = [(u, v) for u in range(n) for v in range(n) if u != v]
        k = int(rng.integers(1, min(len(pairs), 6) + 1))
        idx = rng.choice(len(pairs), size=k, replace=False)
        edges = [pairs[i] for i in idx]
        prog, count = [], {tuple(e): 0 for e in edges}
        for _ in range(n_ops):
            e = tuple(edges[rng.integers(len(edges))])
            if count[e] and rng.integers(2):
                prog.append(("wait", e, int(rng.integers(count[e]))))
            else:
                prog.append(("signal", e))
                count[e] += 1
        g = PipelinePhaserGraph(n, edges, seed=seed % 7)
        got = g.run_program(prog)
        want = simulate_program(edges, prog)
        assert [(e.edge, e.phase) for e in got] == \
            [(e.edge, e.phase) for e in want]
        g.verify_topologies()

    @given(st.integers(1, 4), st.integers(1, 6))
    @settings(max_examples=24, deadline=None)
    def test_1f1b_phase_order_verifies_for_any_shape(S, M):
        sched = derive_1f1b(S, M)
        sched.check()
        verify_phase_order(sched)

    @given(st.integers(1, 3), st.integers(1, 3), st.integers(1, 3))
    @settings(max_examples=24, deadline=None)
    def test_interleaved_phase_order_verifies_for_any_shape(S, v, k):
        """Random (stages, interleave, microbatches=k*S): the expanded
        S*v-chunk schedule is valid (check: dependencies, per-chunk
        in-flight bounds, ring contiguity, F/B alternation) and its
        release order through REAL actors equals the counter oracle."""
        M = k * S                       # chunk rotation needs M % S == 0
        sched = derive_interleaved(S, M, v)
        sched.check()
        verify_phase_order(sched)
        assert sched.n_waves == 2 * (v * M + S - 1)
        # the interleaved bubble fraction divides the plain one
        assert sched.bubble_fraction() <= \
            derive_1f1b(S, M).bubble_fraction() + 1e-12

    @given(st.integers(1, 3), st.integers(2, 3), st.integers(1, 2),
           st.integers(0, 10_000))
    @settings(max_examples=20, deadline=None)
    def test_interleaved_program_random_interleaving_with_demotion(
            S, v, k, seed):
        """Random VALID interleavings of the interleaved schedule's
        instruction stream — including straggler demotion and
        re-promotion of edge-phaser participants MID-program — keep the
        real actors' release order equal to the counter oracle, and the
        converged topologies equal to the leaf-pinned oracle."""
        rng = np.random.default_rng(seed)
        M = k * S
        sched = derive_interleaved(S, M, v)
        base = sched.as_program()
        edges = pipeline_edges(sched.n_chunks)
        # random valid interleaving: repeatedly pick any op whose wait
        # is already satisfied by the oracle counters
        count = {tuple(e): 0 for e in edges}
        pending = list(base)
        prog = []
        while pending:
            ready = [i for i, op in enumerate(pending)
                     if op[0] == "signal" or count[tuple(op[1])] > op[2]]
            # the wave program is valid, so a prefix op is always ready
            i = int(rng.choice(ready[:max(1, len(ready) // 2)]))
            op = pending.pop(i)
            if op[0] == "signal":
                count[tuple(op[1])] += 1
            prog.append(op)
        g = PipelinePhaserGraph(sched.n_chunks, edges, seed=seed % 5)
        cut = sorted(rng.integers(0, len(prog) + 1, size=2))
        demoted = []
        log = []

        def drive(ops):
            for op in ops:
                if op[0] == "signal":
                    g.signal(op[1])
                else:
                    assert g.wait(op[1], op[2]), op

        drive(prog[:cut[0]])
        if edges:
            e = tuple(edges[rng.integers(len(edges))])
            r = int(rng.integers(2))          # SIG or WAIT participant
            g.demote(e, r)
            demoted.append((e, r))
            g.verify_topologies()             # leaf-pinned oracle holds
        drive(prog[cut[0]:cut[1]])
        if demoted and rng.integers(2):
            g.repromote(*demoted.pop())
        drive(prog[cut[1]:])
        got = [(ev.edge, ev.phase) for ev in g.release_log]
        want = [(ev.edge, ev.phase)
                for ev in simulate_program(edges, prog)]
        assert got == want
        g.verify_topologies()

    @given(st.integers(2, 6), st.integers(0, 10_000),
           st.lists(st.sampled_from(["join", "leave", "demote",
                                     "repromote", "step"]),
                    max_size=14))
    @settings(max_examples=30, deadline=None)
    def test_churn_with_demotion_epochs_match_oracle(n, seed, ops):
        rng = np.random.default_rng(seed)
        rt = ElasticPhaserRuntime(n, seed=seed % 5)
        for op in ops:
            if op == "join":
                rt.request_join()
            elif op == "leave" and len(rt.live) > 1:
                rt.request_leave(int(rng.choice(sorted(rt.live))))
            elif op == "demote" and rt.live:
                rt.request_demote(int(rng.choice(sorted(rt.live))))
            elif op == "repromote" and rt.demoted:
                rt.request_repromote(int(rng.choice(sorted(rt.demoted))))
            else:
                rt.advance()
        rt.advance()
        rt.verify_epoch()
        rt.ph.check_quiescent_invariants()
        for ep in rt.epochs:
            if ep.collective is not None:
                assert ep.collective.matches_oracle(), ep.index


# --------------------------------------------------- 1F1B wave schedule
def test_1f1b_last_stage_strictly_alternates():
    s = derive_1f1b(3, 4)
    assert s.stage_stream(2) == [("F", 0), ("B", 0), ("F", 1), ("B", 1),
                                 ("F", 2), ("B", 2), ("F", 3), ("B", 3)]


def test_1f1b_in_flight_bound_beats_gpipe():
    """The wave-synchronous 1F1B cap min(M, 2(S-1-s)+1): for deep M the
    last stages hold far fewer than GPipe's M activations."""
    S, M = 4, 16
    s = derive_1f1b(S, M)
    for stage in range(S):
        live = peak = 0
        for kind, _ in s.stage_stream(stage):
            live += 1 if kind == "F" else -1
            peak = max(peak, live)
        assert peak == min(M, 2 * (S - 1 - stage) + 1)


def test_1f1b_program_is_valid_linearization():
    sched = derive_1f1b(3, 3)
    prog = sched.as_program()
    # the oracle raising would mean an unsatisfied wait
    simulate_program(pipeline_edges(3), prog)
    # every fwd edge signals M phases, every bwd edge too
    sig = {}
    for op in prog:
        if op[0] == "signal":
            sig[op[1]] = sig.get(op[1], 0) + 1
    assert all(v == 3 for v in sig.values()) and len(sig) == 4


def test_stage_partition_validates():
    from repro.models.registry import get_api, get_config
    from repro.pipeline_exec import stage_partition
    api = get_api(get_config("smollm-135m").reduced())
    assert stage_partition(api, 2) == ((0, 1), (1, 2))
    with pytest.raises(AssertionError):
        stage_partition(api, 3)           # 2 layers don't split 3 ways
    enc = get_api(get_config("whisper-small").reduced())
    with pytest.raises(AssertionError):
        stage_partition(enc, 2)           # enc-dec keeps single-axis


# --------------------------------------- interleaved (virtual stages)
def test_interleaved_bubble_factor_v_reduction():
    """The headline: with v chunks per device the fill/drain cost stays
    2(S-1) waves but each wave computes 1/v of a stage — the bubble
    fraction falls from (S-1)/(M+S-1) to (S-1)/(vM+S-1)."""
    for S, M in ((2, 4), (2, 8), (4, 8)):
        plain = derive_1f1b(S, M)
        inter = derive_interleaved(S, M, 2)
        assert plain.n_waves == 2 * (M + S - 1)
        assert inter.n_waves == 2 * (2 * M + S - 1)
        assert plain.n_waves - 2 * M == 2 * (S - 1)       # thick waves
        assert inter.n_waves - 2 * 2 * M == 2 * (S - 1)   # THIN waves
        assert abs(inter.bubble_fraction()
                   - (S - 1) / (2 * M + S - 1)) < 1e-12
        assert inter.bubble_fraction() < plain.bubble_fraction()


def test_interleaved_per_chunk_inflight_tighter_than_expanded_wave_sync():
    """Every chunk's in-flight peak stays at or under the proved bound
    min(M, 2(S-1-s)+1 + (v-1-j)S) — strictly tighter than what the
    expanded S*v-chunk graph would pay under the plain wave-synchronous
    bound min(vM, 2(Sv-1-c)+1) — and live microbatches stay consecutive
    (the compiled program's ring-buffer contract)."""
    for S, M, v in ((2, 4, 2), (4, 8, 2), (3, 6, 2), (2, 8, 4)):
        sched = derive_interleaved(S, M, v)
        inflight = sched.chunk_inflight()
        for (s, j), (peak, span) in inflight.items():
            bound = sched.inflight_bound(s, j)
            c = sched.chunk_of(s, j)
            expanded = min(v * M, 2 * (S * v - 1 - c) + 1)
            assert peak <= bound, (s, j, peak, bound)
            assert span <= bound, (s, j, span, bound)
            if c < S * v - 1:
                assert bound <= expanded, (s, j, bound, expanded)
        assert sched.ring_slots == max(sp for _, sp in inflight.values())


def test_interleaved_chunk_stream_breadth_first_rotation():
    """Device 0 at S=2, v=2, M=4 rotates chunk groups with period S:
    S microbatches through group 0, S through group 1, then the next
    round — the order that lets microbatch 0 reach chunk group 1
    exactly when device 0 finishes group 0's first round."""
    sched = derive_interleaved(2, 4, 2)
    fwd = [(j, m) for k, j, m in sched.chunk_stream(0) if k == "F"]
    assert fwd == [(0, 0), (0, 1), (1, 0), (1, 1),
                   (0, 2), (0, 3), (1, 2), (1, 3)]
    bwd = [(j, m) for k, j, m in sched.chunk_stream(0) if k == "B"]
    assert bwd == [(1, 0), (1, 1), (0, 0), (0, 1),
                   (1, 2), (1, 3), (0, 2), (0, 3)]
    # steady state still alternates: never two forwards back to back
    # after the first backward (backward runs drain the cooldown)
    kinds = [k for k, _, _ in sched.chunk_stream(0)]
    tail = kinds[kinds.index("B"):]
    assert not any(a == b == "F" for a, b in zip(tail, tail[1:]))


def test_interleaved_requires_microbatch_multiple_of_stages():
    with pytest.raises(AssertionError):
        derive_interleaved(2, 3, 2)
    derive_interleaved(2, 3, 1)            # v=1 takes any M
    derive_interleaved(1, 3, 2)            # S=1 divides everything


def test_interleaved_program_reduces_to_plain_at_v1():
    s1 = derive_1f1b(3, 6)
    s2 = derive_interleaved(3, 6, 1)
    assert s1.waves == s2.waves and s1.fingerprint() == s2.fingerprint()
    assert s1.as_program() == s2.as_program()


def test_stage_partition_interleave_chunks():
    from repro.models.registry import get_api, get_config
    from repro.pipeline_exec import stage_partition
    api = get_api(get_config("smollm-135m").reduced(n_layers=4))
    assert stage_partition(api, 2, 2) == ((0, 1), (1, 2), (2, 3), (3, 4))
    assert stage_partition(api, 2, 1) == ((0, 2), (2, 4))
    with pytest.raises(AssertionError):
        stage_partition(api, 2, 3)         # 4 layers != 6 chunks


# -------------------------------------------- ProgramCache 2-D keying
class _FakeBuilder:
    def __init__(self):
        self.built = []

    def __call__(self, pc):
        self.built.append(pc)
        return object()


def test_program_cache_keys_stage_map_times_member_set():
    from repro.collective_exec import ProgramCache
    teams = [(0, 1, 2, 3), (0, 1, 2, 3, 4, 5), (0, 1, 2)]
    progs = {}
    for stages in (1, 2, 4):
        b = _FakeBuilder()
        cache = ProgramCache(b, extra_key=("pipeline", stages,
                                           "pipelined", 2))
        for keys in teams:
            pc = PhaserCollective(len(keys), "data",
                                  kind="recursive_doubling", keys=keys)
            progs[(stages, keys)] = cache.get(pc)
            assert cache.get(pc) is progs[(stages, keys)]   # revisit hits
        assert cache.stats()["misses"] == len(teams)
        assert cache.stats()["hits"] == len(teams)
    # distinct (stage map, member set) -> distinct programs
    assert len({id(p) for p in progs.values()}) == len(progs)


def test_program_cache_demotion_is_distinct_entry():
    from repro.collective_exec import ProgramCache
    b = _FakeBuilder()
    cache = ProgramCache(b)
    keys = (0, 1, 2, 3)
    plain = PhaserCollective(4, "data", kind="phaser_scsl", keys=keys)
    demoted = PhaserCollective(4, "data", kind="phaser_scsl", keys=keys,
                               leaf_keys=(2,))
    assert cache.get(plain) is not cache.get(demoted)
    assert cache.get(demoted) is cache.get(demoted)
    assert len(cache) == 2


def test_pipeline_program_key_carries_stage_map():
    """The program's own key (what checkpoints persist) separates the
    same member set at different stage counts AND interleave factors."""
    from repro.collective_exec import ProgramCache
    pc = PhaserCollective(2, "data", kind="xla_psum", keys=(0, 1))
    base = ProgramCache.key_of(pc)
    two_stages = base + ("pipeline", ((0, 1), (1, 2)), "eager", 2, 1)
    one_stage = base + ("pipeline", ((0, 2),), "eager", 2, 1)
    interleaved = base + ("pipeline", ((0, 1), (1, 2)), "eager", 2, 2)
    assert two_stages != one_stage != base
    assert interleaved != two_stages


# ------------------------------------------------- straggler demotion
def test_demote_pins_leaf_in_oracle_and_schedule():
    rt = ElasticPhaserRuntime(6, seed=0, kind="phaser_scsl")
    rt.advance()
    tall = max(rt.live, key=lambda w: rt.ph.actors[w].sc.height)
    assert rt.ph.actors[tall].sc.height > 1
    rt.request_demote(tall)
    assert rt.ph.actors[tall].sc.height == 1
    assert rt.ph.actors[tall].sn.height == 1
    rt.advance()
    rt.verify_epoch()
    ep = rt.epoch
    assert ep.collective.leaf_keys == (tall,)
    sl = rt.oracle()
    assert sl.nodes[tall].height == 1
    # a leaf's dependents: at most its level-0 successor's chain head
    assert len(sl.children(tall)) <= 1
    # phases keep completing with the demoted signaler contributing
    before = rt.ph.released()
    rt.advance()
    assert rt.ph.released() == before + 1


def test_demote_then_evict_escalation():
    rt = ElasticPhaserRuntime(4, seed=0)
    rt.advance(step=0)
    for step in range(1, 4):
        times = {0: 1.0, 1: 1.0, 2: 1.0, 3: 10.0}
        evicted = rt.record_step_times(step, times)
        rt.advance(step=step)
        if step == 1:
            assert 3 not in rt.demoted and not evicted
        if step == 2:        # second strike: demoted, still live
            assert 3 in rt.demoted and 3 in rt.live and not evicted
            assert rt.epoch.collective.leaf_keys == (3,)
        if step == 3:        # third strike: evicted
            assert evicted == [3] and 3 not in rt.live
    kinds = [e.kind for e in rt.events]
    assert "demote" in kinds and "fail" in kinds
    rt.verify_epoch()


def test_recovered_straggler_is_repromoted():
    rt = ElasticPhaserRuntime(4, seed=0)
    for step in range(2):
        rt.record_step_times(step, {0: 1.0, 1: 1.0, 2: 1.0, 3: 10.0})
        rt.advance(step=step)
    assert 3 in rt.demoted
    rt.record_step_times(2, {w: 1.0 for w in range(4)})
    rt.advance(step=2)
    assert 3 not in rt.demoted
    assert rt.epoch.collective.leaf_keys == ()
    kinds = [e.kind for e in rt.events]
    assert "repromote" in kinds
    rt.verify_epoch()


def test_skiplist_leaf_keys_override():
    keys = list(range(8))
    plain = SkipList.build(keys, seed=0)
    tall = max(keys, key=lambda k: plain.nodes[k].height)
    leafed = SkipList.build(keys, seed=0, leaf_keys={tall})
    assert leafed.nodes[tall].height == 1
    for k in keys:
        if k != tall:
            assert leafed.nodes[k].height == plain.nodes[k].height
    leafed.check_integrity()


# ----------------------------------------------- numeric (slow, 8 dev)
@pytest.mark.slow
def test_pipeline_program_matches_single_axis_under_churn_subprocess():
    """Grow 2 -> 3 on the 2-D (2-stage x data) mesh: per-step loss and
    params equal the single-axis xla_psum engine, per epoch."""
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.collective_exec import build_gradsync_program
from repro.core.collective import PhaserCollective
from repro.data.synthetic import make_batch
from repro.models.registry import get_api, get_config
from repro.optim import AdamW
from repro.pipeline_exec import build_pipeline_program, derive_1f1b, \\
    verify_phase_order
from repro.runtime_elastic import ElasticPhaserRuntime

cfg = get_config("smollm-135m").reduced()
api = get_api(cfg)
opt = AdamW(lr=3e-3, warmup=2, total_steps=12)
S, M = 2, 2
rt = ElasticPhaserRuntime(2, seed=0, kind="recursive_doubling")
params = api.init_params(jax.random.key(0))
opt_state = opt.init(params)
p2, o2 = params, opt_state
for step in range(8):
    if step == 3:
        rt.request_join()
    pc = rt.epoch.collective
    prog = build_pipeline_program(api, opt, pc, n_stages=S,
                                  microbatches=M, stacked=True)
    ref = build_gradsync_program(
        api, opt, PhaserCollective(pc.n, "data", kind="xla_psum",
                                   keys=pc.keys), stacked=True)
    team = list(rt.epoch.live)
    bs = [make_batch(cfg.vocab_size, 4, 32, seed=100 + w, step=step)
          for w in team]
    batch = {k: np.stack([b[k] for b in bs]) for k in bs[0]}
    alive = jnp.asarray([1.0 if w in rt.live else 0.0 for w in team])
    p_dev, o_dev = prog.bind_state(params, opt_state)
    p_dev, o_dev, pm = prog.step(p_dev, o_dev, batch, alive)
    params, opt_state = prog.readout_state(p_dev, o_dev)
    p2, o2, pm2 = ref.step(p2, o2, batch, alive)
    r, r2 = prog.reduce_metrics(pm), ref.reduce_metrics(pm2)
    np.testing.assert_allclose(float(r["loss"]), float(r2["loss"]),
                               rtol=1e-5, atol=1e-6)
    rt.advance(step=step)
    rt.verify_epoch()
    verify_phase_order(derive_1f1b(S, M))
for a, b in zip(jax.tree_util.tree_leaves(params),
                jax.tree_util.tree_leaves(p2)):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-4, atol=2e-5)
assert len(rt.epochs) == 2 and rt.epochs[-1].n == 3
print("OK")
"""
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env={**__import__("os").environ,
                                          "PYTHONPATH": "src"},
                         cwd=__import__("os").path.dirname(
                             __import__("os").path.dirname(__file__)),
                         timeout=900)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout


@pytest.mark.slow
def test_interleaved_program_matches_single_axis_under_churn_subprocess():
    """Grow 2 -> 3 on the 2-D (2-stage x 2-interleave x data) mesh:
    per-step loss and params equal the single-axis xla_psum engine, per
    epoch, with the pipelined overlap + scan-row bucket sub-groups on —
    and the interleaved phase ordering re-proved at every boundary."""
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.collective_exec import build_gradsync_program
from repro.core.collective import PhaserCollective
from repro.data.synthetic import make_batch
from repro.models.registry import get_api, get_config
from repro.optim import AdamW
from repro.pipeline_exec import build_pipeline_program, \\
    derive_interleaved, verify_phase_order
from repro.runtime_elastic import ElasticPhaserRuntime

cfg = get_config("smollm-135m").reduced(n_layers=4)
api = get_api(cfg)
opt = AdamW(lr=3e-3, warmup=2, total_steps=12)
S, M, V = 2, 2, 2
rt = ElasticPhaserRuntime(2, seed=0, kind="recursive_doubling")
params = api.init_params(jax.random.key(0))
opt_state = opt.init(params)
p2, o2 = params, opt_state
for step in range(8):
    if step == 3:
        rt.request_join()
    pc = rt.epoch.collective
    prog = build_pipeline_program(api, opt, pc, n_stages=S,
                                  interleave=V, microbatches=M,
                                  stacked=True, overlap="pipelined",
                                  block_groups=2)
    assert prog.meta["interleave"] == V
    assert prog.meta["bucket_groups"] >= 4
    ref = build_gradsync_program(
        api, opt, PhaserCollective(pc.n, "data", kind="xla_psum",
                                   keys=pc.keys), stacked=True)
    team = list(rt.epoch.live)
    bs = [make_batch(cfg.vocab_size, 4, 32, seed=100 + w, step=step)
          for w in team]
    batch = {k: np.stack([b[k] for b in bs]) for k in bs[0]}
    alive = jnp.asarray([1.0 if w in rt.live else 0.0 for w in team])
    p_dev, o_dev = prog.bind_state(params, opt_state)
    p_dev, o_dev, pm = prog.step(p_dev, o_dev, batch, alive)
    params, opt_state = prog.readout_state(p_dev, o_dev)
    p2, o2, pm2 = ref.step(p2, o2, batch, alive)
    r, r2 = prog.reduce_metrics(pm), ref.reduce_metrics(pm2)
    np.testing.assert_allclose(float(r["loss"]), float(r2["loss"]),
                               rtol=1e-5, atol=1e-6)
    rt.advance(step=step)
    rt.verify_epoch()
    verify_phase_order(derive_interleaved(S, M, V))
for a, b in zip(jax.tree_util.tree_leaves(params),
                jax.tree_util.tree_leaves(p2)):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-4, atol=2e-5)
assert len(rt.epochs) == 2 and rt.epochs[-1].n == 3
print("OK")
"""
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env={**__import__("os").environ,
                                          "PYTHONPATH": "src"},
                         cwd=__import__("os").path.dirname(
                             __import__("os").path.dirname(__file__)),
                         timeout=900)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout
