"""Tests for recursive-doubling creation and the model checker."""
import math

import pytest

from repro.core import modelcheck as mc
from repro.core.creation import recursive_doubling_build, verify_creation
from repro.core.phaser import DistPhaser
from repro.core.skiplist import SkipList


# -- creation ---------------------------------------------------------------
@pytest.mark.parametrize("n", [1, 2, 3, 5, 8, 13, 16, 33, 64])
def test_creation_converges(n):
    stats = verify_creation(n)
    lg = math.ceil(math.log2(n)) if n > 1 else 0
    assert stats.rounds <= lg + 2           # fold/unfold adds at most 2
    assert stats.messages <= 2 * n * (lg + 2)


def test_creation_all_ranks_identical():
    locals_, _ = recursive_doubling_build(list(range(17)), seed=4)
    edges = {r: sl.collection_edges() for r, sl in locals_.items()}
    first = edges[0]
    assert all(e == first for e in edges.values())


# -- model checker ----------------------------------------------------------
def test_checker_eager_insert_no_violations():
    res = mc.check_decomposed(mc.scenario_eager_insert(3, signals=1),
                              max_states=50_000)
    for s in res:
        assert not s.truncated
        assert s.violations == [], s.focus
        assert s.quiescent >= 1


def test_checker_delete_no_violations():
    res = mc.check_decomposed(mc.scenario_delete(4), max_states=50_000)
    for s in res:
        assert s.violations == [], s.focus


def test_checker_insert_delete_no_violations():
    res = mc.check_decomposed(mc.scenario_insert_delete(3),
                              max_states=100_000)
    for s in res:
        assert s.violations == [], s.focus


def test_checker_double_insert_no_violations():
    res = mc.check_decomposed(mc.scenario_double_insert(3),
                              max_states=100_000)
    for s in res:
        assert s.violations == [], s.focus


def test_full_exploration_small_clean():
    s = mc.check_full(mc.scenario_eager_insert(2, signals=1),
                      max_states=100_000)
    assert not s.truncated
    assert s.violations == []


def test_decomposition_is_cheaper_than_full():
    """The paper's Table-1 motivation: joint exploration blows up,
    per-message-class exploration stays small."""
    full = mc.check_full(mc.scenario_eager_insert(3, signals=2),
                         max_states=50_000)
    dec = mc.check_decomposed(mc.scenario_eager_insert(3, signals=2),
                              max_states=50_000)
    dec_total = sum(s.states for s in dec)
    assert full.states > 10 * dec_total, (full.states, dec_total)


def test_checker_detects_injected_bug(monkeypatch):
    """Mutation test: revert the SCSL re-parent to fire-and-forget (the
    historical bug the CHILD_ADD/CHILD_ADD_ACK handshake fixes) and
    confirm the checker reports a violation. Without the handshake a node
    can hand its open interval to a parent that already closed those
    phases, silently breaking the closing-report chain to the head; a
    concurrent insert then anchors a registration against the dead chain
    and the head releases the phase with the +1 delta still in flight."""
    from repro.core import phaser as phx
    from repro.core import messages as M
    from repro.core.phaser import SNSL

    orig = phx.PhaserActor._reparent

    def buggy(self, st, new_parent, effective):
        if st.lid == SNSL:
            return orig(self, st, new_parent, effective)
        # BUG: immediate switch, no grant handshake
        iv = st.adv_open_iv()
        if iv is None:
            return
        old = iv[2]
        if old == new_parent:
            return
        switch = max(effective, st.closed + 1, iv[0])
        end = st.adv_close(switch)
        self._send(old, M.CHILD_DEL(self.rank, old, from_phase=end,
                                    lid=st.lid))
        st.adv_open(end, new_parent)
        self._send(new_parent, M.CHILD_ADD(self.rank, new_parent,
                                           from_phase=end, lid=st.lid))

    def buggy_child_add(self, m):
        st = self.st(m.lid)
        child = m.child if m.child is not None else m.src
        st.book_add(child, m.from_phase)  # BUG: no grant clamping, no ACK
        if st.lid == SNSL:
            rel = self.head_released if self.is_head else st.released
            if rel >= 0:
                self._send(child, M.ADV(self.rank, child, phase=rel,
                                        lid=SNSL))
        elif self.is_head:
            self._try_release_head()
        else:
            self._try_close_sc()

    monkeypatch.setattr(phx.PhaserActor, "_reparent", buggy)
    monkeypatch.setattr(phx.PhaserActor, "_on_CHILD_ADD", buggy_child_add)
    found = []
    for cls in [("TUS",), ("SIG",), ("UNL", "UNL_ACK", "DEREG")]:
        res = mc.check(mc.scenario_insert_delete(3), cls,
                       max_states=50_000)
        found += res.violations
    assert found, "checker failed to catch the injected bug"
