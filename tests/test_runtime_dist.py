"""Multi-host elastic runtime: the skip-list control plane partitioned
over processes (``runtime_dist``).

Tier-1 tests drive the ``InprocCluster`` fabric — every host agent in
this address space, frames through ``InprocFabric`` — which is enough
to prove the partitioned protocol itself: two-phase joins landing as
epochs, the demote→evict path, stale-notification black-holing, and
fingerprint agreement between every process's partition and the
replicated oracle at every boundary.

The slow tier crosses real process boundaries: ``SocketCluster``
spawns ``repro.runtime_dist.worker`` OS processes over AF_UNIX
sockets (a host joins mid-epoch, a straggler is struck out through
demote→evict), and a 3-process × 2-device cluster proves the
checkpoint-resume contract — the manifest's program key records the
process set live at save time, so a resume pre-compiles the
surviving-host program, not the boot-set one.
"""
from __future__ import annotations

import os
import subprocess
import sys

import pytest

from repro.runtime_dist import COORD, DistCoordinator, InprocCluster

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def coordinator(n, **kw):
    return DistCoordinator(InprocCluster(), n, seed=kw.pop("seed", 0), **kw)


# ------------------------------------------------------------ tier-1 inproc
def test_boot_derives_agreed_epoch():
    rt = coordinator(4)
    ep = rt.epoch
    assert ep.index == 0 and ep.n == 4 and ep.live == (0, 1, 2, 3)
    assert ep.fingerprint            # every partition agreed (asserted
    st = rt.control_stats()          # inside _derive_boundary)
    assert st["live"] == [0, 1, 2, 3]
    # boot is oracle-seeded (no protocol frames yet); the first phase
    # crosses processes — every SIG targets the coordinator's HEAD
    rt.advance(step=0)
    assert rt.shard.released() == 0          # phase 0 released
    st = rt.control_stats()
    assert st["remote_frames"] > 0 and st["critical_path"] > 0
    rt.close()


def test_churn_lifecycle_epochs_and_fingerprints():
    """join -> demote -> repromote -> evict, each landing lazily as an
    epoch at the next phase boundary, fingerprint-verified on every
    surviving process."""
    rt = coordinator(3)
    fps = [rt.epoch.fingerprint]

    pid = rt.request_join(step=0)          # eager splice, lazy promote
    assert pid == 3 and rt.pending_churn
    rt.advance(step=0)
    assert rt.epoch.index == 1 and rt.epoch.n == 4
    fps.append(rt.epoch.fingerprint)

    rt.request_demote(pid, step=1)
    rt.advance(step=1)
    assert rt.epoch.demoted == (pid,)
    fps.append(rt.epoch.fingerprint)

    rt.request_repromote(pid, step=2)
    rt.advance(step=2)
    assert rt.epoch.demoted == ()
    fps.append(rt.epoch.fingerprint)

    rt.request_leave(1, fail=True, step=3)
    rt.advance(step=3)
    assert rt.epoch.live == (0, 2, 3)
    fps.append(rt.epoch.fingerprint)

    # a structural change must change the agreed structure identity
    assert fps[0] != fps[1] and fps[1] != fps[2] and fps[3] != fps[4]
    assert [e.kind for e in rt.events] == ["join", "demote", "repromote",
                                           "fail"]
    # clean steady state: further phases advance with no churn
    before = rt.epoch.index
    rt.advance(step=4)
    assert rt.epoch.index == before
    rt.close()


def test_eviction_black_holes_stale_notifications():
    """After a host leaves, in-flight/late notifications addressed to
    its actor must be dropped at the network edge (the monolithic
    runtime delivers them to a departed actor that ignores them) — the
    eviction plus the next boundary must not try to route to the gone
    process."""
    rt = coordinator(4)
    rt.request_leave(1, step=0)
    rt.advance(step=0)                     # boundary over the survivors
    nets = [rt.shard.net] + [a.shard.net
                             for a in rt.cluster.agents.values()]
    for net in nets:
        assert 1 in net.dropped, sorted(net.dropped)
    # the counter only ticks when a stale frame actually arrives; the
    # invariant is bookkeeping + liveness, so churn again on top
    rt.request_join(step=1)
    rt.advance(step=1)
    assert rt.epoch.live == (0, 2, 3, 4)
    assert all(b >= 0 for b in (n.black_holed for n in nets))
    rt.close()


def test_strike_escalation_evicts_straggling_host():
    """The single-runtime straggler policy applied at host granularity:
    straggle, demote to an SCSL leaf, then evict via the deletion
    path."""
    rt = coordinator(3)
    evicted = []
    for step in range(4):
        times = {0: 1.0, 1: 1.0, 2: 10.0}       # host 2 always slow
        evicted += rt.record_step_times(step, times, slack=3.0,
                                        demote_after=2, evict_after=3)
        rt.advance(step=step)
        if evicted:
            break
    assert evicted == [2]
    assert rt.epoch.live == (0, 1)
    kinds = [e.kind for e in rt.events]
    assert "straggle" in kinds and "demote" in kinds and "fail" in kinds
    assert kinds.index("demote") < kinds.index("fail")
    rt.close()


def test_coordinator_owns_head_processes_own_their_actors():
    rt = coordinator(3)
    from repro.core.skiplist import HEAD
    assert rt.shard.owner_of(HEAD) == COORD
    for pid, agent in rt.cluster.agents.items():
        assert agent.shard.owner_of(pid) == pid
        assert agent.shard.owner_of(HEAD) == COORD
    rt.close()


# ------------------------------------------------- slow: real OS processes
@pytest.mark.slow
def test_socket_cluster_join_and_strike_eviction_subprocess():
    """Satellite churn test over real processes: boot 3 workers, a 4th
    joins mid-epoch, one is struck out through the straggler path —
    with oracle/fingerprint agreement across all surviving processes
    at every boundary (asserted inside every ``_derive_boundary``)."""
    code = """
import os
os.chdir({root!r})
from repro.runtime_dist import DistCoordinator, SocketCluster

rt = DistCoordinator(SocketCluster(control_only=True), 3, seed=0)
assert rt.epoch.n == 3
rt.advance(step=0)                       # a clean phase first
rt.request_join(step=1)                  # host 3 joins mid-epoch
rt.advance(step=1)
assert rt.epoch.index == 1 and rt.epoch.live == (0, 1, 2, 3)
evicted = []
for step in range(2, 6):
    times = {{p: (10.0 if p == 1 else 1.0) for p in rt.live}}
    evicted += rt.record_step_times(step, times, slack=3.0,
                                    demote_after=2, evict_after=3)
    rt.advance(step=step)
    if evicted:
        break
assert evicted == [1], evicted
assert rt.epoch.live == (0, 2, 3)
kinds = [e.kind for e in rt.events]
assert kinds.index("demote") < kinds.index("fail")
st = rt.control_stats()
assert st["remote_frames"] > 0 and st["critical_path"] > 0
assert len({{e.fingerprint for e in rt.epochs}}) == len(rt.epochs)
rt.close()
print("OK")
""".format(root=REPO)
    out = subprocess.run([sys.executable, "-c", code],
                         capture_output=True, text=True,
                         env={**os.environ, "PYTHONPATH":
                              os.path.join(REPO, "src")},
                         cwd=REPO, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK" in out.stdout


@pytest.mark.slow
def test_resume_after_eviction_precompiles_surviving_host_program(tmp_path):
    """Satellite regression: the checkpoint manifest's program key
    records the PROCESS SET live at save time. A naive restart boots
    the original host set; resume must read the manifest, shed the
    evicted host, and pre-compile the surviving-host program — so the
    first boundary after restore is a pure cache hit."""
    ckpt = str(tmp_path / "ckpt")
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=6"
import numpy as np
from repro.runtime_dist import DistCoordinator, InprocCluster

CKPT = {ckpt!r}
def data_for(pid):
    return dict(arch="smollm-135m", layers=2, batch=2, seq=16,
                lr=1e-3, steps=50, devices=6,
                device_slice=[pid * 2, 2], ckpt_dir=CKPT,
                local_kind="phaser_scsl")

# ---- run 1: 3 hosts x 2 devices, evict host 2, checkpoint, crash
rt = DistCoordinator(InprocCluster(), 3, seed=0, data_for=data_for)
for s in range(2):
    rt.train_step(s)
    rt.advance(step=s)
rt.request_leave(2, fail=True, step=2)
rt.advance(step=2)                       # epoch over survivors {{0, 1}}
assert rt.epoch.live == (0, 1)
rt.train_step(3)
rt.save_checkpoint(4)
pk = rt.cluster.call(0, {{"op": "manifest_key"}})["program_key"]
assert pk["process_set"] == [0, 1], pk   # survivors, not the boot set
probe = {{p: rt.cluster.call(p, {{"op": "loss_probe"}})["loss"]
         for p in sorted(rt.live)}}
rt.close()

# ---- run 2: naive restart with the BOOT host set
rt2 = DistCoordinator(InprocCluster(), 3, seed=0, data_for=data_for)
mk = rt2.cluster.call(0, {{"op": "manifest_key"}})["program_key"]
for pid in sorted(set(rt2.live) - set(mk["process_set"])):
    rt2.request_leave(pid, step=0)       # shed hosts not in the manifest
out = rt2.resume()
assert out["step"] == 4, out
assert out["program_key"]["process_set"] == [0, 1]
# the survivor program was NOT in the restarted caches (they only hold
# the 3-host boot program) — resume had to compile it, per host
assert out["compiled"] == {{0: True, 1: True}}, out
# restored params are the checkpointed ones, replicated
probe2 = {{p: rt2.cluster.call(p, {{"op": "loss_probe"}})["loss"]
          for p in sorted(rt2.live)}}
assert probe2[0] == probe2[1], probe2
for p in (0, 1):
    np.testing.assert_allclose(probe2[p], probe[p], rtol=0, atol=0)
stats = {{p: rt2.cluster.agents[p]._dp["cache"].stats()
         for p in (0, 1)}}
rt2.advance(step=4)                      # first boundary after resume
for p in (0, 1):
    after = rt2.cluster.agents[p]._dp["cache"].stats()
    assert after["misses"] == stats[p]["misses"], (p, stats[p], after)
    assert after["hits"] > stats[p]["hits"], (p, stats[p], after)
rt2.train_step(4)                        # and stepping still works
rt2.close()
print("OK")
""".format(ckpt=ckpt)
    out = subprocess.run([sys.executable, "-c", code],
                         capture_output=True, text=True,
                         env={**os.environ, "PYTHONPATH":
                              os.path.join(REPO, "src")},
                         cwd=REPO, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK" in out.stdout
