"""Split-phase (fuzzy barrier) semantics + heavy-churn coverage.

The deterministic tests run everywhere; the hypothesis-driven churn
sweeps are skipped (not errored) where the dev-only dependency is
missing, so tier-1 collection never breaks."""
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis",
    reason="dev-only dependency (requirements-dev.txt); property tier "
           "skipped where it is not installed")
from hypothesis import given, settings, strategies as st

from repro.core.phaser import HEAD, SIG_MODE, SIG_WAIT, WAIT_MODE, DistPhaser
from repro.core.runtime import FifoScheduler, RandomScheduler


def test_split_phase_signal_early_wait_late():
    """The phaser's split-phase property: a task that signaled phase k may
    keep computing; the phase advances without it calling any wait — its
    own release notification is observable whenever it looks."""
    ph = DistPhaser(4, seed=0)
    ph.signal(0)                       # task 0 signals EARLY...
    ph.run(FifoScheduler())
    assert ph.released() == -1         # others haven't signaled
    for r in (1, 2, 3):
        ph.signal(r)
    ph.run(FifoScheduler())
    assert ph.released() == 0          # phase advanced; 0 never 'waited'
    # task 0 (conceptually still computing) observes the release lazily
    assert ph.released(0) == 0
    # and can already signal the NEXT phase before anyone else
    ph.signal(0)
    ph.run(FifoScheduler())
    assert ph.released() == 0          # phase 1 incomplete: fuzzy overlap


def test_signal_ahead_multiple_phases():
    """A fast producer may run several phases ahead (bounded only by its
    own work): counts for future phases buffer at the head."""
    ph = DistPhaser(3, seed=2)
    for _ in range(3):
        ph.signal(0)                   # 0 races 3 phases ahead
    ph.run(FifoScheduler())
    assert ph.released() == -1
    for k in range(3):
        ph.signal(1)
        ph.signal(2)
        ph.run(FifoScheduler())
        assert ph.released() == k      # each phase closes as laggards catch up


def test_wait_only_members_get_all_releases():
    modes = {0: SIG_MODE, 1: SIG_MODE, 2: WAIT_MODE, 3: SIG_WAIT}
    ph = DistPhaser(4, modes=modes, seed=1)
    for k in range(4):
        ph.next()
    a = ph.actors[2]
    assert a.sn.released == 3          # pure waiter saw every release
    assert not a.sc.member             # and never participated in SCSL


@given(st.integers(0, 200), st.integers(4, 9), st.integers(1, 3),
       st.integers(1, 2))
@settings(max_examples=25, deadline=None)
def test_multi_add_multi_drop_churn(seed, n, n_add, n_drop):
    """C>1 concurrent insertions + multiple concurrent deletions under
    adversarial delivery: phase completes exactly, structure converges."""
    rng = np.random.default_rng(seed)
    ph = DistPhaser(n, seed=seed % 5)
    newbies = []
    for i in range(n_add):
        parent = int(rng.integers(0, n))
        ph.async_add(parent, n + 10 + i)
        newbies.append(n + 10 + i)
    victims = list(rng.choice(np.arange(1, n), size=min(n_drop, n - 2),
                              replace=False))
    for v in victims:
        ph.drop(int(v))
    for r in range(n):
        if r not in victims:
            ph.signal(r)
    for w in newbies:
        ph.signal(w)
    ph.run(RandomScheduler(seed), max_steps=500_000)
    assert ph.released() == 0, (seed, n, n_add, victims)
    ph.check_quiescent_invariants()
    head = ph.actors[HEAD]
    assert not any(k <= head.head_released and v > 0
                   for k, v in head.sc.buf.items()), "P2 residual"


@given(st.integers(0, 100))
@settings(max_examples=15, deadline=None)
def test_drop_then_rejoin_cycles(seed):
    """Workers leave and new ones join over several phases (the elastic
    training lifecycle), under adversarial delivery."""
    rng = np.random.default_rng(seed)
    ph = DistPhaser(5, seed=1)
    sched = RandomScheduler(seed)
    live = set(range(5))
    next_id = 100
    for k in range(4):
        if k == 1:
            v = int(sorted(live)[rng.integers(1, len(live))])
            ph.drop(v)
            live.discard(v)
        if k == 2:
            parent = min(live)
            ph.async_add(parent, next_id)
            live.add(next_id)
            next_id += 1
        for r in sorted(live):
            ph.signal(r)
        ph.run(sched, max_steps=500_000)
        assert ph.released() == k, (seed, k)
    ph.check_quiescent_invariants()
