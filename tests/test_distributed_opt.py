"""Gradient compression (error feedback) + straggler policy tests."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.optim.compression import (compress, compressed_bytes,
                                     decompress, init_ef_state)
from repro.runtime_elastic import ElasticController


def test_int8_roundtrip_accuracy():
    g = {"w": jax.random.normal(jax.random.key(0), (256, 64)) * 0.01,
         "b": jax.random.normal(jax.random.key(1), (64,)) * 0.1}
    st = init_ef_state(g)
    q, s, st = compress(g, st)
    back = decompress(q, s)
    for k in g:
        rel = float(jnp.max(jnp.abs(back[k] - g[k]))
                    / jnp.max(jnp.abs(g[k])))
        assert rel < 0.02, (k, rel)   # <=1/127 + rounding


def test_error_feedback_conserves_mass():
    """Sum of transmitted + residual == sum of raw gradients over steps
    (nothing silently lost)."""
    key = jax.random.key(2)
    g_total = jnp.zeros((128,))
    sent_total = jnp.zeros((128,))
    st = init_ef_state({"w": g_total})
    for i in range(20):
        key, sub = jax.random.split(key)
        g = {"w": jax.random.normal(sub, (128,)) * 1e-3}
        g_total = g_total + g["w"]
        q, s, st = compress(g, st)
        sent_total = sent_total + decompress(q, s)["w"]
    drift = sent_total + st.residual["w"] - g_total
    np.testing.assert_allclose(np.asarray(drift), 0.0, atol=1e-5)


def test_compression_ratio():
    g = {"w": jnp.zeros((1024, 1024))}
    full, comp = compressed_bytes(g)
    assert full / comp > 3.9


def test_straggler_policy_evicts_persistent():
    c = ElasticController(4, seed=0)
    for step in range(5):
        c.step_barrier(step)
        times = {0: 1.0, 1: 1.0, 2: 1.0, 3: 10.0}   # 3 is 10x median
        evicted = c.record_step_times(step, times)
        if step < 2:
            assert evicted == []
        if evicted:
            assert evicted == [3]
            break
    assert 3 not in c.live
    # phases keep completing without the evicted worker
    before = c.ph.released()
    assert c.step_barrier(99) == before + 1
    kinds = [e.kind for e in c.events]
    assert kinds.count("straggle") == 3 and "fail" in kinds


def test_straggler_policy_forgives_transient():
    c = ElasticController(4, seed=0)
    for step in range(6):
        c.step_barrier(step)
        slow = 3 if step % 2 == 0 else 1     # alternating — never 3 strikes
        times = {w: (5.0 if w == slow else 1.0) for w in range(4)}
        c.record_step_times(step, times)
    assert c.live == {0, 1, 2, 3}
